"""End-to-end LLM serving with the bin-packing autoscaler.

Request streams (ordered partitions) feed replicas that run a real jitted
``serve_step`` of a small qwen3-family model; the monitor measures each
stream's byte rate, and the controller sizes the fleet and assigns streams
with MBFP -- scaling up on a traffic spike and back down after, while the
broker enforces the single-reader invariant through every migration.

  PYTHONPATH=src python examples/autoscale_serve.py
"""
import json

import numpy as np

from repro import configs
from repro.broker import TopicPartition
from repro.serving import AutoscaleSimulation
from repro.serving.llm_replica import LLMReplica, SharedModel
from repro.serving.replica import ReplicaConfig

CAP = 0.25e6          # replica ingest capacity (bytes/s of request payload)
REC = 65536           # one request record (big payloads -> few real decodes on CPU)
N_STREAMS = 6


def main():
    cfg = configs.get("qwen3-8b", smoke=True)
    model = SharedModel(cfg, max_len=16, max_batch=8)
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    def rate_fn(tp: TopicPartition, t: float) -> float:
        base = 0.05e6 * (1 + tp.partition % 3)
        if 80 <= t < 160:                       # traffic spike on streams 0-2
            return base * (4 if tp.partition < 3 else 1)
        return base

    sim = AutoscaleSimulation(n_partitions=N_STREAMS, rate_fn=rate_fn,
                              capacity=CAP, monitor_interval=5.0,
                              record_bytes=REC)
    # swap in LLM replicas (requests as payloads)
    sink = sim.sink
    broker = sim.broker
    sim.manager._factory = lambda cid: LLMReplica(
        cid, broker, sink, ReplicaConfig(rate=CAP), model)

    # produce actual request payloads instead of raw bytes
    rng = np.random.default_rng(0)

    def produce(dt):
        t = sim.clock.now()
        for i in range(N_STREAMS):
            tp = TopicPartition(sim.topic, i)
            sim._accum[i] += max(0.0, rate_fn(tp, t)) * dt
            while sim._accum[i] >= sim.record_bytes:
                req = json.dumps({"prompt": rng.integers(
                    1, cfg.vocab_size, size=2).tolist(), "gen": 2})
                broker.produce(tp, req, nbytes=sim.record_bytes)
                sim._accum[i] -= sim.record_bytes
                sim.produced_bytes += sim.record_bytes
    sim._produce = produce

    marks = {60: "steady", 140: "SPIKE", 230: "post-spike"}
    for step in range(240):
        sim.tick(1.0)
        t = int(sim.clock.now())
        if t in marks:
            reps = sim.manager.replicas
            tokens = sum(getattr(r, "generated_tokens", 0) for r in reps.values())
            print(f"t={t:4d}s [{marks[t]:10s}] replicas={sim.manager.n_alive()} "
                  f"lag={sim.broker.total_lag('autoscaler', sim.topic)/1e3:.0f}KB "
                  f"tokens_generated={tokens}")
            del marks[t]

    n_mig = len(sim.controller.migrations)
    moved = sum(len(m.moved) for m in sim.controller.migrations)
    print(f"\nreassignments: {n_mig}, total stream migrations: {moved}, "
          f"mean Rscore: {np.mean([m.rscore for m in sim.controller.migrations]):.3f}")
    served = sum(getattr(r, "requests_served", 0)
                 for r in sim.manager.replicas.values())
    print(f"requests served by current fleet: {served}; "
          f"fleet size: {sim.manager.n_alive()}")
    assert sim.manager.n_alive() >= 1


if __name__ == "__main__":
    main()
