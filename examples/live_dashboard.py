"""Live terminal dashboard over a fleet run: streaming sketch snapshots
and incident counts from the host-side ``progress`` callback.

``FleetRunner.simulate(..., progress=...)`` hands a
:class:`~repro.fleet.FleetProgress` snapshot to the callback after each
bucket group finishes: scenarios done/total, the merge of every finished
scenario's streaming sketch (:class:`SketchSummary` -- whole-run
mean/extrema/EWMA and histogram quantiles per channel, O(1) memory no
matter how long the run), and the cumulative per-rule incident counts
from the in-loop alerting rules.  This example renders those snapshots
as a redrawing ANSI dashboard -- what an operator console tailing a
long sweep would show -- without ever materialising per-step frames
(``record_frames=False``).

The callback is strictly opt-in and off by default: a fleet run without
``progress=`` never invokes host code mid-run, and the dashboard never
changes trajectories -- it only *reads* finished buckets.

  PYTHONPATH=src python examples/live_dashboard.py            # dashboard
  PYTHONPATH=src python examples/live_dashboard.py --smoke    # CI: plain
  PYTHONPATH=src python examples/live_dashboard.py --no-ansi  # append-only
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.core.scenarios import generate_masked_scenario
from repro.fleet import FleetConfig, FleetProgress, FleetRunner
from repro.lagsim import LagSimConfig
from repro.telemetry import (AlertConfig, SketchConfig, TelemetryConfig,
                             default_rules)

#: (family, scenarios, T, N) -- deliberately ragged so the fleet runs
#: several bucket groups and the dashboard gets several snapshots
FULL = (("bursty", 3, 48, 10), ("churn", 3, 64, 8),
        ("topic_lifecycle", 3, 96, 12))
SMOKE = (("bursty", 2, 24, 6), ("topic_lifecycle", 2, 32, 6))

#: sketch channels worth a dashboard row (of the ~10 recorded)
CHANNELS = ("lag_total", "consumers", "unreadable")


def _bar(frac: float, width: int = 32) -> str:
    full = int(round(frac * width))
    return "#" * full + "-" * (width - full)


def render(snap: FleetProgress) -> str:
    """One dashboard frame as plain text (ANSI clearing is the caller's)."""
    lines = [
        "repro fleet dashboard",
        f"  scenarios [{_bar(snap.done / max(snap.total, 1))}] "
        f"{snap.done}/{snap.total}   last bucket {snap.bucket}",
    ]
    if snap.sketch is not None:
        s = snap.sketch
        lines.append(f"  sketch ({s.count:.0f} policy-steps aggregated)")
        lines.append(f"    {'channel':<12} {'mean':>9} {'max':>9} "
                     f"{'ewma':>9} {'p99':>9}")
        ewma = s.ewma[min(s.ewma)]          # fastest window
        for ch in CHANNELS:
            if ch not in s.names:
                continue
            i = s.channel_index(ch)
            p99 = (f"{s.quantile(0.99, ch):>9.3f}"
                   if ch in s.hist_names else f"{'-':>9}")
            lines.append(f"    {ch:<12} {float(s.mean[i]):>9.3f} "
                         f"{float(s.vmax[i]):>9.3f} "
                         f"{float(ewma[i]):>9.3f} {p99}")
    if snap.incidents:
        firing = {k: v for k, v in snap.incidents.items() if v}
        lines.append(f"  incidents {firing if firing else '(none)'}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + plain output for CI")
    ap.add_argument("--no-ansi", action="store_true",
                    help="append frames instead of redrawing in place")
    args = ap.parse_args()
    ansi = not (args.smoke or args.no_ansi) and sys.stdout.isatty()

    plan = SMOKE if args.smoke else FULL
    scenarios = []
    for i, (fam, count, t, n) in enumerate(plan):
        speeds, active = generate_masked_scenario(
            fam, jax.random.key(i), count, t, n)
        scenarios.extend((speeds[b], active[b]) for b in range(count))

    cfg = LagSimConfig(
        capacity=1.0, dt=1.0, migration_steps=2,
        telemetry=TelemetryConfig(record_frames=False,
                                  sketch=SketchConfig(),
                                  alerts=AlertConfig(rules=default_rules())))
    snaps = []

    def on_progress(snap: FleetProgress) -> None:
        snaps.append(snap)
        if ansi:
            sys.stdout.write("\x1b[2J\x1b[H")
        print(render(snap))
        if not ansi:
            print()
        sys.stdout.flush()

    runner = FleetRunner(FleetConfig())
    res = runner.simulate(("MBFP", "KEDA_LAG"), scenarios, cfg,
                          progress=on_progress)

    assert snaps and snaps[-1].done == len(scenarios), (
        "dashboard saw no complete progress stream")
    total_inc = sum(snaps[-1].incidents.values())
    print(f"done: {len(scenarios)} scenarios in {len(snaps)} snapshot(s), "
          f"{total_inc} incident(s) opened "
          f"(rules: {', '.join(res.alert_config.rule_names)})")


if __name__ == "__main__":
    main()
