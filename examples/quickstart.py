"""Quickstart: the paper's algorithms in five minutes, through the stable
``repro.api`` facade.

Everything here resolves policy names through ``repro.registry`` -- the
one catalogue of packers (Sec. II-B heuristics + Sec. IV-B/IV-C sticky
family), optimizers and reactive scalers -- and returns the versioned
result dataclasses of ``repro.api``.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import api

C = 2.3e6  # consumer capacity, bytes/s (the paper's measured 2.3 MB/s)

# --- what's on the shelf -----------------------------------------------------
for family in api.FAMILIES:
    print(f"{family:<10} {', '.join(api.list_policies(family=family))}")

# --- one packing decision ----------------------------------------------------
speeds = {"orders-0": 1.1e6, "orders-1": 0.7e6, "sensors-0": 1.9e6,
          "sensors-1": 0.4e6, "invoices-0": 0.2e6}
res = api.pack(speeds, C, algorithm="BFD")
print(f"\nBFD packs {len(speeds)} partitions onto {res.n_bins} consumers:")
for cid in sorted(res.loads):
    parts = sorted(p for p, c in res.assignment.items() if c == cid)
    print(f"  consumer {cid}: {parts} ({res.loads[cid] / 1e6:.2f} MB/s)")

# --- a rebalance-aware decision (Algorithm 1, MBFP) --------------------------
speeds["sensors-0"] = 2.5e6                    # the load shifted
new = api.pack(speeds, C, algorithm="MBFP", prev=res.assignment)
print(f"\nafter a load spike, MBFP uses {new.n_bins} consumers, "
      f"Rscore={new.rscore:.3f} consumer-iterations/s of backlog while "
      f"rebalancing")

# --- the paper's evaluation on synthetic streams (Eq. 11) --------------------
table = api.evaluate(algorithms=("BFD", "FFD", "NFD", "MBF", "MBFP"),
                     deltas=(5, 15, 25), n_partitions=30,
                     n_measurements=120, capacity=1.0, seed=0)
print("\n delta  algo   CBS      E[R]   (lower is better on both)")
for d in table.deltas:
    for a in sorted(table.algorithms):
        mark = " *pareto" if a in table.pareto[d] else ""
        print(f"  {d:3d}   {a:5s} {table.cbs[d][a]:7.4f} "
              f"{table.avg_rscore[d][a]:7.3f}{mark}")
