"""Quickstart: the paper's algorithms in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ALL_ALGORITHMS, evaluate_deltas, generate_stream,
                        modified_any_fit, pack, pareto_front, rscore)

C = 2.3e6  # consumer capacity, bytes/s (the paper's measured 2.3 MB/s)

# --- one packing decision ---------------------------------------------------
speeds = {"orders-0": 1.1e6, "orders-1": 0.7e6, "sensors-0": 1.9e6,
          "sensors-1": 0.4e6, "invoices-0": 0.2e6}
result = pack(speeds, C, strategy="best", decreasing=True)   # BFD
print(f"BFD packs {len(speeds)} partitions onto {result.n_bins} consumers:")
for cid, parts in sorted(result.bins().items()):
    load = sum(speeds[p] for p in parts)
    print(f"  consumer {cid}: {parts} ({load / 1e6:.2f} MB/s)")

# --- a rebalance-aware decision (Algorithm 1, MBFP) --------------------------
speeds["sensors-0"] = 2.5e6                    # the load shifted
prev = result.pid_to_bin
new = modified_any_fit(speeds, C, group={c: ps for c, ps in result.bins().items()},
                       fit="best", sort_key="max_partition")
r = rscore(prev, new.pid_to_bin, speeds, C)
print(f"\nafter a load spike, MBFP uses {new.n_bins} consumers, "
      f"Rscore={r:.3f} consumer-iterations/s of backlog while rebalancing")

# --- the paper's evaluation on a synthetic stream (Eq. 11) -------------------
streams = {d: generate_stream(30, 120, d, 1.0, seed=0) for d in (5, 15, 25)}
table = evaluate_deltas(
    {k: ALL_ALGORITHMS[k] for k in ("BFD", "FFD", "NFD", "MBF", "MBFP")},
    streams, capacity=1.0)
print("\n delta  algo   CBS      E[R]   (lower is better on both)")
for d, pts in sorted(table.items()):
    front = pareto_front(pts)
    for a, (cbs, er) in sorted(pts.items()):
        mark = " *pareto" if a in front else ""
        print(f"  {d:3d}   {a:5s} {cbs:7.4f} {er:7.3f}{mark}")
