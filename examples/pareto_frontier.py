"""Trace the bins-vs-R-score Pareto frontier of one packing instance and
place every heuristic against it.

The paper's heuristics race each other; this example computes the thing
they should be judged by (the 2024 follow-up's view): the *frontier* of
assignments trading consumer cost against rebalance cost.  For one stream
of a chosen scenario family it

  1. builds the mid-trace instance: current speeds plus the sticky-BFD
     incumbent assignment from the preceding iterations;
  2. sweeps lambda over the batched annealer (``repro.opt``) -- every
     (lambda, restart) chain in one XLA program -- and extracts the
     non-dominated (bins, R-score) front, with the exact branch-and-bound
     bin floor for reference;
  3. repacks the same instance with all 12 heuristics and reports each
     one's position: on/off the front, and its single-point hypervolume
     share of the annealed front's.

  PYTHONPATH=src python examples/pareto_frontier.py
  PYTHONPATH=src python examples/pareto_frontier.py --family heavy_tail --n 10
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.scenarios import SCENARIO_FAMILIES, generate_scenario
from repro.registry import PACKER_FAMILIES, list_policies
from repro.opt import (
    anneal_frontier,
    branch_and_bound,
    heuristic_point,
    incumbent_assignment,
)

CAPACITY = 1.0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--family", default="diurnal",
                    choices=sorted(SCENARIO_FAMILIES))
    ap.add_argument("--n", type=int, default=8, help="partitions")
    ap.add_argument("--iters", type=int, default=16, help="trace length")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lambdas", type=float, nargs="+",
                    default=[0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0])
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    trace = np.asarray(generate_scenario(
        args.family, jax.random.key(args.seed), 1, args.iters, args.n,
        capacity=CAPACITY))[0]                                 # [T, N]
    t_rep = args.iters // 2
    prev = incumbent_assignment(trace, CAPACITY, t_rep)
    speeds = trace[t_rep]

    opt = branch_and_bound(speeds.tolist(), CAPACITY)
    print(f"{args.family}: iteration {t_rep} of a {args.iters}-step stream, "
          f"{args.n} partitions, sum(speeds)={speeds.sum():.2f} C")
    print(f"exact bin floor (branch-and-bound, "
          f"{'proven optimal' if opt.optimal else 'upper bound'}): "
          f"{opt.n_bins} consumers\n")

    fr = anneal_frontier(speeds, prev, CAPACITY, jax.random.key(args.seed),
                         lambdas=args.lambdas, restarts=args.restarts,
                         steps=args.steps)
    print("annealed lambda sweep (best chain per lambda):")
    for lam, (b, r) in zip(fr.lambdas, fr.per_lambda):
        print(f"  lambda={lam:<5g} -> {int(b)} consumers, Rscore {r:.3f}")
    print(f"\nPareto front (over all {len(args.lambdas) * args.restarts} "
          f"chains), hypervolume {fr.hypervolume:.3f}:")
    for b, r in fr.front:
        print(f"  {int(b)} consumers, Rscore {r:.3f}")

    print(f"\n{'algorithm':<8} {'consumers':>9} {'Rscore':>8} "
          f"{'vs frontier':>12} {'HV share':>9}")
    for name in list_policies(family=PACKER_FAMILIES, backend="jax"):
        pt = heuristic_point(name, speeds, prev, CAPACITY)
        met = fr.heuristic_metrics(pt)
        tag = "dominated" if met["dominated"] else "on front"
        print(f"{name:<8} {int(pt[0]):>9} {pt[1]:>8.3f} {tag:>12} "
              f"{met['hv_ratio']:>8.1%}")
    print("\n(HV share = the heuristic point's own hypervolume over the "
          "annealed front's; 100% = it matches the whole frontier)")


if __name__ == "__main__":
    main()
