"""Adversarial robustness report: the worst-case SLO envelope per policy
family, from ``BENCH_adversarial.json``.

Reads the benchmark artifact the adversarial search publishes
(``benchmarks/adversarial_bench.py``) and prints, per registry family:
the representative policy, the worst violation fraction the evolved
scenario achieved against it, the incident load at that worst case, the
random-search baseline at the same eval budget, and the witness knobs --
the concrete burst/skew/churn/lifecycle settings that realize the
worst case (replay them via ``repro.api.replay`` on the matching
``witness_<family>.npz`` trace).

``--attack POLICY`` skips the artifact and runs a fresh small search
against one named policy instead, printing the same row live.

  PYTHONPATH=src python examples/adversarial_report.py
  PYTHONPATH=src python examples/adversarial_report.py --smoke
  PYTHONPATH=src python examples/adversarial_report.py --attack MWF
"""
from __future__ import annotations

import argparse
import json
import os

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_adversarial.json")

#: the knobs worth a column (the rest are in the JSON)
_KNOB_COLS = ("burst_amp", "burst_len_frac", "tail_sigma", "churn_p")


def _print_table(families: dict) -> None:
    hdr = (f"{'family':<11} {'policy':<13} {'worst viol%':>11} "
           f"{'incidents':>9} {'random%':>8} {'beats':>5}  witness knobs")
    print(hdr)
    print("-" * len(hdr))
    for fam in sorted(families):
        row = families[fam]
        knobs = row["witness_knobs"]
        knob_s = " ".join(f"{k}={knobs[k]:.2f}" for k in _KNOB_COLS
                          if k in knobs)
        print(f"{fam:<11} {row['policy']:<13} "
              f"{100 * row['worst_violation_frac']:>11.1f} "
              f"{row['worst_incidents']:>9.1f} "
              f"{100 * row['baseline']['best_violation_frac']:>8.1f} "
              f"{'yes' if row['beats_baseline'] else 'no':>5}  {knob_s}")


def _attack_row(policy: str) -> dict:
    from repro.api import SearchConfig, attack
    from repro.lagsim import LagSimConfig

    cfg = SearchConfig(pop_size=8, generations=5, iters=96, n=6)
    out = attack(policy, config=cfg, sim=LagSimConfig(), seed=0)
    return {
        "policy": out.policy,
        "worst_violation_frac": out.best_violation_frac,
        "worst_incidents": out.best_incidents,
        "witness_knobs": out.witness_knobs,
        "baseline": {"best_violation_frac":
                     out.baseline.best_violation_frac if out.baseline
                     else 0.0},
        "beats_baseline": bool(out.beats_baseline),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: require the artifact to exist, carry "
                         "every registry family, and print cleanly")
    ap.add_argument("--attack", metavar="POLICY",
                    help="run a fresh small search against POLICY instead "
                         "of reading the artifact")
    ap.add_argument("--bench", default=BENCH_PATH,
                    help="path to BENCH_adversarial.json")
    args = ap.parse_args()

    if args.attack:
        _print_table({"(live)": _attack_row(args.attack)})
        return

    with open(args.bench) as f:
        report = json.load(f)
    families = report["families"]
    if args.smoke:
        from repro.scenarios import family_representatives

        missing = sorted(set(family_representatives()) - set(families))
        assert not missing, (
            f"BENCH_adversarial.json is missing envelope rows for "
            f"registry families {missing}; re-run "
            f"benchmarks/adversarial_bench.py")
        for fam, row in families.items():
            assert 0.0 <= row["worst_violation_frac"] <= 1.0, (fam, row)
            assert len(row["witness_genome"]) > 0, fam
    print(f"adversarial worst-case envelope "
          f"(seed {report['config']['seed']}, "
          f"{report['config']['pop_size']}x"
          f"{report['config']['generations']} search, "
          f"{report['config']['iters']} steps x "
          f"{report['config']['n_partitions']} partitions)\n")
    _print_table(families)
    print("\n(random% = best violation a uniform random search found at "
          "the same eval budget; replay any row via repro.api.replay on "
          "its witness_<family>.npz trace)")
    if args.smoke:
        print("adversarial report smoke OK")


if __name__ == "__main__":
    main()
