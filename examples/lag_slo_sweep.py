"""Closed-loop lag sweep: scaling policies x scenario families, with SLO
metrics.

Where ``examples/scenario_sweep.py`` *scores* packings (bins, R-score,
migrations), this example closes the loop: the lag digital twin
(``repro.lagsim``) evolves per-partition backlog under each policy --
including migration downtime, the paper's rebalancing cost made physical
-- and reports what operators actually page on: SLO violation fraction,
peak lag, time-to-drain, and consumer-seconds cost.

Policies cover the paper's bin-packing algorithms *and* the
industry-standard reactive baselines (KEDA-style lag threshold,
consumption-rate threshold), so the trade-off the paper claims --
adequate consumption at lower cost -- is directly visible per family.

Scenarios run through the *masked* generator API: ``churn`` and
``topic_lifecycle`` partitions genuinely disappear (``active == False``
-- unreadable and empty) rather than idling near zero, exercising the
variable-N mask contract end to end.

``--trace PATH`` swaps the generated suite for one recorded trace
(``repro.scenarios`` ``.json``/``.npz`` -- a seed-library shape or an
adversarial witness), replayed through the same closed loop at the
trace's own capacity.

  PYTHONPATH=src python examples/lag_slo_sweep.py           # small sweep
  PYTHONPATH=src python examples/lag_slo_sweep.py --smoke   # CI-sized
  PYTHONPATH=src python examples/lag_slo_sweep.py --trace witness_heuristic.npz
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.scenarios import masked_scenario_suite
from repro.lagsim import LagSimConfig, summarize_sweep, sweep_lag

FULL = dict(policies=("BFD", "MBFP", "MWFP", "KEDA_LAG", "RATE_THRESHOLD"),
            families=("diurnal", "ramp", "bursty", "churn", "heavy_tail",
                      "topic_lifecycle"),
            batch=3, iters=64, n=12)
SMOKE = dict(policies=("BFD", "MBFP", "KEDA_LAG"),
             families=("bursty", "churn", "topic_lifecycle"),
             batch=2, iters=24, n=6)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run the fused Pallas lag-update kernel inside the "
                         "scan (interpret mode on CPU) instead of the jnp "
                         "reference path")
    ap.add_argument("--trace", metavar="PATH",
                    help="replay a recorded trace (.json/.npz from "
                         "repro.scenarios) instead of the generated suite")
    args = ap.parse_args()
    p = dict(SMOKE if args.smoke else FULL)

    capacity = 1.0
    if args.trace:
        from repro.scenarios import load_trace

        tr = load_trace(args.trace)
        capacity = float(tr.capacity)
        p["families"] = (tr.name,)
        p["batch"], p["iters"], p["n"] = tr.batch, tr.iters, tr.n
        suite = {tr.name: (tr.rates, tr.active)}
        print(f"replaying trace {tr.name!r} ({tr.source}, "
              f"capacity {capacity:g})")
    cfg = LagSimConfig(capacity=capacity, dt=1.0, migration_steps=2,
                       use_kernel=args.use_kernel)
    if not args.trace:
        suite = masked_scenario_suite(jax.random.key(0), p["batch"],
                                      p["iters"], p["n"],
                                      families=p["families"])
    print(f"closed-loop sweep: {len(p['policies'])} policies x "
          f"{len(p['families'])} families x {p['batch']} streams of "
          f"{p['iters']} steps, {p['n']} partitions (masked) ...")

    hdr = (f"{'family':<15} {'policy':<15} {'viol%':>6} {'peak lag':>9} "
           f"{'drain(s)':>9} {'cost(c*s)':>10} {'migrations':>10}")
    for fam in p["families"]:
        speeds, active = suite[fam]
        res = sweep_lag(p["policies"], speeds, cfg, active=active)
        s = summarize_sweep(res, cfg)
        print(f"\n{hdr}")
        best = int(np.argmin(s["violation_frac"].mean(axis=1)))
        for i, pol in enumerate(res.policies):
            star = " *" if i == best else ""
            print(f"{fam:<15} {pol:<15} "
                  f"{100 * s['violation_frac'][i].mean():>6.1f} "
                  f"{s['peak_lag'][i].mean():>9.2f} "
                  f"{s['time_to_drain'][i].mean():>9.1f} "
                  f"{s['consumer_seconds'][i].mean():>10.0f} "
                  f"{s['total_migrations'][i].mean():>10.0f}{star}")
    print("\n(* = lowest mean SLO-violation fraction in that family; "
          "lag in units of one consumer-step of capacity)")


if __name__ == "__main__":
    main()
