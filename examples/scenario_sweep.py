"""Batched scenario sweep: every packing algorithm x a fleet of workloads.

Generates a batch of synthetic partition write-speed trajectories from
several scenario families (diurnal cycles, launch ramps, flash crowds,
topic churn, heavy-tailed skew -- see docs/paper_map.md for the catalogue),
stacks them into one ``f32[B, T, N]`` tensor, and evaluates all 12 packing
algorithms over the whole fleet in one vmapped XLA program per algorithm.

Prints, per (family, algorithm): mean consumers used, mean Rscore (Eq. 10)
and total partition migrations -- the same cost/disruption trade-off as the
paper's Figs. 6-9, but across workload shapes the paper never tested.

  PYTHONPATH=src python examples/scenario_sweep.py
"""
from __future__ import annotations

import collections

import jax
import numpy as np

from repro.core.jaxpack import sweep_streams
from repro.core.scenarios import scenario_suite, stack_suite
from repro.registry import PACKER_FAMILIES, list_policies

ALGORITHMS = list_policies(family=PACKER_FAMILIES, backend="jax")

FAMILIES = ("diurnal", "ramp", "bursty", "churn", "heavy_tail")
BATCH = 3          # streams per family
ITERS = 48         # measurements per stream
N_PARTITIONS = 16
CAPACITY = 1.0


def main() -> None:
    suite = scenario_suite(jax.random.key(0), BATCH, ITERS, N_PARTITIONS,
                           capacity=CAPACITY, families=FAMILIES)
    labels, batch = stack_suite(suite)
    print(f"sweeping {len(ALGORITHMS)} algorithms over "
          f"{batch.shape[0]} streams ({len(FAMILIES)} families x {BATCH}) "
          f"of {ITERS} iterations x {N_PARTITIONS} partitions ...")
    res = sweep_streams(ALGORITHMS, batch, CAPACITY)

    rows = collections.defaultdict(dict)
    bins = np.asarray(res.bins)          # (A, B, T)
    rscores = np.asarray(res.rscores)
    migs = np.asarray(res.migrations)
    fam_idx = {f: [i for i, l in enumerate(labels) if l == f]
               for f in FAMILIES}
    for a, algo in enumerate(res.algorithms):
        for fam, idx in fam_idx.items():
            rows[fam][algo] = (bins[a, idx].mean(), rscores[a, idx].mean(),
                               int(migs[a, idx].sum()))

    hdr = f"{'family':<11} {'algo':<5} {'mean bins':>9} {'mean R':>8} {'migrations':>10}"
    for fam in FAMILIES:
        print(f"\n{hdr}")
        best = min(rows[fam], key=lambda a: rows[fam][a][0])
        for algo in res.algorithms:
            b, r, m = rows[fam][algo]
            star = " *" if algo == best else ""
            print(f"{fam:<11} {algo:<5} {b:>9.2f} {r:>8.4f} {m:>10d}{star}")
    print("\n(* = fewest mean consumers in that family)")


if __name__ == "__main__":
    main()
