"""Elastic training with preemption recovery.

Trains a small LM end to end (data pipeline -> jitted fwd/bwd/AdamW ->
checkpoints), kills the run mid-flight, restarts from the checkpoint
(including the data-pipeline cursor), and verifies the loss keeps
descending.  On CPU the default config is a ~2M-param model so a few hundred
steps complete in minutes; pass ``--full`` for the ~100M-param config used
on real hardware (same code path).

  PYTHONPATH=src python examples/elastic_train.py
"""
import argparse
import dataclasses
import shutil
import tempfile

from repro.models import ArchConfig
from repro.launch.train import train

TINY = ArchConfig(
    name="elastic-demo-2m", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=384, vocab_size=2048, remat=False,
    dtype="float32", param_dtype="float32",
)

FULL_100M = ArchConfig(
    name="elastic-demo-100m", family="dense",
    n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
    d_ff=2560, vocab_size=32000,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M-param config")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    cfg = FULL_100M if args.full else TINY
    batch, seq = (8, 256) if args.full else (8, 64)

    ckpt = tempfile.mkdtemp(prefix="elastic_train_")
    try:
        print(f"=== phase 1: train {cfg.name}, preempted at step "
              f"{args.steps // 2} ===")
        out1 = train(cfg, steps=args.steps, batch=batch, seq=seq,
                     ckpt_dir=ckpt, save_every=args.steps // 4,
                     die_at_step=args.steps // 2)
        print(f"=== phase 2: restart from checkpoint, finish to "
              f"{args.steps} ===")
        out2 = train(cfg, steps=args.steps, batch=batch, seq=seq,
                     ckpt_dir=ckpt, save_every=args.steps // 4)
        l0 = out1["losses"][0]
        l1 = out2["losses"][-1]
        print(f"\nloss {l0:.3f} -> {l1:.3f} across the preemption boundary")
        assert l1 < l0, "loss did not improve across restart"
        print("elastic restart OK")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
