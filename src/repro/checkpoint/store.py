"""Checkpoint store.

Layout:  <dir>/step_<N>/MANIFEST.msgpack  +  one compressed blob per leaf
(zstd when ``zstandard`` is installed, stdlib zlib otherwise; the manifest
records the codec per leaf so either reader restores either layout).

* atomic: written to ``step_<N>.tmp`` then renamed, so a crash mid-save never
  corrupts the latest checkpoint (restart-safety for the training loop);
* elastic: blobs store the *global* array -- restore accepts arbitrary target
  shardings (``jax.device_put`` reshards), so the same checkpoint restores
  onto a different mesh shape or replica count;
* integrity: per-blob crc32 checked on restore.
"""
from __future__ import annotations

import os
import re
import shutil
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: fall back to stdlib zlib when zstandard is absent
    import zstandard as zstd
except ImportError:  # pragma: no cover - depends on environment
    zstd = None

_SEP = "/"


def _compress(data: bytes, cctx) -> tuple[bytes, str]:
    """Returns (blob, codec).  ``cctx``: one ZstdCompressor per checkpoint
    (zstd contexts are not safe to share across concurrent saves), or None
    to fall back to zlib."""
    if cctx is not None:
        return cctx.compress(data), "zstd"
    return zlib.compress(data, level=6), "zlib"


def _decompress(blob: bytes, codec: str, dctx) -> bytes:
    if codec == "zstd":
        if dctx is None:
            raise ImportError(
                "checkpoint was written with zstd but zstandard is not "
                "installed")
        return dctx.decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Blocking sharded save; returns the final step directory."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    cctx = zstd.ZstdCompressor(level=3) if zstd is not None else None
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        blob, codec = _compress(arr.tobytes(order="C"), cctx)
        ext = ".zst" if codec == "zstd" else ".zz"
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ext
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(blob)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(blob) & 0xFFFFFFFF, "codec": codec,
        }
    with open(os.path.join(tmp, "MANIFEST.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", name))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (tree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of shardings
    for elastic placement on a (possibly different) mesh."""
    base = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(base, "MANIFEST.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    dctx = zstd.ZstdDecompressor() if zstd is not None else None
    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, want in flat_target.items():
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint {base} missing leaf {key!r}")
        with open(os.path.join(base, meta["file"]), "rb") as f:
            blob = f.read()
        if (zlib.crc32(blob) & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {key!r}")
        arr = np.frombuffer(
            _decompress(blob, meta.get("codec", "zstd"), dctx),
            dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key!r}: shape {arr.shape} != {want.shape}")
        sh = flat_shard.get(key)
        out[key] = (jax.device_put(arr, sh) if sh is not None
                    else jnp.asarray(arr))
    # unflatten back into target's structure
    leaves_with_path = jax.tree_util.tree_flatten_with_path(target)
    keys_in_order = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in path)
                     for path, _ in leaves_with_path[0]]
    return jax.tree_util.tree_unflatten(
        leaves_with_path[1], [out[k] for k in keys_in_order])


class CheckpointManager:
    """Keep-last-k rotation + best-effort async save via a worker thread."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        # materialize on host before handing to the thread (device buffers may
        # be donated by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self._thread is not None:
            self._thread.join()
        if self.async_save:
            import threading
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree, extra))
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree, extra)

    def _save_and_gc(self, step, tree, extra):
        save_checkpoint(self.directory, step, tree, extra)
        steps = sorted(int(m.group(1)) for n in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", n)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, target: Any, shardings: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, target, shardings)
