"""Sharded checkpointing: msgpack manifest + zstd-compressed per-leaf blobs,
atomic step directories, and elastic restore (load onto a different mesh /
shardings than the save used)."""
from .store import (CheckpointManager, latest_step, restore_checkpoint,
                    save_checkpoint)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
