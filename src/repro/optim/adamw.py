"""AdamW (decoupled weight decay) with f32 moments over a pytree of params.

State mirrors the param tree, so the optimizer state inherits the param
shardings (ZeRO-style: moments are sharded exactly like their parameters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state, params, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = warmup_cosine(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


def opt_state_specs(param_spec_tree) -> Dict[str, Any]:
    """Optimizer-state logical specs mirror the parameter specs."""
    return {"mu": param_spec_tree, "nu": param_spec_tree, "step": ()}
