"""Error-feedback int8 gradient compression for the cross-pod hop.

The multi-pod mesh reduces gradients over the ``pod`` axis across the
data-center interconnect (DCI), which is an order of magnitude slower than
intra-pod ICI.  Quantizing that one hop to int8 with an error-feedback
residual (so quantization error is re-injected next step and the compression
is unbiased over time) cuts cross-pod gradient bytes by 4x at negligible
quality cost.

``ef_int8_psum`` is designed for use inside ``shard_map`` over the pod axis:
    g_local  (per-pod partial gradient)
    q, scale = quantize(g_local + residual)
    q_sum    = psum(q)   <- int8 wire format (simulated: int32 accumulation)
    g_hat    = dequant(q_sum)
    residual = (g_local + residual) - dequant(q)
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_int8_compress_state(params) -> Any:
    """Residual tree (zeros), one per parameter leaf."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_int8_psum(grads, residuals, axis_name: str):
    """Per-leaf int8 quantized psum over ``axis_name`` with error feedback.

    Returns (reduced_grads, new_residuals).  Scales are psum-maxed so every
    pod dequantizes with a common scale (one extra scalar per leaf).
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        # int8 on the wire; accumulate in int32 to avoid overflow
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        g_hat = qsum.astype(jnp.float32) * scale / n
        new_r = x - q.astype(jnp.float32) * scale
        return g_hat, new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])
