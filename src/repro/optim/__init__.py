"""Optimizer substrate: AdamW with global-norm clipping and warmup-cosine
schedule, plus error-feedback int8 gradient compression for the cross-pod
data-parallel hop."""
from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm, warmup_cosine
from .compress import ef_int8_compress_state, ef_int8_psum

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "warmup_cosine",
    "ef_int8_compress_state",
    "ef_int8_psum",
]
