"""Replica capacity from the compiled roofline (the paper's Fig.-10
calibration, TPU edition).

The paper measures a consumer's max throughput empirically (~2.3 MB/s) and
feeds it to the packer as the bin size C.  On the TPU serving fleet the
equivalent C is the decode throughput of one replica (mesh slice), which we
derive from the dry-run's compiled ``serve_step``: tokens/s = global_batch /
dominant roofline term (+ amortized flush for block-buffered decode).

``ControllerConfig(capacity=derived_replica_capacity(...)["tokens_per_s"])``
closes the loop: the packer sizes the fleet with a capacity that comes from
the same compiled artifact the dry-run validated.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

# repo root, resolved robustly from this file (src/repro/serving -> root)
# rather than left as a fragile relative join for open() to trip over
_REPO_ROOT = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)),
    os.pardir, os.pardir, os.pardir))
DEFAULT_RESULTS = os.path.join(_REPO_ROOT, "dryrun_results.jsonl")


def derived_replica_capacity(arch: str, shape: str = "decode_32k",
                             mesh: str = "16x16", rules: str = "baseline",
                             results_path: Optional[str] = None,
                             bytes_per_token: float = 4.0) -> Dict:
    path = os.path.abspath(results_path or DEFAULT_RESULTS)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no dry-run results at {path}. The replica capacity is derived "
            f"from the compiled roofline, so generate the file first with "
            f"the dry-run step:\n"
            f"  PYTHONPATH=src python -m repro.launch.dryrun "
            f"--arch {arch} --shape {shape} --out {path}\n"
            f"(writes one JSON line per arch/shape/mesh/rules cell), or pass "
            f"results_path= pointing at an existing dryrun_results.jsonl.")
    best = None
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if (r.get("arch") == arch and r.get("shape") == shape and
                    r.get("mesh") == mesh and
                    r.get("rules", "baseline") == rules and "roofline" in r):
                best = r
    if best is None:
        raise KeyError(f"no dry-run record for {arch}/{shape}/{mesh}/{rules}")
    rl = best["roofline"]
    step_s = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
    fl = best.get("flush_amortized")
    if fl:
        step_s += fl["t_memory_s"] + fl["t_collective_s"]
    # global_batch tokens are decoded per step across the whole mesh slice
    from repro.launch.shapes import SHAPES
    batch = SHAPES[shape].global_batch
    tok_s = batch / step_s
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "rules": rules,
        "step_seconds": step_s,
        "tokens_per_s": tok_s,
        "bytes_per_s": tok_s * bytes_per_token,
        "bottleneck": rl["bottleneck"],
    }
