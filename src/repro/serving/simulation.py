"""End-to-end autoscaling simulation: producers -> broker -> monitor ->
controller -> replica group (paper Fig. 3), on a simulated clock.

The workload is a per-partition byte-rate function; the driver ticks the
world forward, periodically sampling the monitor and stepping the controller
and replicas, while recording the metrics the paper reports (consumer count,
Rscore per reassignment, consumer-group lag).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.broker import Broker, SimClock, TopicPartition
from repro.core.controller import Controller, ControllerConfig
from repro.core.monitor import Monitor

from .manager import SimulatedReplicaManager
from .replica import ReplicaConfig, Sink

RateFn = Callable[[TopicPartition, float], float]


@dataclasses.dataclass
class SimMetrics:
    times: List[float] = dataclasses.field(default_factory=list)
    n_replicas: List[int] = dataclasses.field(default_factory=list)
    lag_bytes: List[int] = dataclasses.field(default_factory=list)
    produced: List[int] = dataclasses.field(default_factory=list)
    consumed: List[int] = dataclasses.field(default_factory=list)

    def as_arrays(self):
        return {k: np.asarray(v) for k, v in dataclasses.asdict(self).items()}


class AutoscaleSimulation:
    """Closed-loop world simulation.

    Randomness: ``seed`` drives only this object's producer-side jitter rng
    (``rate_jitter`` below); deterministic workloads (``constant_rates``, or
    any jitter-free ``rate_fn``) are unaffected by it.  Stochastic rate
    functions such as ``random_walk_rates`` carry their *own* seed argument
    -- pass it there, not here.
    """

    def __init__(
        self,
        n_partitions: int,
        rate_fn: RateFn,
        capacity: float = 2.3e6,            # the paper's measured 2.3 MB/s
        algorithm: str = "MBFP",
        topic: str = "sensors",
        record_bytes: int = 512,
        monitor_interval: float = 5.0,
        heartbeat_timeout: float = 30.0,
        min_reassign_interval: float = 0.0,
        overload_factor: float = 1.0,
        seed: int = 0,
        rate_jitter: float = 0.0,           # +-fraction of rate, from ``seed``
    ):
        self.clock = SimClock()
        self.broker = Broker(self.clock)
        self.topic = topic
        self.n_partitions = n_partitions
        self.broker.create_topic(topic, n_partitions)
        self.rate_fn = rate_fn
        self.record_bytes = record_bytes
        self.monitor = Monitor(self.broker, [topic])
        self.sink = Sink()
        self.replica_cfg = ReplicaConfig(rate=capacity)
        self.manager = SimulatedReplicaManager(self.broker, self.sink, self.replica_cfg)
        self.controller = Controller(
            self.broker, self.manager,
            ControllerConfig(capacity=capacity, algorithm=algorithm,
                             heartbeat_timeout=heartbeat_timeout,
                             min_reassign_interval=min_reassign_interval,
                             overload_factor=overload_factor))
        self.monitor_interval = monitor_interval
        self._accum: Dict[int, float] = {i: 0.0 for i in range(n_partitions)}
        self._next_monitor = 0.0
        self.metrics = SimMetrics()
        self.rng = np.random.default_rng(seed)
        self.rate_jitter = float(rate_jitter)
        self.produced_bytes = 0

    # ------------------------------------------------------------------ tick
    def _produce(self, dt: float) -> None:
        t = self.clock.now()
        jitter = (1.0 + self.rate_jitter *
                  self.rng.uniform(-1.0, 1.0, self.n_partitions)
                  if self.rate_jitter else None)
        for i in range(self.n_partitions):
            tp = TopicPartition(self.topic, i)
            rate = max(0.0, self.rate_fn(tp, t))
            if jitter is not None:
                rate = max(0.0, rate * jitter[i])
            self._accum[i] += rate * dt
            while self._accum[i] >= self.record_bytes:
                self.broker.produce(tp, value=b"x" * 0, nbytes=self.record_bytes)
                self._accum[i] -= self.record_bytes
                self.produced_bytes += self.record_bytes

    def tick(self, dt: float = 1.0) -> None:
        self._produce(dt)
        self.clock.advance(dt)
        if self.clock.now() >= self._next_monitor:
            m = self.monitor.sample()
            self.controller.observe_measurement(m.speeds)
            self._next_monitor = self.clock.now() + self.monitor_interval
        self.controller.run_once()
        consumed = self.manager.step_all(dt)
        self.controller.run_once()      # pick up acks promptly
        self.metrics.times.append(self.clock.now())
        self.metrics.n_replicas.append(self.manager.n_alive())
        self.metrics.lag_bytes.append(self.broker.total_lag("autoscaler", self.topic))
        self.metrics.produced.append(self.produced_bytes)
        self.metrics.consumed.append(consumed)

    def run(self, seconds: float, dt: float = 1.0) -> SimMetrics:
        steps = int(round(seconds / dt))
        for _ in range(steps):
            self.tick(dt)
        return self.metrics

    # ------------------------------------------------------------- scenarios
    @staticmethod
    def constant_rates(rates: Sequence[float]) -> RateFn:
        def fn(tp: TopicPartition, t: float) -> float:
            return rates[tp.partition]
        return fn

    @staticmethod
    def random_walk_rates(n: int, capacity: float, delta: float, seed: int = 0,
                          step_every: float = 5.0) -> RateFn:
        """Eq. 11 applied as a continuous workload."""
        rng = np.random.default_rng(seed)
        state = {"t": 0.0, "rates": rng.uniform(0, capacity, n)}

        def fn(tp: TopicPartition, t: float) -> float:
            while t >= state["t"] + step_every:
                state["rates"] = np.maximum(
                    0.0, state["rates"] + rng.uniform(-delta, delta, n) / 100.0 * capacity)
                state["t"] += step_every
            return float(state["rates"][tp.partition])
        return fn
