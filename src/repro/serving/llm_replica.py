"""LLM-serving replica: the paper's consumer whose "insert into data lake"
phase is replaced by actual batched token generation with a jitted
``serve_step`` -- request streams (partitions) in, generated tokens out.

Each record on a partition is one request: ``{"prompt": [ids], "gen": n}``.
The replica drains up to BATCH_BYTES of requests per cycle (phase 1), groups
them (phase 2), decodes them with the shared model (phase 3; real compute),
and processes its metadata mailbox / acks exactly like the base replica
(phase 4) -- so the controller, two-phase migration, and failure handling
are identical whether the payload is bytes or tokens.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.broker import Broker
from repro.models import (ArchConfig, init_decode_state, init_params,
                          serve_step)

from .replica import Replica, ReplicaConfig, Sink


class SharedModel:
    """One model + jitted step shared by all replicas in the demo process
    (on real hardware each replica owns a mesh slice; here they share the
    CPU device)."""

    def __init__(self, cfg: ArchConfig, max_len: int = 64, max_batch: int = 8,
                 seed: int = 0):
        self.cfg = cfg
        self.max_len = max_len
        self.max_batch = max_batch
        self.params = init_params(jax.random.key(seed), cfg)
        self._step = jax.jit(
            lambda p, s, b: serve_step(p, cfg, s, b))

    def generate(self, prompts: List[List[int]], gen: int) -> np.ndarray:
        """Greedy-decode ``gen`` tokens for up to max_batch prompts.  The
        batch is padded to max_batch so every call shares one jit signature."""
        bsz = len(prompts)
        state = init_decode_state(self.cfg, self.max_batch, self.max_len)
        # teacher-force the prompts token by token (prefill via decode path)
        maxp = max(len(p) for p in prompts)
        toks = np.zeros((self.max_batch, maxp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        logits = None
        for t in range(maxp):
            logits, state = self._step(self.params, state,
                                       {"inputs": jnp.asarray(toks[:, t])})
        out = np.zeros((self.max_batch, gen), np.int32)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for g in range(gen):
            out[:, g] = np.asarray(cur)
            logits, state = self._step(self.params, state, {"inputs": cur})
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return out[:bsz]


class LLMReplica(Replica):
    def __init__(self, cid: int, broker: Broker, sink: Sink,
                 config: Optional[ReplicaConfig], model: SharedModel):
        super().__init__(cid, broker, sink, config)
        self.model = model
        self.generated_tokens = 0
        self.requests_served = 0

    def step(self, dt: float) -> int:
        if not self.alive or self.crashed:
            return 0
        budget = self.cfg.rate * self.rate_factor * dt + self._carry
        fetch_cap = int(min(self.cfg.batch_bytes, budget))
        batches = self.handle.poll(fetch_cap) if fetch_cap > 0 else {}

        consumed = 0
        requests: List[List[int]] = []
        gen_n = 8
        for tp, recs in batches.items():
            for r in recs:
                req = json.loads(r.value) if isinstance(r.value, str) else r.value
                requests.append(list(req.get("prompt", [1])))
                gen_n = int(req.get("gen", 8))
                consumed += r.nbytes
        # phase 3: batched generation (chunks of the model's max batch)
        for i in range(0, len(requests), self.model.max_batch):
            chunk = requests[i:i + self.model.max_batch]
            out = self.model.generate(chunk, gen_n)
            self.generated_tokens += int(out.size)
            self.requests_served += len(chunk)
            self.sink.insert("generations", out.size * 4, len(chunk))
        for tp, recs in batches.items():
            self.handle.commit(tp, recs[-1].offset + 1)

        self._carry = min(budget - consumed, self.cfg.rate * self.rate_factor)
        self.consumed_bytes += consumed
        self.last_rate = consumed / dt if dt > 0 else 0.0
        self.backlog_hint = sum(self.broker.lag(self.cfg.group, tp)
                                for tp in self.handle.assigned)
        for msg in self._read_metadata():
            self._apply_metadata(msg)
        if self.alive:
            self._send({"type": "heartbeat",
                        "stats": {"rate": self.last_rate,
                                  "backlog": self.backlog_hint,
                                  "tokens": self.generated_tokens}})
        return consumed
