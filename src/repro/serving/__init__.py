"""Serving/consumption data plane: replicas (consumers), their lifecycle
manager, and the end-to-end autoscaling simulation (paper Secs. V-B/V-C)."""
from .manager import SimulatedReplicaManager
from .replica import Replica, ReplicaConfig, Sink
from .simulation import AutoscaleSimulation, SimMetrics

__all__ = [
    "SimulatedReplicaManager",
    "Replica",
    "ReplicaConfig",
    "Sink",
    "AutoscaleSimulation",
    "SimMetrics",
]
