"""Replica lifecycle management (the paper's Kubernetes deployments).

``SimulatedReplicaManager`` spawns in-process ``Replica`` objects; the
deployment "manifest name" is the replica's mailbox id, mirroring the paper's
``metadata.name`` trick.  On real infrastructure the same protocol would be
backed by the cluster API (one deployment per consumer).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Set

from repro.broker import Broker
from repro.core.controller import ReplicaManagerProtocol

from .replica import Replica, ReplicaConfig, Sink


class SimulatedReplicaManager(ReplicaManagerProtocol):
    def __init__(self, broker: Broker, sink: Optional[Sink] = None,
                 config: Optional[ReplicaConfig] = None,
                 replica_factory: Optional[Callable[[int], Replica]] = None):
        self.broker = broker
        self.sink = sink or Sink()
        self.config = config or ReplicaConfig()
        self.replicas: Dict[int, Replica] = {}
        self._factory = replica_factory
        self.created_total = 0
        self.deleted_total = 0

    def create(self, cid: int) -> None:
        existing = self.replicas.get(cid)
        if existing is not None and existing.alive and not existing.crashed:
            return
        if self._factory is not None:
            self.replicas[cid] = self._factory(cid)
        else:
            self.replicas[cid] = Replica(cid, self.broker, self.sink, self.config)
        self.created_total += 1

    def delete(self, cid: int) -> None:
        rep = self.replicas.pop(cid, None)
        if rep is not None:
            rep.alive = False
            self.deleted_total += 1

    def list(self) -> Set[int]:
        return {cid for cid, r in self.replicas.items() if r.alive}

    # -- simulation helpers -------------------------------------------------
    def step_all(self, dt: float) -> int:
        return sum(r.step(dt) for r in list(self.replicas.values()))

    def n_alive(self) -> int:
        return len(self.list())
