"""Consumer replica (paper Sec. V-B, Fig. 4).

Each replica cycles through four phases:

  1. fetch up to BATCH_BYTES from its assigned partitions (or give up after
     WAIT_TIME_SECS);
  2. process records, batching by topic (one destination table per topic);
  3. asynchronously insert each topic batch into the data lake (``Sink``);
  4. drain its metadata mailbox, apply state changes (start/stop/shutdown/
     report), persist its state, and ack to the controller.

In this container the replica is driven by a simulated clock: ``step(dt)``
performs one cycle with a byte budget ``rate * dt`` (the paper's consumer
works at a constant max rate C when saturated -- the SBSBP capacity
assumption, validated in their Fig. 10 and in our capacity-calibration
benchmark).  ``rate_factor`` < 1 models a straggler.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.broker import Broker, ConsumerHandle, TopicPartition
from repro.core.controller import CONTROLLER_INBOX, consumer_mailbox


class Sink:
    """Data-lake stand-in: one 'table' per topic."""

    def __init__(self):
        self.tables: Dict[str, int] = {}
        self.records: Dict[str, int] = {}

    def insert(self, topic: str, nbytes: int, nrecords: int) -> None:
        self.tables[topic] = self.tables.get(topic, 0) + nbytes
        self.records[topic] = self.records.get(topic, 0) + nrecords


@dataclasses.dataclass
class ReplicaConfig:
    batch_bytes: int = 1 << 20         # BATCH_BYTES
    wait_time_secs: float = 1.0        # WAIT_TIME_SECS
    rate: float = 2.3e6                # max consumption rate C (bytes/s)
    group: str = "autoscaler"


class Replica:
    def __init__(self, cid: int, broker: Broker, sink: Sink,
                 config: Optional[ReplicaConfig] = None, rate_factor: float = 1.0):
        self.cid = int(cid)
        self.broker = broker
        self.sink = sink
        self.cfg = config or ReplicaConfig()
        self.rate_factor = float(rate_factor)
        self.member = f"consumer-{self.cid}"
        self.handle: ConsumerHandle = broker.consumer(self.cfg.group, self.member)
        self.mailbox = consumer_mailbox(self.cid)
        self._meta_group = f"meta-{self.cid}"
        # A fresh incarnation must not replay state changes addressed to a
        # previous incarnation of this consumer id (stale start/stop would
        # break the single-reader invariant): seek the mailbox to latest.
        # The controller (re)sends everything relevant after creating us.
        broker.create_topic(self.mailbox.topic, 1)
        end = broker.partition(self.mailbox).end_offset
        broker.commit(self._meta_group, self.mailbox, end)
        self.alive = True
        self.crashed = False
        self._carry = 0.0              # unused byte budget carried across steps
        self.consumed_bytes = 0
        self.last_rate = 0.0
        self.backlog_hint = 0

    # ------------------------------------------------------------------ io
    def _send(self, msg: dict) -> None:
        msg = dict(msg, consumer=self.cid)
        raw = json.dumps(msg)
        self.broker.produce(CONTROLLER_INBOX, raw, nbytes=len(raw))

    def _read_metadata(self) -> List[dict]:
        part = self.broker.partition(self.mailbox)
        off = self.broker.committed(self._meta_group, self.mailbox)
        recs = part.read(off)
        if recs:
            self.broker.commit(self._meta_group, self.mailbox, recs[-1].offset + 1)
        return [json.loads(r.value) for r in recs]

    def persisted_metadata(self) -> str:
        return json.dumps({"consumer": self.cid,
                           "partitions": [[tp.topic, tp.partition]
                                          for tp in sorted(self.handle.assigned)]})

    # ---------------------------------------------------------------- cycle
    def step(self, dt: float) -> int:
        """One consumer cycle with a byte budget of rate*dt.  Returns bytes
        consumed."""
        if not self.alive or self.crashed:
            return 0
        budget = self.cfg.rate * self.rate_factor * dt + self._carry
        consumed = 0

        # phase 1: fetch up to BATCH_BYTES (bounded by the rate budget)
        fetch_cap = int(min(self.cfg.batch_bytes, budget))
        batches = self.handle.poll(fetch_cap) if fetch_cap > 0 else {}

        # phase 2: process + batch per topic (destination table per topic)
        per_topic: Dict[str, List] = {}
        for tp, recs in batches.items():
            per_topic.setdefault(tp.topic, []).extend(recs)

        # phase 3: async insert per topic table
        for topic, recs in per_topic.items():
            nbytes = sum(r.nbytes for r in recs)
            self.sink.insert(topic, nbytes, len(recs))
            consumed += nbytes
        # at-least-once: commit only after the sink accepted the batch
        for tp, recs in batches.items():
            self.handle.commit(tp, recs[-1].offset + 1)

        self._carry = min(budget - consumed, self.cfg.rate * self.rate_factor)
        self.consumed_bytes += consumed
        self.last_rate = consumed / dt if dt > 0 else 0.0
        self.backlog_hint = sum(self.broker.lag(self.cfg.group, tp)
                                for tp in self.handle.assigned)

        # phase 4: metadata queue -> update state, persist, ack
        for msg in self._read_metadata():
            self._apply_metadata(msg)

        if self.alive:
            self._send({"type": "heartbeat",
                        "stats": {"rate": self.last_rate,
                                  "backlog": self.backlog_hint,
                                  "capacity": self.cfg.rate * self.rate_factor}})
        return consumed

    def _apply_metadata(self, msg: dict) -> None:
        typ = msg["type"]
        if typ == "stop":
            tps = [TopicPartition(t, int(p)) for t, p in msg["partitions"]]
            for tp in tps:
                self.handle.unassign(tp)
            self.persisted_metadata()
            self._send({"type": "stopped",
                        "partitions": [[tp.topic, tp.partition] for tp in tps]})
        elif typ == "start":
            tps = [TopicPartition(t, int(p)) for t, p in msg["partitions"]]
            for tp in tps:
                self.handle.assign(tp)
            self.persisted_metadata()
            self._send({"type": "started",
                        "partitions": [[tp.topic, tp.partition] for tp in tps]})
        elif typ == "report_state":
            self._send({"type": "state_report",
                        "partitions": [[tp.topic, tp.partition]
                                       for tp in sorted(self.handle.assigned)]})
        elif typ == "shutdown":
            self.handle.close()
            self.alive = False
            self._send({"type": "shutdown_ack"})

    # ------------------------------------------------------------- failures
    def crash(self) -> None:
        """Hard failure: stops processing *without* releasing partitions --
        the controller must detect the missing heartbeats, expel the member
        via the group coordinator, and repack its partitions."""
        self.crashed = True
