"""whisper-large-v3 [audio]: enc-dec, 32+32L d_model=1280 20H d_ff=5120
vocab=51866 -- conv/mel frontend STUB (precomputed frame embeddings,
T_enc=1500) [arXiv:2212.04356; unverified]."""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    norm_type="layernorm", gated_mlp=False,
    encoder_decoder=True, n_encoder_layers=32, encoder_seq_len=1500,
    input_mode="embeddings",
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    norm_type="layernorm", gated_mlp=False,
    encoder_decoder=True, n_encoder_layers=2, encoder_seq_len=16,
    input_mode="embeddings", remat=False,
)
