"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Vision frontend is a STUB: inputs are precomputed patch embeddings
(B, S, d_model); M-RoPE sections (t,h,w) = (16, 24, 24) over head_dim/2=64.
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    input_mode="embeddings",
)

SMOKE = ArchConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    rope_theta=10_000.0, mrope_sections=(4, 2, 2),
    input_mode="embeddings", remat=False,
)
