"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 + 1 shared expert -- early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=True, n_experts=16, experts_per_token=1, n_shared_experts=1,
    moe_d_ff=8192, rope_theta=500_000.0,
)

SMOKE = ArchConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    moe=True, n_experts=4, experts_per_token=1, n_shared_experts=1,
    moe_d_ff=128, remat=False,
)
