"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 -- Mamba+attn 1:7 interleave (1 attention layer
per period of 8, offset 4), MoE every 2nd layer [arXiv:2403.19887; hf]."""
from repro.models import ArchConfig, MambaConfig

FULL = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    moe=True, n_experts=16, experts_per_token=2, moe_every=2,
    moe_d_ff=14336,
    attn_layer_period=8, attn_layer_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = ArchConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    moe=True, n_experts=4, experts_per_token=2, moe_every=2,
    moe_d_ff=128,
    attn_layer_period=4, attn_layer_offset=2,
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
    remat=False, mamba_chunk=8,
)
