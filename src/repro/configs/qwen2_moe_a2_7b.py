"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].
"""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    moe=True, n_experts=60, experts_per_token=4, n_shared_experts=4,
    moe_d_ff=1408, rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=256,
    moe=True, n_experts=6, experts_per_token=2, n_shared_experts=2,
    moe_d_ff=96, remat=False,
)
