"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite/granite-3.0-*; hf]."""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab_size=49155, rope_theta=10_000_000.0,
)

SMOKE = ArchConfig(
    name="granite-3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=256, remat=False,
)
