"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 --
Finch, data-dependent decay, head size 64 [arXiv:2404.05892; hf]."""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    rwkv=True, rwkv_head_size=64,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    rwkv=True, rwkv_head_size=16, remat=False,
)
