"""Architecture registry: one module per assigned architecture, each
exporting ``FULL`` (the exact published config) and ``SMOKE`` (a reduced
same-family config for CPU tests).  ``get(name)`` / ``list_archs()`` are the
public API; the launcher selects with ``--arch <id>``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ArchConfig

_ARCHS = [
    "qwen2_vl_72b",
    "llama4_scout_17b_a16e",
    "qwen2_moe_a2_7b",
    "granite_3_8b",
    "deepseek_67b",
    "olmo_1b",
    "qwen3_8b",
    "jamba_v0_1_52b",
    "rwkv6_3b",
    "whisper_large_v3",
]

ARCH_IDS = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-3-8b": "granite_3_8b",
    "deepseek-67b": "deepseek_67b",
    "olmo-1b": "olmo_1b",
    "qwen3-8b": "qwen3_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-large-v3": "whisper_large_v3",
}


def _module(name: str):
    mod = ARCH_IDS.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str, smoke: bool = False) -> ArchConfig:
    m = _module(name)
    return m.SMOKE if smoke else m.FULL


def list_archs() -> List[str]:
    return list(ARCH_IDS)
