"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304 --
non-parametric LayerNorm, tied embeddings [arXiv:2402.00838; hf]."""
from repro.models import ArchConfig

FULL = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50304,
    norm_type="nonparametric_ln", tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="olmo-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    norm_type="nonparametric_ln", tie_embeddings=True, remat=False,
)
