"""Mixture-of-Experts block: softmax top-k routing, shared experts, and a
sort-based capacity dispatch (static shapes, MXU-friendly batched expert
einsum, token dropping above capacity) -- the TPU-native formulation of
"send each token to its expert" (no ragged shapes, no host control flow).

Covers: qwen2-moe (60 routed top-4 + 4 shared), llama4-scout (16 routed
top-1 + 1 shared), jamba (16 routed top-2, MoE every 2nd layer).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ArchConfig, scaled_normal, split_keys
from .layers import apply_mlp, init_mlp, mlp_specs
from .sharding import rule_axis_size, shard


def init_moe(key, cfg: ArchConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.expert_ff, cfg.n_experts
    ks = split_keys(key, ["router", "wi", "wg", "wo", "shared"])
    p = {
        "router": scaled_normal(ks["router"], (d, e), d, jnp.float32),
        "wi": scaled_normal(ks["wi"], (e, d, f), d, cfg.pdtype),
        "wg": scaled_normal(ks["wg"], (e, d, f), d, cfg.pdtype),
        "wo": scaled_normal(ks["wo"], (e, f, d), f, cfg.pdtype),
    }
    if cfg.n_shared_experts > 0:
        shared_cfg_ff = cfg.n_shared_experts * cfg.expert_ff
        p["shared"] = init_mlp(ks["shared"], cfg, d_ff=shared_cfg_ff)
    return p


def moe_specs(cfg: ArchConfig) -> Dict:
    s = {
        "router": ("p_embed", None),
        "wi": ("p_experts", "p_embed", "p_ffn"),
        "wg": ("p_experts", "p_embed", "p_ffn"),
        "wo": ("p_experts", "p_ffn", "p_embed"),
    }
    if cfg.n_shared_experts > 0:
        s["shared"] = mlp_specs(cfg)
    return s


def _capacity(cfg: ArchConfig, group_tokens: int) -> int:
    """Per-dispatch-group expert capacity (group = one batch row)."""
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = int(cfg.capacity_factor * group_tokens * k / e)
    if group_tokens * k <= 128:          # decode-sized groups: no 128 padding
        return max(1, cap)
    return max(128, -(-cap // 128) * 128)  # 128-aligned (MXU + shardable)


def apply_moe(p: Dict, cfg: ArchConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (y, aux_loss).

    GROUPED sort-based dispatch: every batch row routes its own S*k
    (token, expert) entries -- top-k, per-row stable sort by expert id,
    per-row capacity ``cf * S * k / E``, batched gather into a
    (B, E, C, d) buffer, batched expert SwiGLU, gate-weighted combine.

    Keeping the dispatch *within* a batch row means all sorting/scatter
    stays local to the data shard that owns the row (no global argsort, no
    cross-shard scatter collectives), which is what makes MoE scale on the
    (pod, data, model) mesh; the hierarchical equivalent of per-device
    all-to-all dispatch in expert-parallel systems.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    if s == 1 and b > 1:
        # decode: regroup single-token rows into one dispatch group per data
        # shard so expert capacity amortizes over the local batch instead of
        # padding every token to a full expert row
        g_rows = next((g for g in (16, 8, 4, 2) if b % g == 0), 1)
        if g_rows > 1:
            y, aux = apply_moe(p, cfg, x.reshape(b // g_rows, g_rows, d))
            return y.reshape(b, s, d), aux
    n = s * k                                   # dispatch entries per row
    cap = _capacity(cfg, s)
    dt = cfg.adtype

    # --- routing (f32 for numerics) ---
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                     # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style; one-hot, no scatter)
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = jax.nn.one_hot(eidx, e, dtype=jnp.float32).mean(axis=(0, 1, 2))
    aux = e * jnp.sum(me * ce)

    # --- per-row sort-based dispatch (GATHER-ONLY for tensor data: the big
    # (.., d)-shaped tensors only move through take_along_axis; scatters
    # touch int32 index arrays, which keeps the XLA SPMD lowering local and
    # cheap on every backend) ---
    flat_e = eidx.reshape(b, n)
    order = jnp.argsort(flat_e, axis=1, stable=True)          # (B, n)
    inv_order = jnp.argsort(order, axis=1)                    # unsort perm
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position within the expert group = index - first occurrence index
    first_of = jax.vmap(lambda r: jnp.searchsorted(r, r, side="left"))(sorted_e)
    pos_in_grp = jnp.arange(n)[None, :] - first_of
    keep = pos_in_grp < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_grp, e * cap)  # (B, n)
    token_of = order // k                                     # (B, n)

    rows = jnp.arange(b)[:, None]
    # slot -> source token (int32 scatter; n = S*k entries, tiny)
    src_token = jnp.full((b, e * cap + 1), s, jnp.int32).at[rows, slot].set(
        token_of.astype(jnp.int32), mode="drop")
    x_pad = jnp.concatenate([x.astype(dt), jnp.zeros((b, 1, d), dt)], axis=1)
    buf = jnp.take_along_axis(x_pad, src_token[:, : e * cap, None], axis=1)
    buf = buf.reshape(b, e, cap, d)

    # --- expert compute: EP when the rules shard p_experts (pad E up to the
    # axis size; the sharding constraint below reshapes (data-local, E-repl)
    # -> (data-local, E-sharded), which GSPMD lowers to the dispatch
    # all-to-all), TP-ffn otherwise ---
    ep = rule_axis_size("p_experts")
    e_pad = -(-e // ep) * ep if ep > 1 else e
    wi, wg, wo = (p[k_].astype(dt) for k_ in ("wi", "wg", "wo"))
    if e_pad != e:
        padw = ((0, e_pad - e), (0, 0), (0, 0))
        wi, wg, wo = (jnp.pad(w_, padw) for w_ in (wi, wg, wo))
        buf = jnp.pad(buf, ((0, 0), (0, e_pad - e), (0, 0), (0, 0)))
    buf = shard(buf, "batch", "p_experts", "exp_cap", None)   # <- a2a in
    h = jnp.einsum("becd,edf->becf", buf, wi)
    g = jnp.einsum("becd,edf->becf", buf, wg)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    h = shard(h, "batch", "p_experts", "exp_cap", "ffn")
    y_e = jnp.einsum("becf,efd->becd", h, wo)
    y_e = shard(y_e, "batch", None, None, None)               # <- a2a out (local combine)
    if e_pad != e:
        y_e = y_e[:, :e]

    # --- combine: gather per entry, gate-weight, unsort, sum over k ---
    y_flat = jnp.concatenate([y_e.reshape(b, e * cap, d),
                              jnp.zeros((b, 1, d), dt)], axis=1)
    per_entry = jnp.take_along_axis(y_flat, slot[..., None], axis=1)  # (B,n,d)
    gate_sorted = jnp.take_along_axis(gate.reshape(b, n), order, axis=1)
    per_entry = per_entry * gate_sorted[..., None].astype(dt)
    per_entry = jnp.take_along_axis(per_entry, inv_order[..., None], axis=1)
    y = per_entry.reshape(b, s, k, d).sum(axis=2)
    y = shard(y, "batch", "seq_sp", None)     # back to the SP residual layout

    if cfg.n_shared_experts > 0:
        y = y + apply_mlp(p["shared"], cfg, x)   # dense shared expert stays SP
    return y, aux
