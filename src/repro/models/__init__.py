"""Model zoo: the 10 assigned architectures in pure JAX."""
from .base import ArchConfig, MambaConfig
from .sharding import axis_rules, logical_spec, shard, spec_tree_to_shardings
from .transformer import (decode_state_specs, forward, init_decode_state,
                          init_params, param_specs, serve_step)

__all__ = [
    "ArchConfig",
    "MambaConfig",
    "axis_rules",
    "logical_spec",
    "shard",
    "spec_tree_to_shardings",
    "decode_state_specs",
    "forward",
    "init_decode_state",
    "init_params",
    "param_specs",
    "serve_step",
]
