"""Mamba-1 block (used by jamba's 7-of-8 non-attention layers).

Selective SSM with input-dependent (dt, B, C); the recurrence

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

is evaluated chunkwise: sequential ``lax.scan`` over chunks of
``cfg.mamba_chunk`` steps, parallel associative scan within a chunk, so
peak memory is O(B * chunk * d_in * d_state) instead of O(B * S * ...).

Decode keeps a constant-size state (h, conv window) -- this is why the
hybrid/ssm archs run the 500k-token long-context shape.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import ArchConfig, MambaConfig, scaled_normal, split_keys
from .sharding import shard


def _mcfg(cfg: ArchConfig) -> MambaConfig:
    return cfg.mamba or MambaConfig()


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    m = _mcfg(cfg)
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or math.ceil(cfg.d_model / 16)
    return d_in, m.d_state, m.d_conv, dt_rank


def init_mamba(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    d_in, n, d_conv, dt_rank = _dims(cfg)
    ks = split_keys(key, ["in", "conv", "x", "dt", "out", "A"])
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    return {
        "w_in": scaled_normal(ks["in"], (d, 2 * d_in), d, cfg.pdtype),
        "conv": scaled_normal(ks["conv"], (d_conv, d_in), d_conv, cfg.pdtype),
        "conv_b": jnp.zeros((d_in,), cfg.pdtype),
        "w_x": scaled_normal(ks["x"], (d_in, dt_rank + 2 * n), d_in, cfg.pdtype),
        "w_dt": scaled_normal(ks["dt"], (dt_rank, d_in), dt_rank, cfg.pdtype),
        "dt_bias": jnp.full((d_in,), -4.6, cfg.pdtype),   # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(cfg.pdtype),
        "D": jnp.ones((d_in,), cfg.pdtype),
        "w_out": scaled_normal(ks["out"], (d_in, d), d_in, cfg.pdtype),
    }


def mamba_specs(cfg: ArchConfig) -> Dict:
    return {
        "w_in": ("p_embed", "p_ffn"),
        "conv": (None, "p_ffn"),
        "conv_b": ("p_ffn",),
        "w_x": ("p_ffn", None),
        "w_dt": (None, "p_ffn"),
        "dt_bias": ("p_ffn",),
        "A_log": ("p_ffn", None),
        "D": ("p_ffn",),
        "w_out": ("p_ffn", "p_embed"),
    }


def _ssm_chunk_scan(dA: jax.Array, dBx: jax.Array, h0: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Within-chunk associative scan of h_t = dA_t*h_{t-1} + dBx_t.

    dA/dBx: (B, c, d_in, n); h0: (B, d_in, n).  Returns (h_all, h_last).
    """
    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    a_all, b_all = lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = a_all * h0[:, None] + b_all
    return h_all, h_all[:, -1]


def _selective_ssm(p: Dict, cfg: ArchConfig, x: jax.Array, h0: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d_in) post-conv activations; h0: (B, d_in, n)."""
    b, s, d_in = x.shape
    _, n, _, dt_rank = _dims(cfg)
    c = min(cfg.mamba_chunk, s)
    n_chunks = -(-s // c)
    pad = n_chunks * c - s
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))

    proj = jnp.einsum("bsd,dr->bsr", xf, p["w_x"].astype(jnp.float32))
    dt_r, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_r,
                                    p["w_dt"].astype(jnp.float32))
                         + p["dt_bias"].astype(jnp.float32))      # (B,S,d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (d_in, n)
    dA = jnp.exp(dt[..., None] * A[None, None])                   # (B,S,d_in,n)
    dBx = dt[..., None] * B_[:, :, None, :] * xf[..., None]       # (B,S,d_in,n)

    dA_c = dA.reshape(b, n_chunks, c, d_in, n).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(b, n_chunks, c, d_in, n).transpose(1, 0, 2, 3, 4)
    C_c = C_.reshape(b, n_chunks, c, n).transpose(1, 0, 2, 3)

    def body(h, blk):
        dA_b, dBx_b, C_b = blk
        h_all, h_last = _ssm_chunk_scan(dA_b, dBx_b, h)
        y_b = jnp.einsum("bcdn,bcn->bcd", h_all, C_b)
        return h_last, y_b

    h_last, y = lax.scan(body, h0.astype(jnp.float32), (dA_c, dBx_c, C_c))
    y = y.transpose(1, 0, 2, 3).reshape(b, n_chunks * c, d_in)[:, :s]
    y = y + xf[:, :s] * p["D"].astype(jnp.float32)
    return y.astype(x.dtype), h_last


def _causal_conv(p: Dict, x: jax.Array, ctx: Optional[jax.Array] = None
                 ) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, d_in); ctx: (B, d_conv-1, d_in)
    carried context for decode (zeros for a fresh sequence)."""
    w = p["conv"].astype(jnp.float32)                 # (d_conv, d_in)
    d_conv = w.shape[0]
    xf = x.astype(jnp.float32)
    if ctx is None:
        ctx = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), jnp.float32)
    xp = jnp.concatenate([ctx.astype(jnp.float32), xf], axis=1)
    out = sum(xp[:, i:i + xf.shape[1]] * w[i][None, None]
              for i in range(d_conv))
    return (out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)


def mamba_block(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba mixer.  x: (B, S, d)."""
    d_in, n, d_conv, _ = _dims(cfg)
    dt = cfg.adtype
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", None, "ffn")
    xs = jax.nn.silu(_causal_conv(p, xs).astype(jnp.float32)).astype(dt)
    h0 = jnp.zeros((x.shape[0], d_in, n), jnp.float32)
    y, _ = _selective_ssm(p, cfg, xs, h0)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt))


def init_mamba_state(cfg: ArchConfig, batch: int) -> Dict:
    d_in, n, d_conv, _ = _dims(cfg)
    return {"h": jnp.zeros((batch, d_in, n), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, d_in), cfg.adtype)}


def mamba_state_specs() -> Dict:
    return {"h": ("batch", "p_ffn", None), "conv": ("batch", None, "p_ffn")}


def mamba_decode_step(p: Dict, cfg: ArchConfig, x: jax.Array, state: Dict
                      ) -> Tuple[jax.Array, Dict]:
    """Single-token decode.  x: (B, 1, d)."""
    d_in, n, d_conv, _ = _dims(cfg)
    dt_ = cfg.adtype
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt_))
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_ctx = state["conv"]
    xs_c = _causal_conv(p, xs, ctx=conv_ctx)
    xs_act = jax.nn.silu(xs_c.astype(jnp.float32)).astype(dt_)
    new_conv = jnp.concatenate([conv_ctx[:, 1:], xs.astype(conv_ctx.dtype)],
                               axis=1) if d_conv > 1 else conv_ctx
    y, h_new = _selective_ssm(p, cfg, xs_act, state["h"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    return out, {"h": h_new, "conv": new_conv}
