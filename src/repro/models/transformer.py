"""Unified model assembly for all 10 architectures.

* dense / moe / vlm: homogeneous [attn + (mlp|moe)] blocks -> ``lax.scan``
  over stacked layer params (+ optional remat), so HLO size and compile time
  are independent of depth (95-layer deepseek compiles as fast as 16-layer
  olmo).
* hybrid (jamba): layers are stacked in *periods* of ``attn_layer_period``
  (8) -- scan over periods, an unrolled python loop over the 8 in-period
  sublayers (1 attention + 7 mamba; MoE on every 2nd layer).
* ssm (rwkv6): homogeneous [time-mix + channel-mix] scan.
* audio (whisper): encoder-decoder, see ``whisper.py``; dispatched here.

Public entry points: ``init_params`` / ``param_specs`` / ``forward`` (loss) /
``init_decode_state`` / ``decode_state_specs`` / ``serve_step``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (attention_block, attention_specs, decode_attention,
                        init_attention, init_kv_cache, kv_cache_specs)
from .base import ArchConfig, split_keys
from .layers import (apply_mlp, apply_norm, cross_entropy, embed_inputs,
                     embedding_specs, init_embedding, init_lm_head, init_mlp,
                     init_norm, lm_head_specs, logits_fn, mlp_specs,
                     norm_specs)
from .mamba import (init_mamba, init_mamba_state, mamba_block,
                    mamba_decode_step, mamba_specs, mamba_state_specs)
from .moe import apply_moe, init_moe, moe_specs
from .rwkv6 import (init_rwkv_channel_mix, init_rwkv_state, init_rwkv_time_mix,
                    rwkv_channel_mix, rwkv_channel_mix_specs, rwkv_state_specs,
                    rwkv_time_mix, rwkv_time_mix_specs)
from .sharding import shard

AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# per-layer init/specs
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _with_layer_dim(specs: Dict) -> Dict:
    """Prefix every leaf tuple with the stacked-layer dim (replicated)."""
    def f(leaf):
        return (None,) + leaf
    return jax.tree.map(f, specs, is_leaf=lambda x: isinstance(x, tuple))


def _dense_layer_init(cfg: ArchConfig, moe_layer: bool):
    def init(key):
        ks = split_keys(key, ["ln1", "attn", "ln2", "ffn"])
        p = {"ln1": init_norm(ks["ln1"], cfg),
             "attn": init_attention(ks["attn"], cfg),
             "ln2": init_norm(ks["ln2"], cfg)}
        p["ffn"] = init_moe(ks["ffn"], cfg) if moe_layer else init_mlp(ks["ffn"], cfg)
        return p
    return init


def _dense_layer_specs(cfg: ArchConfig, moe_layer: bool) -> Dict:
    return {"ln1": norm_specs(cfg), "attn": attention_specs(cfg),
            "ln2": norm_specs(cfg),
            "ffn": moe_specs(cfg) if moe_layer else mlp_specs(cfg)}


def _rwkv_layer_init(cfg: ArchConfig):
    def init(key):
        ks = split_keys(key, ["ln1", "tm", "ln2", "cm"])
        return {"ln1": init_norm(ks["ln1"], cfg),
                "tm": init_rwkv_time_mix(ks["tm"], cfg),
                "ln2": init_norm(ks["ln2"], cfg),
                "cm": init_rwkv_channel_mix(ks["cm"], cfg)}
    return init


def _rwkv_layer_specs(cfg: ArchConfig) -> Dict:
    return {"ln1": norm_specs(cfg), "tm": rwkv_time_mix_specs(cfg),
            "ln2": norm_specs(cfg), "cm": rwkv_channel_mix_specs(cfg)}


def _jamba_period_init(cfg: ArchConfig):
    """One period = ``attn_layer_period`` sublayers."""
    period = cfg.attn_layer_period

    def init(key):
        keys = jax.random.split(key, period)
        subs = []
        for j in range(period):
            ks = split_keys(keys[j], ["ln1", "mix", "ln2", "ffn"])
            p = {"ln1": init_norm(ks["ln1"], cfg), "ln2": init_norm(ks["ln2"], cfg)}
            p["mix"] = (init_attention(ks["mix"], cfg) if cfg.is_attn_layer(j)
                        else init_mamba(ks["mix"], cfg))
            p["ffn"] = (init_moe(ks["ffn"], cfg) if cfg.is_moe_layer(j)
                        else init_mlp(ks["ffn"], cfg))
            subs.append(p)
        return {f"sub{j}": subs[j] for j in range(period)}
    return init


def _jamba_period_specs(cfg: ArchConfig) -> Dict:
    period = cfg.attn_layer_period
    out = {}
    for j in range(period):
        s = {"ln1": norm_specs(cfg), "ln2": norm_specs(cfg)}
        s["mix"] = attention_specs(cfg) if cfg.is_attn_layer(j) else mamba_specs(cfg)
        s["ffn"] = moe_specs(cfg) if cfg.is_moe_layer(j) else mlp_specs(cfg)
        out[f"sub{j}"] = s
    return out


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> Dict:
    if cfg.encoder_decoder:
        from .whisper import init_whisper
        return init_whisper(key, cfg)
    ks = split_keys(key, ["embed", "layers", "final", "head"])
    p: Dict[str, Any] = {"embedding": init_embedding(ks["embed"], cfg)}
    if cfg.rwkv:
        p["layers"] = _stack_init(ks["layers"], cfg.n_layers, _rwkv_layer_init(cfg))
    elif cfg.attn_layer_period > 0:
        n_periods = cfg.n_layers // cfg.attn_layer_period
        p["layers"] = _stack_init(ks["layers"], n_periods, _jamba_period_init(cfg))
    else:
        moe_layer = cfg.moe and cfg.moe_every == 1
        if cfg.moe and cfg.moe_every != 1:
            raise NotImplementedError("interleaved MoE only via attn_layer_period")
        p["layers"] = _stack_init(ks["layers"], cfg.n_layers,
                                  _dense_layer_init(cfg, moe_layer))
    p["final_norm"] = init_norm(ks["final"], cfg)
    p["lm_head"] = init_lm_head(ks["head"], cfg)
    return p


def param_specs(cfg: ArchConfig) -> Dict:
    if cfg.encoder_decoder:
        from .whisper import whisper_specs
        return whisper_specs(cfg)
    if cfg.rwkv:
        layer = _rwkv_layer_specs(cfg)
    elif cfg.attn_layer_period > 0:
        layer = _jamba_period_specs(cfg)
    else:
        layer = _dense_layer_specs(cfg, cfg.moe and cfg.moe_every == 1)
    return {"embedding": embedding_specs(cfg),
            "layers": _with_layer_dim(layer),
            "final_norm": norm_specs(cfg),
            "lm_head": lm_head_specs(cfg)}


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block(lp: Dict, cfg: ArchConfig, moe_layer: bool,
                 x: jax.Array, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    h = apply_norm(lp["ln1"], cfg, x)
    x = x + attention_block(lp["attn"], cfg, h, positions)
    h = apply_norm(lp["ln2"], cfg, x)
    if moe_layer:
        y, aux = apply_moe(lp["ffn"], cfg, h)
    else:
        y, aux = apply_mlp(lp["ffn"], cfg, h), jnp.float32(0.0)
    x = shard(x + y, "batch", "seq_sp", None)
    return x, aux


def _jamba_period_block(pp: Dict, cfg: ArchConfig, x: jax.Array,
                        positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    aux_total = jnp.float32(0.0)
    for j in range(cfg.attn_layer_period):
        lp = pp[f"sub{j}"]
        h = apply_norm(lp["ln1"], cfg, x)
        if cfg.is_attn_layer(j):
            x = x + attention_block(lp["mix"], cfg, h, positions)
        else:
            x = x + mamba_block(lp["mix"], cfg, h)
        h = apply_norm(lp["ln2"], cfg, x)
        if cfg.is_moe_layer(j):
            y, aux = apply_moe(lp["ffn"], cfg, h)
            aux_total = aux_total + aux
        else:
            y = apply_mlp(lp["ffn"], cfg, h)
        x = shard(x + y, "batch", "seq_sp", None)
    return x, aux_total


def _rwkv_block(lp: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    h = apply_norm(lp["ln1"], cfg, x)
    y, _ = rwkv_time_mix(lp["tm"], cfg, h)
    x = x + y
    h = apply_norm(lp["ln2"], cfg, x)
    y, _ = rwkv_channel_mix(lp["cm"], cfg, h)
    return shard(x + y, "batch", "seq_sp", None)


def backbone(params: Dict, cfg: ArchConfig, x: jax.Array,
             positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Token embeddings -> final norm output.  Returns (hidden, aux_loss)."""
    if cfg.rwkv:
        def body(carry, lp):
            return _rwkv_block(lp, cfg, carry), jnp.float32(0.0)
    elif cfg.attn_layer_period > 0:
        def body(carry, lp):
            return _jamba_period_block(lp, cfg, carry, positions)
    else:
        moe_layer = cfg.moe and cfg.moe_every == 1

        def body(carry, lp):
            return _dense_block(lp, cfg, moe_layer, carry, positions)

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = lax.scan(body, x, params["layers"])
    x = apply_norm(params["final_norm"], cfg, x)
    return x, jnp.sum(aux)


def forward(params: Dict, cfg: ArchConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Training loss.  batch: inputs (tokens (B,S) or embeddings (B,S,d)),
    labels (B,S), optional positions ((B,S) or (3,B,S) for M-RoPE)."""
    if cfg.encoder_decoder:
        from .whisper import whisper_forward
        return whisper_forward(params, cfg, batch)
    inputs = batch["inputs"]
    bsz, seq = (inputs.shape[0], inputs.shape[1])
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(seq)[None, :], (bsz, seq))
    x = embed_inputs(params["embedding"], cfg, inputs)
    h, aux = backbone(params, cfg, x, positions)
    logits = logits_fn(params, cfg, h)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = loss + AUX_LOSS_COEF * aux
    return total, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    """Decode-state pytree sized for a cache of ``max_len`` tokens."""
    if cfg.encoder_decoder:
        from .whisper import init_whisper_decode_state
        return init_whisper_decode_state(cfg, batch, max_len)
    state: Dict[str, Any] = {"cache_len": jnp.zeros((), jnp.int32)}
    if cfg.rwkv:
        state["rwkv"] = jax.vmap(lambda _: init_rwkv_state(cfg, batch))(
            jnp.arange(cfg.n_layers))
    elif cfg.attn_layer_period > 0:
        n_periods = cfg.n_layers // cfg.attn_layer_period
        n_mamba = cfg.attn_layer_period - 1
        state["kv"] = init_kv_cache(cfg, batch, max_len, n_layers=n_periods)
        state["mamba"] = jax.vmap(lambda _: jax.vmap(
            lambda __: init_mamba_state(cfg, batch))(jnp.arange(n_mamba)))(
            jnp.arange(n_periods))
    else:
        state["kv"] = init_kv_cache(cfg, batch, max_len)
        if cfg.decode_tail_window > 0:
            from .attention import init_kv_tail
            state["tail"] = init_kv_tail(cfg, batch, cfg.decode_tail_window)
    return state


def decode_state_specs(cfg: ArchConfig) -> Dict:
    if cfg.encoder_decoder:
        from .whisper import whisper_decode_state_specs
        return whisper_decode_state_specs(cfg)
    specs: Dict[str, Any] = {"cache_len": ()}
    if cfg.rwkv:
        specs["rwkv"] = _with_layer_dim(rwkv_state_specs())
    elif cfg.attn_layer_period > 0:
        # kv_cache_specs already carries the stacked-layer dim
        specs["kv"] = kv_cache_specs()
        specs["mamba"] = _with_layer_dim(_with_layer_dim(mamba_state_specs()))
    else:
        specs["kv"] = kv_cache_specs()
        if cfg.decode_tail_window > 0:
            from .attention import kv_tail_specs
            specs["tail"] = kv_tail_specs()
    return specs


def serve_step(params: Dict, cfg: ArchConfig, state: Dict, batch: Dict
               ) -> Tuple[jax.Array, Dict]:
    """One decode step: new token (B,) or embedding (B,1,d) -> logits (B,V).

    The KV cache holds ``state["cache_len"]`` tokens; the step appends one.
    """
    if cfg.encoder_decoder:
        from .whisper import whisper_serve_step
        return whisper_serve_step(params, cfg, state, batch)
    inputs = batch["inputs"]
    if cfg.input_mode == "tokens" and inputs.ndim == 1:
        inputs = inputs[:, None]
    x = embed_inputs(params["embedding"], cfg, inputs)       # (B, 1, d)
    bsz = x.shape[0]
    clen = state["cache_len"]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(clen[None, None], (bsz, 1)).astype(jnp.int32)

    new_state: Dict[str, Any] = {"cache_len": clen + 1}

    if cfg.rwkv:
        def body(carry, xs):
            lp, st = xs
            h = apply_norm(lp["ln1"], cfg, carry)
            y, tm_state = rwkv_time_mix(lp["tm"], cfg, h,
                                        {"shift": st["tm_shift"], "wkv": st["wkv"]})
            carry = carry + y
            h = apply_norm(lp["ln2"], cfg, carry)
            y, cm_state = rwkv_channel_mix(lp["cm"], cfg, h,
                                           {"shift": st["cm_shift"]})
            carry = carry + y
            return carry, {"tm_shift": tm_state["shift"], "wkv": tm_state["wkv"],
                           "cm_shift": cm_state["shift"]}
        x, rwkv_state = lax.scan(body, x, (params["layers"], state["rwkv"]))
        new_state["rwkv"] = rwkv_state
    elif cfg.attn_layer_period > 0:
        def body(carry, xs):
            pp, kc, vc, mstates = xs
            midx = 0
            new_m = []
            for j in range(cfg.attn_layer_period):
                lp = pp[f"sub{j}"]
                h = apply_norm(lp["ln1"], cfg, carry)
                if cfg.is_attn_layer(j):
                    y, kc, vc = decode_attention(lp["mix"], cfg, h, kc, vc,
                                                 clen, positions)
                else:
                    st = jax.tree.map(lambda a: a[midx], mstates)
                    y, st2 = mamba_decode_step(lp["mix"], cfg, h, st)
                    new_m.append(st2)
                    midx += 1
                carry = carry + y
                h = apply_norm(lp["ln2"], cfg, carry)
                if cfg.is_moe_layer(j):
                    y, _ = apply_moe(lp["ffn"], cfg, h)
                else:
                    y = apply_mlp(lp["ffn"], cfg, h)
                carry = carry + y
            stacked_m = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            return carry, (kc, vc, stacked_m)
        x, (kc, vc, mstates) = lax.scan(
            body, x, (params["layers"], state["kv"]["k"], state["kv"]["v"],
                      state["mamba"]))
        new_state["kv"] = {"k": kc, "v": vc}
        new_state["mamba"] = mstates
    else:
        moe_layer = cfg.moe and cfg.moe_every == 1
        tailed = cfg.decode_tail_window > 0

        if tailed:
            from .attention import decode_attention_tailed

            def body(carry, xs):
                lp, kc, vc, tk, tv = xs
                h = apply_norm(lp["ln1"], cfg, carry)
                y, tk, tv = decode_attention_tailed(
                    lp["attn"], cfg, h, kc, vc, tk, tv, clen, positions)
                carry = carry + y
                h = apply_norm(lp["ln2"], cfg, carry)
                if moe_layer:
                    y, _ = apply_moe(lp["ffn"], cfg, h)
                else:
                    y = apply_mlp(lp["ffn"], cfg, h)
                carry = carry + y
                return carry, (tk, tv)
            x, (tk, tv) = lax.scan(
                body, x, (params["layers"], state["kv"]["k"],
                          state["kv"]["v"], state["tail"]["k"],
                          state["tail"]["v"]))
            new_state["kv"] = state["kv"]          # main written only by flush
            new_state["tail"] = {"k": tk, "v": tv}
        else:
            def body(carry, xs):
                lp, kc, vc = xs
                h = apply_norm(lp["ln1"], cfg, carry)
                y, kc, vc = decode_attention(lp["attn"], cfg, h, kc, vc, clen,
                                             positions)
                carry = carry + y
                h = apply_norm(lp["ln2"], cfg, carry)
                if moe_layer:
                    y, _ = apply_moe(lp["ffn"], cfg, h)
                else:
                    y = apply_mlp(lp["ffn"], cfg, h)
                carry = carry + y
                return carry, (kc, vc)
            x, (kc, vc) = lax.scan(body, x,
                                   (params["layers"], state["kv"]["k"],
                                    state["kv"]["v"]))
            new_state["kv"] = {"k": kc, "v": vc}

    h = apply_norm(params["final_norm"], cfg, x)
    logits = logits_fn(params, cfg, h)[:, 0, :]
    return logits, new_state
