"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Per head (size 64), the WKV state S in R^{hd x hd} evolves as

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (w_t data-dependent)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Attention-free: state is constant-size, so decode cost is O(1) per token and
the 500k long-context shape is natural.  The full-sequence path scans over
time in chunks (states carried across chunks; within a chunk the recurrence
is unrolled as a scan over steps on (B, H, hd, hd) states).

Token-shift low-rank interpolation (ddlerp) follows the Finch paper with a
single shared LoRA per projection set, kept small (rank 32).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import ArchConfig, scaled_normal, split_keys
from .sharding import shard

LORA_RANK = 32


def _dims(cfg: ArchConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_size
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init_rwkv_time_mix(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    h, hd = _dims(cfg)
    ks = split_keys(key, ["r", "k", "v", "g", "o", "w1", "w2", "mix"])
    return {
        "w_r": scaled_normal(ks["r"], (d, d), d, cfg.pdtype),
        "w_k": scaled_normal(ks["k"], (d, d), d, cfg.pdtype),
        "w_v": scaled_normal(ks["v"], (d, d), d, cfg.pdtype),
        "w_g": scaled_normal(ks["g"], (d, d), d, cfg.pdtype),
        "w_o": scaled_normal(ks["o"], (d, d), d, cfg.pdtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x W1) W2))
        "decay_w0": jnp.full((d,), -6.0, cfg.pdtype),
        "decay_w1": scaled_normal(ks["w1"], (d, LORA_RANK), d, cfg.pdtype),
        "decay_w2": scaled_normal(ks["w2"], (LORA_RANK, d), LORA_RANK, cfg.pdtype),
        "bonus_u": jnp.zeros((h, hd), cfg.pdtype),
        "mix": jax.random.uniform(ks["mix"], (5, d), cfg.pdtype, 0.0, 1.0),
        "ln_x": jnp.ones((d,), cfg.pdtype),
    }


def rwkv_time_mix_specs(cfg: ArchConfig) -> Dict:
    return {
        "w_r": ("p_embed", "p_ffn"), "w_k": ("p_embed", "p_ffn"),
        "w_v": ("p_embed", "p_ffn"), "w_g": ("p_embed", "p_ffn"),
        "w_o": ("p_ffn", "p_embed"),
        "decay_w0": (None,), "decay_w1": ("p_embed", None),
        "decay_w2": (None, None), "bonus_u": ("p_heads", None),
        "mix": (None, None), "ln_x": (None,),
    }


def init_rwkv_channel_mix(key, cfg: ArchConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, ["k", "v", "r", "mix"])
    return {
        "w_k": scaled_normal(ks["k"], (d, f), d, cfg.pdtype),
        "w_v": scaled_normal(ks["v"], (f, d), f, cfg.pdtype),
        "w_r": scaled_normal(ks["r"], (d, d), d, cfg.pdtype),
        "mix": jax.random.uniform(ks["mix"], (2, d), cfg.pdtype, 0.0, 1.0),
    }


def rwkv_channel_mix_specs(cfg: ArchConfig) -> Dict:
    return {"w_k": ("p_embed", "p_ffn"), "w_v": ("p_ffn", "p_embed"),
            "w_r": ("p_embed", "p_embed"), "mix": (None, None)}


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """shifted[t] = x[t-1]; position 0 takes ``last`` (decode carry)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v: (B,T,H,hd); w: (B,T,H,hd) decay in (0,1); s0: (B,H,hd,hd).

    Returns (out (B,T,H,hd), s_last).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)     # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, out

    rt = jnp.moveaxis(r, 1, 0)
    kt = jnp.moveaxis(k, 1, 0)
    vt = jnp.moveaxis(v, 1, 0)
    wt = jnp.moveaxis(w, 1, 0)
    s_last, out = lax.scan(step, s0, (rt, kt, vt, wt))
    return jnp.moveaxis(out, 0, 1), s_last


def rwkv_time_mix(p: Dict, cfg: ArchConfig, x: jax.Array,
                  state: Dict | None = None) -> Tuple[jax.Array, Dict]:
    """x: (B, T, d).  state: {"shift": (B,d), "wkv": (B,H,hd,hd)} or None."""
    b, t, d = x.shape
    h, hd = _dims(cfg)
    f32 = jnp.float32
    if state is None:
        state = {"shift": jnp.zeros((b, d), x.dtype),
                 "wkv": jnp.zeros((b, h, hd, hd), f32)}
    xs = _token_shift(x, state["shift"])
    mix = p["mix"].astype(x.dtype)                      # (5, d)
    xr, xk, xv, xg, xw = [x + (xs - x) * mix[i] for i in range(5)]

    dt = cfg.adtype
    r = jnp.einsum("btd,de->bte", xr, p["w_r"].astype(dt))
    k = jnp.einsum("btd,de->bte", xk, p["w_k"].astype(dt))
    v = jnp.einsum("btd,de->bte", xv, p["w_v"].astype(dt))
    g = jnp.einsum("btd,de->bte", xg, p["w_g"].astype(dt))
    # data-dependent decay (f32; exp(-exp(.)) in (0,1))
    lo = jnp.tanh(jnp.einsum("btd,dr->btr", xw.astype(f32),
                             p["decay_w1"].astype(f32)))
    wlog = p["decay_w0"].astype(f32) + jnp.einsum(
        "btr,rd->btd", lo, p["decay_w2"].astype(f32))
    w = jnp.exp(-jnp.exp(wlog))

    shp = (b, t, h, hd)
    rf, kf, vf = (z.astype(f32).reshape(shp) for z in (r, k, v))
    wf = w.reshape(shp)
    uf = p["bonus_u"].astype(f32)
    if cfg.wkv_impl == "kernel_stub":
        # traffic-equivalent stand-in for the Pallas WKV kernel: one pass
        # over the four streams, output stream written once, state carried
        # in VMEM (so it never appears as per-step HBM traffic).  The real
        # kernel (kernels/rwkv6_scan.py) computes the exact recurrence and
        # is validated against _wkv_scan in tests/test_kernels.py.
        out = rf * (kf * vf + uf[None, None] * wf)
        s_last = state["wkv"] + jnp.einsum("bhk,bhv->bhkv", kf[:, -1], vf[:, -1])
    else:
        out, s_last = _wkv_scan(rf, kf, vf, wf, uf, state["wkv"])
    out = out.reshape(b, t, d)
    # groupnorm-ish per-head ln_x then gate
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mean) * lax.rsqrt(var + 1e-5) * p["ln_x"].astype(f32)
    out = out.astype(dt) * jax.nn.silu(g.astype(f32)).astype(dt)
    y = jnp.einsum("bte,ed->btd", out, p["w_o"].astype(dt))
    new_state = {"shift": x[:, -1, :], "wkv": s_last}
    return shard(y, "batch", "seq_sp", None), new_state


def rwkv_channel_mix(p: Dict, cfg: ArchConfig, x: jax.Array,
                     state: Dict | None = None) -> Tuple[jax.Array, Dict]:
    b, t, d = x.shape
    if state is None:
        state = {"shift": jnp.zeros((b, d), x.dtype)}
    xs = _token_shift(x, state["shift"])
    mix = p["mix"].astype(x.dtype)
    xk = x + (xs - x) * mix[0]
    xr = x + (xs - x) * mix[1]
    dt = cfg.adtype
    k = jnp.einsum("btd,df->btf", xk, p["w_k"].astype(dt))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(dt)
    k = shard(k, "batch", None, "ffn")
    v = jnp.einsum("btf,fd->btd", k, p["w_v"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr,
                                  p["w_r"].astype(dt)).astype(jnp.float32))
    y = v * r.astype(dt)
    return y, {"shift": x[:, -1, :]}


def init_rwkv_state(cfg: ArchConfig, batch: int) -> Dict:
    h, hd = _dims(cfg)
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), cfg.adtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "cm_shift": jnp.zeros((batch, cfg.d_model), cfg.adtype),
    }


def rwkv_state_specs() -> Dict:
    return {"tm_shift": ("batch", None), "wkv": ("batch", "p_heads", None, None),
            "cm_shift": ("batch", None)}
