"""Shared building blocks: norms, RoPE/M-RoPE, MLPs, embeddings, loss."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ArchConfig, scaled_normal, split_keys
from .sharding import shard

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(key, cfg: ArchConfig, d: Optional[int] = None) -> Dict:
    d = d or cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), cfg.pdtype)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), cfg.pdtype),
                "bias": jnp.zeros((d,), cfg.pdtype)}
    if cfg.norm_type == "nonparametric_ln":   # olmo: no affine params
        return {}
    raise ValueError(cfg.norm_type)


def norm_specs(cfg: ArchConfig) -> Dict:
    if cfg.norm_type == "rmsnorm":
        return {"scale": (None,)}
    if cfg.norm_type == "layernorm":
        return {"scale": (None,), "bias": (None,)}
    return {}


def apply_norm(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    if cfg.norm_type == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x: jax.Array, scale: jax.Array) -> jax.Array:
    """qk-norm (qwen3): RMS norm over head_dim."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE + qwen2-vl multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ArchConfig) -> jax.Array:
    hd = cfg.head_dim
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 frequency slots are split into
    ``mrope_sections`` (t, h, w); each section takes its angle from the
    corresponding position stream.  Text tokens have t==h==w, which makes
    M-RoPE degenerate to 1-D RoPE exactly as in the paper.
    """
    freqs = rope_freqs(cfg)                                    # (hd/2,)
    if positions.ndim == 3 and cfg.mrope_sections:
        sec = jnp.asarray(
            sum(([i] * s for i, s in enumerate(cfg.mrope_sections)), []),
            dtype=jnp.int32)                                   # (hd/2,)
        pos = positions.astype(jnp.float32)                    # (3, B, S)
        # angle per (B, S, hd/2): pick the stream of each frequency slot
        pos_sel = jnp.take(pos, sec, axis=0)                   # (hd/2, B, S)
        theta = jnp.einsum("fbs,f->bsf", pos_sel, freqs)       # (B, S, hd/2)
    else:
        if positions.ndim == 3:
            positions = positions[0]
        theta = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd/2)
    cos = jnp.cos(theta)[:, :, None, :]                        # (B, S, 1, hd/2)
    sin = jnp.sin(theta)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jax.Array:
    """Whisper-encoder style fixed sinusoids (T, d)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, ["wi", "wg", "wo"])
    p = {"wi": scaled_normal(ks["wi"], (d, f), d, cfg.pdtype),
         "wo": scaled_normal(ks["wo"], (f, d), f, cfg.pdtype)}
    if cfg.gated_mlp:
        p["wg"] = scaled_normal(ks["wg"], (d, f), d, cfg.pdtype)
    return p


def mlp_specs(cfg: ArchConfig) -> Dict:
    s = {"wi": ("p_embed", "p_ffn"), "wo": ("p_ffn", "p_embed")}
    if cfg.gated_mlp:
        s["wg"] = ("p_embed", "p_ffn")
    return s


def apply_mlp(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    dt = cfg.adtype
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    # Megatron-style: MLP intermediate is ffn-sharded (seq gathered here;
    # the residual stream outside stays sequence-sharded)
    h = shard(h, "batch", None, "ffn") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# embeddings + logits + loss
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ArchConfig) -> Dict:
    p = {}
    if cfg.input_mode == "tokens":
        p["table"] = scaled_normal(key, (cfg.vocab_size, cfg.d_model),
                                   cfg.d_model, cfg.pdtype)
    else:  # frontend stub: a projection adapter over precomputed embeddings
        p["adapter"] = scaled_normal(key, (cfg.d_model, cfg.d_model),
                                     cfg.d_model, cfg.pdtype)
    return p


def embedding_specs(cfg: ArchConfig) -> Dict:
    if cfg.input_mode == "tokens":
        return {"table": ("p_vocab", "p_embed")}
    return {"adapter": (None, "p_embed")}


def embed_inputs(p: Dict, cfg: ArchConfig, inputs: jax.Array) -> jax.Array:
    if cfg.input_mode == "tokens":
        x = jnp.take(p["table"].astype(cfg.adtype), inputs, axis=0)
    else:
        x = jnp.einsum("...d,de->...e", inputs.astype(cfg.adtype),
                       p["adapter"].astype(cfg.adtype))
    return shard(x, "batch", "seq_sp", None)


def init_lm_head(key, cfg: ArchConfig) -> Dict:
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        return {}
    return {"w": scaled_normal(key, (cfg.d_model, cfg.vocab_size),
                               cfg.d_model, cfg.pdtype)}


def lm_head_specs(cfg: ArchConfig) -> Dict:
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        return {}
    return {"w": ("p_embed", "p_vocab")}


def logits_fn(params: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        w = params["embedding"]["table"].astype(cfg.adtype).T
    else:
        w = params["lm_head"]["w"].astype(cfg.adtype)
    logits = jnp.einsum("...d,dv->...v", x, w)
    if logits.ndim == 3:
        logits = shard(logits, "batch", "seq_sp", "vocab")
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean CE in f32 with stable logsumexp (vocab may be sharded)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
