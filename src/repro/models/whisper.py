"""Whisper-large-v3 backbone: encoder-decoder transformer.

Per the assignment, the conv/mel frontend is a STUB -- ``inputs`` are
precomputed frame embeddings (B, T_enc, d) passed through a linear adapter.
Encoder: bidirectional attention + sinusoidal positions.  Decoder: causal
self-attention + cross-attention to the encoder output, learned positions
(whisper's real table is 448 entries; longer assigned shapes clip into it --
they exercise sharding/caching, not speech modeling).  LayerNorm + GELU
(non-gated), pre-norm, as in the original.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (attention_block, attention_specs, decode_attention,
                        init_attention, init_kv_cache, kv_cache_specs)
from .base import ArchConfig, scaled_normal, split_keys
from .layers import (apply_mlp, apply_norm, cross_entropy, init_mlp, init_norm,
                     logits_fn, mlp_specs, norm_specs, sinusoidal_positions)
from .sharding import shard

WHISPER_MAX_TARGET_POSITIONS = 448


def _stack_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _enc_layer_init(cfg: ArchConfig):
    def init(key):
        ks = split_keys(key, ["ln1", "attn", "ln2", "mlp"])
        return {"ln1": init_norm(ks["ln1"], cfg),
                "attn": init_attention(ks["attn"], cfg),
                "ln2": init_norm(ks["ln2"], cfg),
                "mlp": init_mlp(ks["mlp"], cfg)}
    return init


def _dec_layer_init(cfg: ArchConfig):
    def init(key):
        ks = split_keys(key, ["ln1", "self", "ln2", "cross", "ln3", "mlp"])
        return {"ln1": init_norm(ks["ln1"], cfg),
                "self_attn": init_attention(ks["self"], cfg),
                "ln2": init_norm(ks["ln2"], cfg),
                "cross_attn": init_attention(ks["cross"], cfg),
                "ln3": init_norm(ks["ln3"], cfg),
                "mlp": init_mlp(ks["mlp"], cfg)}
    return init


def init_whisper(key, cfg: ArchConfig) -> Dict:
    ks = split_keys(key, ["adapter", "enc", "encn", "emb", "pos", "dec",
                          "decn", "head"])
    d = cfg.d_model
    return {
        "embedding": {"adapter": scaled_normal(ks["adapter"], (d, d), d,
                                               cfg.pdtype)},
        "enc_layers": _stack_init(ks["enc"], cfg.n_encoder_layers,
                                  _enc_layer_init(cfg)),
        "enc_norm": init_norm(ks["encn"], cfg),
        "dec_embed": scaled_normal(ks["emb"], (cfg.vocab_size, d), d, cfg.pdtype),
        "dec_pos": scaled_normal(ks["pos"], (WHISPER_MAX_TARGET_POSITIONS, d),
                                 d, cfg.pdtype),
        "layers": _stack_init(ks["dec"], cfg.n_layers, _dec_layer_init(cfg)),
        "final_norm": init_norm(ks["decn"], cfg),
        "lm_head": {"w": scaled_normal(ks["head"], (d, cfg.vocab_size), d,
                                       cfg.pdtype)},
    }


def whisper_specs(cfg: ArchConfig) -> Dict:
    def with_layer(s):
        return jax.tree.map(lambda t: (None,) + t, s,
                            is_leaf=lambda x: isinstance(x, tuple))
    enc_layer = {"ln1": norm_specs(cfg), "attn": attention_specs(cfg),
                 "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    dec_layer = {"ln1": norm_specs(cfg), "self_attn": attention_specs(cfg),
                 "ln2": norm_specs(cfg), "cross_attn": attention_specs(cfg),
                 "ln3": norm_specs(cfg), "mlp": mlp_specs(cfg)}
    return {
        "embedding": {"adapter": (None, "p_embed")},
        "enc_layers": with_layer(enc_layer),
        "enc_norm": norm_specs(cfg),
        "dec_embed": ("p_vocab", "p_embed"),
        "dec_pos": (None, "p_embed"),
        "layers": with_layer(dec_layer),
        "final_norm": norm_specs(cfg),
        "lm_head": {"w": ("p_embed", "p_vocab")},
    }


def encode(params: Dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, d) precomputed embeddings (frontend stub)."""
    dt = cfg.adtype
    x = jnp.einsum("btd,de->bte", frames.astype(dt),
                   params["embedding"]["adapter"].astype(dt))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    x = shard(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def body(carry, lp):
        h = apply_norm(lp["ln1"], cfg, carry)
        carry = carry + attention_block(lp["attn"], cfg, h, positions,
                                        causal=False)
        h = apply_norm(lp["ln2"], cfg, carry)
        carry = shard(carry + apply_mlp(lp["mlp"], cfg, h),
                      "batch", "seq_sp", None)
        return carry, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], cfg, x)


def _dec_embed(params: Dict, cfg: ArchConfig, tokens: jax.Array,
               positions: jax.Array) -> jax.Array:
    dt = cfg.adtype
    x = jnp.take(params["dec_embed"].astype(dt), tokens, axis=0)
    pos = jnp.clip(positions, 0, WHISPER_MAX_TARGET_POSITIONS - 1)
    x = x + jnp.take(params["dec_pos"].astype(dt), pos, axis=0)
    return shard(x, "batch", "seq_sp", None)


def whisper_forward(params: Dict, cfg: ArchConfig, batch: Dict
                    ) -> Tuple[jax.Array, Dict]:
    """Teacher-forced training step.

    batch: inputs (B, T_enc, d) frame embeddings; decoder_tokens (B, S);
    labels (B, S).
    """
    enc = encode(params, cfg, batch["inputs"])
    tokens = batch["decoder_tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _dec_embed(params, cfg, tokens, positions)

    def body(carry, lp):
        h = apply_norm(lp["ln1"], cfg, carry)
        carry = carry + attention_block(lp["self_attn"], cfg, h, positions)
        h = apply_norm(lp["ln2"], cfg, carry)
        carry = carry + _cross_attention(lp["cross_attn"], cfg, h, enc)
        h = apply_norm(lp["ln3"], cfg, carry)
        carry = shard(carry + apply_mlp(lp["mlp"], cfg, h),
                      "batch", "seq_sp", None)
        return carry, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["layers"])
    x = apply_norm(params["final_norm"], cfg, x)
    logits = logits_fn(params, cfg, x)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss, "aux": jnp.float32(0.0)}


def _cross_attention(p: Dict, cfg: ArchConfig, x: jax.Array, enc: jax.Array
                     ) -> jax.Array:
    """Full (non-causal) cross-attention; no RoPE (whisper uses none here)."""
    dt = cfg.adtype
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", enc, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc, p["wv"].astype(dt))
    g = cfg.n_heads // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s_ = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32) * hd ** -0.5,
                    k.astype(jnp.float32))
    w = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhst,bthk->bshk", w, v.astype(jnp.float32)).astype(dt)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_whisper_decode_state(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    L, T = cfg.n_layers, cfg.encoder_seq_len
    return {
        "cache_len": jnp.zeros((), jnp.int32),
        "kv": init_kv_cache(cfg, batch, max_len),
        "cross_k": jnp.zeros((L, batch, T, kv, hd), cfg.adtype),
        "cross_v": jnp.zeros((L, batch, T, kv, hd), cfg.adtype),
    }


def whisper_decode_state_specs(cfg: ArchConfig) -> Dict:
    return {
        "cache_len": (),
        "kv": kv_cache_specs(),     # already includes the stacked-layer dim
        "cross_k": (None, "batch", "cache_seq", "p_kv", None),
        "cross_v": (None, "batch", "cache_seq", "p_kv", None),
    }


def precompute_cross_kv(params: Dict, cfg: ArchConfig, enc: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Encoder output -> per-layer cross K/V, computed once per request."""
    dt = cfg.adtype

    def one(lp):
        k = jnp.einsum("btd,dhk->bthk", enc, lp["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", enc, lp["cross_attn"]["wv"].astype(dt))
        return k, v

    return jax.vmap(one)(params["layers"])


def whisper_serve_step(params: Dict, cfg: ArchConfig, state: Dict, batch: Dict
                       ) -> Tuple[jax.Array, Dict]:
    tokens = batch["inputs"]
    if tokens.ndim == 1:
        tokens = tokens[:, None]
    b = tokens.shape[0]
    clen = state["cache_len"]
    positions = jnp.broadcast_to(clen[None, None], (b, 1)).astype(jnp.int32)
    x = _dec_embed(params, cfg, tokens, positions)
    dt = cfg.adtype
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kv

    def body(carry, xs):
        lp, kc, vc, ck, cv = xs
        h = apply_norm(lp["ln1"], cfg, carry)
        y, kc, vc = decode_attention(lp["self_attn"], cfg, h, kc, vc, clen,
                                     positions)
        carry = carry + y
        # cross attention against precomputed K/V
        h = apply_norm(lp["ln2"], cfg, carry)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(dt))
        ck_e = jnp.repeat(ck, g, axis=2) if g > 1 else ck
        cv_e = jnp.repeat(cv, g, axis=2) if g > 1 else cv
        s_ = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32) * hd ** -0.5,
                        ck_e.astype(jnp.float32))
        w = jax.nn.softmax(s_, axis=-1)
        o = jnp.einsum("bhst,bthk->bshk", w, cv_e.astype(jnp.float32)).astype(dt)
        carry = carry + jnp.einsum("bshk,hkd->bsd", o,
                                   lp["cross_attn"]["wo"].astype(dt))
        h = apply_norm(lp["ln3"], cfg, carry)
        carry = carry + apply_mlp(lp["mlp"], cfg, h)
        return carry, (kc, vc)

    x, (kc, vc) = lax.scan(body, x, (params["layers"], state["kv"]["k"],
                                     state["kv"]["v"], state["cross_k"],
                                     state["cross_v"]))
    x = apply_norm(params["final_norm"], cfg, x)
    logits = logits_fn(params, cfg, x)[:, 0, :]
    new_state = dict(state, cache_len=clen + 1, kv={"k": kc, "v": vc})
    return logits, new_state
