"""Architecture configuration and parameter-initialization helpers.

One ``ArchConfig`` describes any of the 10 assigned architectures (dense /
MoE / hybrid Mamba+attention / RWKV / encoder-decoder).  Parameters are plain
pytrees (nested dicts of jnp arrays); every init function has a sibling
``*_specs`` returning the same tree shape with *logical axis names* per dim,
which the launcher resolves to mesh ``PartitionSpec``s (divisibility-checked)
-- see ``repro/launch/shardings.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None  # per-expert ffn width (defaults to d_ff)
    moe_every: int = 1              # MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # --- attention details ---
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t,h,w) sections
    # --- norms / mlp ---
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm | nonparametric_ln
    gated_mlp: bool = True          # SwiGLU if True else GELU MLP
    # --- hybrid (jamba) ---
    attn_layer_period: int = 0      # 0 = every layer is attention
    attn_layer_offset: int = 0
    mamba: Optional[MambaConfig] = None
    # --- rwkv ---
    rwkv: bool = False
    rwkv_head_size: int = 64
    # --- encoder-decoder (whisper) ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500     # precomputed frame embeddings
    # --- io ---
    input_mode: str = "tokens"      # tokens | embeddings (vlm/audio stub)
    tie_embeddings: bool = False
    # --- numerics ---
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"    # stored parameter dtype (bf16 for serving)
    remat: bool = True              # activation checkpointing across layers
    attn_chunk: int = 1024          # kv-block size of the online-softmax path
    mamba_chunk: int = 128
    use_pallas: bool = False        # TPU Pallas kernels (ref path if False)
    # roofline modeling of the Pallas WKV kernel: "scan" = jnp recurrence
    # (HBM state traffic every step); "kernel_stub" = stream-equivalent
    # elementwise stand-in whose HLO traffic matches the kernel (state lives
    # in VMEM; validated separately in interpret mode)
    wkv_impl: str = "scan"
    # decode: block-buffered KV writes -- new tokens go to a small
    # batch-sharded tail (local DUS); the sequence-sharded main cache is
    # only written by an amortized flush every `decode_tail_window` steps.
    # 0 = paper-baseline direct DUS into the sharded cache.
    decode_tail_window: int = 0

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def is_attn_layer(self, idx: int) -> bool:
        if self.attn_layer_period <= 0:
            return not self.rwkv
        return idx % self.attn_layer_period == self.attn_layer_offset

    def is_moe_layer(self, idx: int) -> bool:
        return self.moe and (idx % max(1, self.moe_every) == max(1, self.moe_every) - 1)

    def n_params(self) -> int:
        """Total parameter count (exact, from the param tree)."""
        from .transformer import init_params  # local import to avoid cycle
        shapes = jax.eval_shape(lambda k: init_params(k, self), jax.random.key(0))
        return int(sum(math.prod(x.shape) for x in jax.tree.leaves(shapes)))

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed-to experts count)."""
        total = self.n_params()
        if not self.moe:
            return total
        e, k = self.n_experts, self.experts_per_token
        # expert block params per MoE layer
        per_expert = 3 * self.d_model * self.expert_ff
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        inactive = n_moe_layers * per_expert * (e - k)
        return total - inactive


def scaled_normal(key, shape, scale_dim: int, dtype) -> jax.Array:
    """Truncated-normal init with 1/sqrt(fan_in) scale."""
    std = 1.0 / math.sqrt(max(1, scale_dim))
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std
            ).astype(dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))
