"""Logical-axis sharding: models annotate activations with logical names;
the launcher installs a rules table mapping logical names -> mesh axes.

Outside a rules context every annotation is a no-op, so smoke tests and
benchmarks on the single CPU device never touch device state.  Divisibility
is checked per annotation: a logical dim that does not divide over its mesh
axes silently falls back to replication (e.g. 8 kv heads over a 16-way model
axis, or 60 experts over 16).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, AxisSpec]]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, AxisSpec]):
    """Install (mesh, logical->mesh-axes) rules for model tracing."""
    prev = _current()
    _state.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.ctx = prev


def resolve_axis(name: Optional[str], dim: int,
                 mesh: Mesh, rules: Dict[str, AxisSpec]) -> AxisSpec:
    """Mesh axes for one logical dim, with divisibility fallback."""
    if name is None:
        return None
    axes = rules.get(name)
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if dim % total != 0:
        return None  # replicate rather than pad
    return axes if len(axes) > 1 else axes[0]


def logical_spec(names: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: Dict[str, AxisSpec]) -> P:
    assert len(names) == len(shape), (names, shape)
    out = []
    used: set = set()
    for n, d in zip(names, shape):
        axes = resolve_axis(n, d, mesh, rules)
        tup = (axes,) if isinstance(axes, str) else (axes or ())
        if any(a in used for a in tup):
            axes = None        # keep-first: a mesh axis shards at most one dim
        else:
            used.update(tup)
        out.append(axes)
    return P(*out)


def rule_axis_size(name: str) -> int:
    """Total mesh-axis size a logical name maps to (1 outside a context or
    when unmapped).  Lets modules adapt their structure to the rules (e.g.
    expert-parallel padding)."""
    ctx = _current()
    if ctx is None:
        return 1
    mesh, rules = ctx
    axes = rules.get(name)
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return total


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate activation ``x`` with logical axis names (no-op outside a
    rules context)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_tree_to_shardings(spec_tree, shape_tree, mesh: Mesh,
                           rules: Dict[str, AxisSpec]):
    """Resolve a tree of logical-name tuples against a matching tree of
    ShapeDtypeStructs into NamedShardings (for jit in_shardings)."""
    def one(names, sds):
        return NamedSharding(mesh, logical_spec(names, sds.shape, mesh, rules))
    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
