"""GQA attention: training/prefill (online-softmax over KV blocks, so 32k
sequences never materialize an S x S score matrix) and decode over a KV
cache (flash-decoding style -- the cache's sequence axis may be sharded
across the model axis; XLA inserts the distributed max/sum reductions).

The Pallas TPU kernels in ``repro/kernels`` implement the same math with
explicit VMEM tiling; ``cfg.use_pallas`` switches to them on TPU.  The
jnp path below is their oracle and the dry-run lowering path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .base import ArchConfig, scaled_normal, split_keys
from .layers import apply_rope, rms_norm_headwise
from .sharding import shard

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, ["wq", "wk", "wv", "wo", "qn", "kn"])
    p = {
        "wq": scaled_normal(ks["wq"], (d, h, hd), d, cfg.pdtype),
        "wk": scaled_normal(ks["wk"], (d, kv, hd), d, cfg.pdtype),
        "wv": scaled_normal(ks["wv"], (d, kv, hd), d, cfg.pdtype),
        "wo": scaled_normal(ks["wo"], (h, hd, d), h * hd, cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    return p


def attention_specs(cfg: ArchConfig) -> Dict:
    s = {
        "wq": ("p_embed", "p_heads", None),
        "wk": ("p_embed", "p_kv", None),
        "wv": ("p_embed", "p_kv", None),
        "wo": ("p_heads", None, "p_embed"),
    }
    if cfg.qk_norm:
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    return s


def _qkv(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dt = cfg.adtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"])
        k = rms_norm_headwise(k, p["k_norm"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv", None)
    v = shard(v, "batch", None, "kv", None)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,kv,hd) -> (B,S,H,hd) by repeating each kv head H/kv times."""
    b, s, kv, hd = k.shape
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def online_softmax_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             cfg: ArchConfig, causal: bool = True,
                             q_offset: int = 0) -> jax.Array:
    """Blockwise attention with running (max, sum) state.

    q: (B, Sq, H, hd); k/v: (B, Skv, H, hd) [kv heads already expanded].
    Scans over KV blocks of ``cfg.attn_chunk`` so peak memory is
    O(Sq * block) instead of O(Sq * Skv).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    blk = min(cfg.attn_chunk, skv)
    n_blk = (skv + blk - 1) // blk
    pad = n_blk * blk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blk, blk, h, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blk, blk, h, hd).transpose(1, 0, 2, 3, 4)
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)  # MXU: bf16 in, f32 acc
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, blk_in):
        acc, m, l, i = carry
        kc, vc = blk_in                              # (B, blk, H, hd)
        s_ = jnp.einsum("bqhk,bjhk->bhqj", qf, kc,
                        preferred_element_type=jnp.float32)
        kv_pos = i * blk + jnp.arange(blk)
        if causal:
            mask = kv_pos[None, :] <= q_pos[:, None]
        else:
            mask = jnp.ones((sq, blk), bool)
        valid = (kv_pos < skv)[None, :]
        s_ = jnp.where((mask & valid)[None, None], s_, NEG_INF)
        m_new = jnp.maximum(m, s_.max(axis=-1))
        p_ = jnp.exp(s_ - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p_.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqj,bjhk->bhqk", p_.astype(q.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l_new, i + 1), None

    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l, _), _ = lax.scan(body, (acc0, m0, l0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)      # (B, Sq, H, hd)


def attention_block(p: Dict, cfg: ArchConfig, x: jax.Array,
                    positions: jax.Array, causal: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    q, k, v = _qkv(p, cfg, x, positions)
    if cfg.use_pallas:
        from repro.kernels.ops import flash_attention as _fa
        out = _fa(q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads),
                  causal=causal)
    else:
        out = online_softmax_attention(
            q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads),
            cfg, causal=causal)
    out = shard(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.adtype))
    return shard(y, "batch", "seq_sp", None)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None) -> Dict:
    """KV cache, kv-head-major (L, B, kv, S, hd): the decode einsums
    ("bngk,bnsk->bngs") are layout-native, so no per-layer transposed copies
    of the cache slice appear in the compiled step."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, kv, max_len, hd)
    return {"k": jnp.zeros(shape, cfg.adtype),
            "v": jnp.zeros(shape, cfg.adtype)}


def kv_cache_specs() -> Dict:
    return {"k": (None, "batch", "p_kv", "cache_seq", None),
            "v": (None, "batch", "p_kv", "cache_seq", None)}


def init_kv_tail(cfg: ArchConfig, batch: int, window: int,
                 n_layers: Optional[int] = None) -> Dict:
    """Batch-sharded write buffer for block-buffered decode (layout
    (L, B, kv, W, hd): kv-major so attention needs no transpose)."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, kv, window, hd)
    return {"k": jnp.zeros(shape, cfg.adtype),
            "v": jnp.zeros(shape, cfg.adtype)}


def kv_tail_specs() -> Dict:
    return {"k": (None, "batch", "p_kv", None, None),
            "v": (None, "batch", "p_kv", None, None)}


def decode_attention_tailed(p: Dict, cfg: ArchConfig, x: jax.Array,
                            k_main: jax.Array, v_main: jax.Array,
                            k_tail: jax.Array, v_tail: jax.Array,
                            cache_len: jax.Array, positions: jax.Array):
    """Block-buffered decode: the new token's K/V goes into the small
    batch-sharded tail (LOCAL dynamic-update-slice -- never a cross-shard
    write into the sequence-sharded main cache); attention spans
    main[0:main_len] ++ tail[0:tail_len+1] under one joint softmax.

    main: (B, kv, S, hd); tail: (B, kv, W, hd).
    main_len = floor(cache_len / W) * W; the flush (see flush_kv_tail)
    migrates a full tail into main every W steps, amortizing the sharded
    write W-fold.
    """
    b, _, d = x.shape
    w_win = cfg.decode_tail_window
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    main_len = (cache_len // w_win) * w_win
    tail_len = cache_len - main_len
    kt = k_new.transpose(0, 2, 1, 3).astype(k_tail.dtype)      # (B, kv, 1, hd)
    k_tail = lax.dynamic_update_slice_in_dim(k_tail, kt, tail_len, axis=2)
    v_tail = lax.dynamic_update_slice_in_dim(
        v_tail, v_new.transpose(0, 2, 1, 3).astype(v_tail.dtype), tail_len,
        axis=2)

    kv, hd = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kv
    scale = hd ** -0.5
    qf = (q.reshape(b, kv, g, hd).astype(jnp.float32) * scale).astype(q.dtype)
    s_main = jnp.einsum("bngk,bnsk->bngs", qf, k_main,
                        preferred_element_type=jnp.float32)
    smax = k_main.shape[2]
    s_main = jnp.where(jnp.arange(smax)[None, None, None, :] < main_len,
                       s_main, NEG_INF)
    s_tail = jnp.einsum("bngk,bnwk->bngw", qf, k_tail,
                        preferred_element_type=jnp.float32)
    s_tail = jnp.where(jnp.arange(w_win)[None, None, None, :] <= tail_len,
                       s_tail, NEG_INF)
    # two-part online-softmax merge: never concatenate the (sequence-
    # sharded) main scores with the (local) tail scores -- all sharded-S
    # reductions stay inside the main part (flash-decoding style), the merge
    # itself is (B, kv, g, hd)-sized
    m1 = s_main.max(axis=-1)
    p1 = jnp.exp(s_main - m1[..., None])
    l1 = p1.sum(axis=-1)
    o1 = jnp.einsum("bngs,bnsk->bngk", p1.astype(q.dtype), v_main,
                    preferred_element_type=jnp.float32)
    m2 = s_tail.max(axis=-1)
    p2 = jnp.exp(s_tail - m2[..., None])
    l2 = p2.sum(axis=-1)
    o2 = jnp.einsum("bngw,bnwk->bngk", p2.astype(q.dtype), v_tail,
                    preferred_element_type=jnp.float32)
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)[..., None]
    e2 = jnp.exp(m2 - m)[..., None]
    denom = l1[..., None] * e1 + l2[..., None] * e2
    o = (o1 * e1 + o2 * e2) / jnp.maximum(denom, 1e-30)
    o = o.reshape(b, 1, cfg.n_heads, hd).astype(cfg.adtype)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.adtype))
    return shard(y, "batch", None, None), k_tail, v_tail


def flush_kv_tail(cfg: ArchConfig, state: Dict) -> Dict:
    """Migrate a FULL tail (W tokens) into the sequence-sharded main cache.
    Call when ``cache_len % W == 0`` and ``cache_len > 0``; the serving loop
    amortizes this one sharded write over W decode steps."""
    w_win = cfg.decode_tail_window
    clen = state["cache_len"]
    dst = clen - w_win
    kv = state["kv"]
    tail = state["tail"]
    # tail (L,B,kv,W,hd) and main (L,B,kv,S,hd) share the kv-major layout:
    # the flush is a straight dynamic-update-slice on the sequence axis
    k_main = lax.dynamic_update_slice_in_dim(kv["k"], tail["k"], dst, axis=3)
    v_main = lax.dynamic_update_slice_in_dim(kv["v"], tail["v"], dst, axis=3)
    return dict(state,
                kv={"k": k_main, "v": v_main},
                tail=jax.tree.map(jnp.zeros_like, tail))


def decode_attention(p: Dict, cfg: ArchConfig, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, positions: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x: (B, 1, d); k/v_cache: (B, kv, S_max, hd);
    cache_len: () current fill; positions: (B, 1).

    Returns (y, new_k_cache, new_v_cache).  The new token's K/V is written at
    ``cache_len``; attention spans the first ``cache_len + 1`` entries.
    """
    b, _, d = x.shape
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    k_cache = lax.dynamic_update_slice_in_dim(
        k_cache, k_new.transpose(0, 2, 1, 3).astype(k_cache.dtype),
        cache_len, axis=2)
    v_cache = lax.dynamic_update_slice_in_dim(
        v_cache, v_new.transpose(0, 2, 1, 3).astype(v_cache.dtype),
        cache_len, axis=2)
    k_cache = shard(k_cache, "batch", "p_kv", "cache_seq", None)
    v_cache = shard(v_cache, "batch", "p_kv", "cache_seq", None)

    kv, hd = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kv
    scale = hd ** -0.5
    # MXU-style: bf16 operands, f32 accumulation (keeps the sharded cache in
    # its storage dtype -- no whole-cache f32 round trips)
    qf = (q.reshape(b, kv, g, hd).astype(jnp.float32) * scale).astype(q.dtype)
    s_ = jnp.einsum("bngk,bnsk->bngs", qf, k_cache,
                    preferred_element_type=jnp.float32)      # (B, kv, g, S)
    smax = k_cache.shape[2]
    valid = jnp.arange(smax)[None, None, None, :] <= cache_len
    s_ = jnp.where(valid, s_, NEG_INF)
    w = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bngs,bnsk->bngk", w.astype(q.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, cfg.n_heads, hd).astype(cfg.adtype)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.adtype))
    return shard(y, "batch", None, None), k_cache, v_cache
