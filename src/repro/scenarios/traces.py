"""Versioned on-disk workload traces: save, load, validate, resample.

The fleet contract (PR 5) is a pair of arrays -- rates ``f32[B, T, N]``
and partition existence ``active: bool[B, T, N]`` -- and everything
downstream (``sweep_lag``, ``FleetRunner``, the benchmarks) consumes
exactly that.  This module gives the pair a *file format*, so a scenario
can come from disk instead of a generator: a recorded production
workload, a seed shape from the Kafka benchmark paper
(``scenarios.seeds``), or a witness genome the adversarial search found
(``scenarios.search``).

Format (version ``TRACE_VERSION``):

* ``.json`` -- self-describing, diff-able, the golden-fixture format.
  ``rates`` round-trip exactly: every float32 is representable as a JSON
  double and numpy reads it back to the identical float32.
* ``.npz``  -- compressed binary for anything big; the same header
  rides inside as a JSON string.

``load_trace`` always validates: version, shapes, dtypes, finiteness,
non-negative rates, and the mask contract (a partition that does not
exist must have rate exactly 0 -- silence where absent).

``resample_trace`` retimes a trace to a different step count.  With
``iters == trace.iters`` it returns the trace *unchanged* -- the
bit-for-bit identity the round-trip property test pins -- otherwise
zero-order hold (``"hold"``, default; mask-safe) or ``"linear"`` on the
rates with a hold mask.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np

#: on-disk format version; bump on breaking layout changes
TRACE_VERSION = 1

_KIND = "repro.trace"


@dataclasses.dataclass
class Trace:
    """One fleet-contract workload batch with provenance.

    ``rates``/``active`` are host numpy (``f32``/``bool``, both
    ``[B, T, N]``); hand them straight to ``FleetRunner.simulate(...,
    active=...)`` or ``repro.api.replay``.  ``meta`` carries free-form
    provenance (generator knobs, witness genome, resampling history).
    """

    rates: np.ndarray
    active: np.ndarray
    capacity: float = 1.0
    name: str = ""
    source: str = ""
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    version: int = TRACE_VERSION

    @property
    def batch(self) -> int:
        return int(self.rates.shape[0])

    @property
    def iters(self) -> int:
        return int(self.rates.shape[1])

    @property
    def n(self) -> int:
        return int(self.rates.shape[2])


def validate_trace(trace: Trace) -> Trace:
    """Check the fleet contract; -> the trace with canonical dtypes.

    Raises ``ValueError`` naming the first violated invariant: format
    version, rank/shape, finiteness, negative rates, or a rate where the
    partition does not exist.
    """
    if int(trace.version) != TRACE_VERSION:
        raise ValueError(
            f"unsupported trace format version {trace.version!r}; this "
            f"build reads version {TRACE_VERSION}")
    rates = np.asarray(trace.rates, np.float32)
    active = np.asarray(trace.active, bool)
    if rates.ndim != 3:
        raise ValueError(
            f"trace rates must be f32[B, T, N]; got shape {rates.shape}")
    if active.shape != rates.shape:
        raise ValueError(
            f"trace active mask shape {active.shape} != rates shape "
            f"{rates.shape}")
    if not float(trace.capacity) > 0.0:
        raise ValueError(
            f"trace capacity must be > 0, got {trace.capacity!r}")
    if not np.isfinite(rates).all():
        raise ValueError("trace rates contain non-finite values")
    if (rates < 0.0).any():
        raise ValueError("trace rates contain negative values")
    if rates[~active].any():
        raise ValueError(
            "trace violates the mask contract: a partition with "
            "active=False must have rate exactly 0 (silence where absent)")
    trace.rates = rates
    trace.active = active
    return trace


def trace_from_scenario(family: str, key, batch: int, iters: int, n: int, *,
                        capacity: float = 1.0, name: Optional[str] = None,
                        **knobs) -> Trace:
    """Materialize one registered family's batch as a :class:`Trace`
    (provenance: family + knobs; deterministic under a fixed key)."""
    from repro.core.scenarios import generate_masked_scenario

    speeds, active = generate_masked_scenario(
        family, key, batch, iters, n, capacity=capacity, **knobs)
    return validate_trace(Trace(
        rates=np.asarray(speeds, np.float32),
        active=np.asarray(active, bool), capacity=float(capacity),
        name=name or family, source=f"synthetic:{family}",
        meta={"family": family,
              "knobs": {k: float(v) for k, v in knobs.items()}}))


def _header(trace: Trace) -> Dict[str, Any]:
    return {"kind": _KIND, "version": int(trace.version),
            "name": trace.name, "source": trace.source,
            "capacity": float(trace.capacity),
            "shape": [trace.batch, trace.iters, trace.n],
            "meta": trace.meta}


def save_trace(trace: Trace, path: str) -> str:
    """Write a validated trace to ``path`` (format by extension:
    ``.json`` or ``.npz``); -> the path written."""
    trace = validate_trace(trace)
    ext = os.path.splitext(path)[1].lower()
    if ext == ".json":
        doc = _header(trace)
        # float32 -> JSON double -> float32 is exact (doubles cover f32)
        doc["rates"] = trace.rates.astype(np.float32).tolist()
        doc["active"] = trace.active.astype(int).tolist()
        with open(path, "w") as f:
            json.dump(doc, f)
    elif ext == ".npz":
        np.savez_compressed(path, rates=trace.rates,
                            active=trace.active,
                            header=np.array(json.dumps(_header(trace))))
    else:
        raise ValueError(
            f"unknown trace extension {ext!r} for {path!r}; "
            f"use .json or .npz")
    return path


def load_trace(path: str) -> Trace:
    """Read + validate a trace written by :func:`save_trace`."""
    ext = os.path.splitext(path)[1].lower()
    if ext == ".json":
        with open(path) as f:
            doc = json.load(f)
        head = doc
        rates = np.asarray(doc["rates"], np.float32)
        active = np.asarray(doc["active"], bool)
    elif ext == ".npz":
        with np.load(path) as z:
            head = json.loads(str(z["header"][()]))
            rates = np.asarray(z["rates"], np.float32)
            active = np.asarray(z["active"], bool)
    else:
        raise ValueError(
            f"unknown trace extension {ext!r} for {path!r}; "
            f"use .json or .npz")
    if head.get("kind") != _KIND:
        raise ValueError(
            f"{path!r} is not a {_KIND} file (kind={head.get('kind')!r})")
    trace = Trace(rates=rates, active=active,
                  capacity=float(head.get("capacity", 1.0)),
                  name=str(head.get("name", "")),
                  source=str(head.get("source", path)),
                  meta=dict(head.get("meta", {})),
                  version=int(head.get("version", -1)))
    trace = validate_trace(trace)
    shape = head.get("shape")
    if shape is not None and tuple(shape) != trace.rates.shape:
        raise ValueError(
            f"{path!r}: header shape {tuple(shape)} != payload shape "
            f"{trace.rates.shape}")
    return trace


def resample_trace(trace: Trace, iters: int,
                   method: str = "hold") -> Trace:
    """Retime a trace to ``iters`` steps.

    ``iters == trace.iters`` returns ``trace`` itself, untouched -- the
    identity the bit-for-bit round-trip property relies on.  Otherwise:
    ``"hold"`` (zero-order hold on rates *and* mask, the mask-safe
    default) or ``"linear"`` (linear rate interpolation, hold mask,
    rates re-silenced where the held mask says absent).
    """
    if int(iters) < 1:
        raise ValueError(f"resample target iters must be >= 1, got {iters}")
    t = trace.iters
    if int(iters) == t:
        return trace
    if method not in ("hold", "linear"):
        raise ValueError(
            f"unknown resample method {method!r}; use 'hold' or 'linear'")
    idx = np.minimum((np.arange(int(iters)) * t) // int(iters), t - 1)
    active = trace.active[:, idx]
    if method == "hold":
        rates = trace.rates[:, idx]
    else:
        pos = (np.arange(int(iters), dtype=np.float64) * (t - 1)
               / max(int(iters) - 1, 1))
        lo = np.floor(pos).astype(int)
        hi = np.minimum(lo + 1, t - 1)
        frac = (pos - lo).astype(np.float32)[None, :, None]
        rates = (trace.rates[:, lo] * (1.0 - frac)
                 + trace.rates[:, hi] * frac).astype(np.float32)
        rates = np.where(active, rates, np.float32(0.0))
    meta = dict(trace.meta)
    meta["resampled"] = {"from_iters": t, "to_iters": int(iters),
                         "method": method}
    return validate_trace(Trace(
        rates=rates, active=active, capacity=trace.capacity,
        name=trace.name, source=trace.source, meta=meta,
        version=trace.version))


__all__ = [
    "TRACE_VERSION",
    "Trace",
    "load_trace",
    "resample_trace",
    "save_trace",
    "trace_from_scenario",
    "validate_trace",
]
