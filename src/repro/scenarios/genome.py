"""Scenario genomes: fixed vectors over a family's registered knobs.

A genome is ``f32[K]`` where gene ``i`` is the value of the family's
``i``-th registered :class:`~repro.core.scenarios.KnobSpec` (so the
registry *is* the genome layout -- ``FamilySpec.knob_names`` names the
axes).  Everything here is traced-safe: ``decode_genome`` produces the
knob dict a generator takes with the genes still as jax values, and
``repair_genome`` is pure ``jnp`` (clip to bounds, then enforce each
``FamilySpec.ordered`` pair by lifting the upper knob to the lower one
-- the in-graph twin of the host-side ``ValueError`` an empty lifecycle
window raises).
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.scenarios import FamilySpec, family_spec


def _spec(family: Union[str, FamilySpec]) -> FamilySpec:
    return family if isinstance(family, FamilySpec) else family_spec(family)


def genome_bounds(family: Union[str, FamilySpec]
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """-> ``(lo f32[K], hi f32[K])`` over the family's registered knobs."""
    spec = _spec(family)
    lo = np.asarray([k.lo for k in spec.knobs], np.float32)
    hi = np.asarray([k.hi for k in spec.knobs], np.float32)
    return lo, hi


def default_genome(family: Union[str, FamilySpec]) -> np.ndarray:
    """The genome encoding every knob's registered default (``f32[K]``)."""
    spec = _spec(family)
    return np.asarray([k.default for k in spec.knobs], np.float32)


def decode_genome(family: Union[str, FamilySpec], genome
                  ) -> Dict[str, jax.Array]:
    """Genome vector -> ``{knob_name: gene}`` kwargs for the family's
    generators.  Traced-safe: genes pass through as jax values."""
    spec = _spec(family)
    genome = jnp.asarray(genome, jnp.float32)
    if genome.shape != (len(spec.knobs),):
        raise ValueError(
            f"family {spec.name!r} takes genomes of shape "
            f"({len(spec.knobs)},) over knobs {spec.knob_names}; got "
            f"shape {genome.shape}")
    return {k.name: genome[i] for i, k in enumerate(spec.knobs)}


def genome_knobs(family: Union[str, FamilySpec], genome
                 ) -> Dict[str, float]:
    """Host-side twin of :func:`decode_genome`: plain floats, for witness
    JSON and replaying a stored genome through ``generate_*``."""
    spec = _spec(family)
    genome = np.asarray(genome, np.float32)
    if genome.shape != (len(spec.knobs),):
        raise ValueError(
            f"family {spec.name!r} takes genomes of shape "
            f"({len(spec.knobs)},); got shape {genome.shape}")
    return {k.name: float(genome[i]) for i, k in enumerate(spec.knobs)}


def repair_genome(family: Union[str, FamilySpec], genome) -> jax.Array:
    """Project a (possibly batched ``[..., K]``) genome back into the
    valid region: clip every gene to its knob bounds, then repair each
    ``ordered`` pair so the upper knob is ``>= `` the lower one (e.g.
    ``death_frac >= birth_frac`` -- mutation may break the order; the
    search repairs instead of raising, so every stored witness replays
    through the host-side validation cleanly)."""
    spec = _spec(family)
    lo, hi = genome_bounds(spec)
    g = jnp.clip(jnp.asarray(genome, jnp.float32), lo, hi)
    idx = {name: i for i, name in enumerate(spec.knob_names)}
    for lo_name, hi_name in spec.ordered:
        i, j = idx[lo_name], idx[hi_name]
        g = g.at[..., j].set(jnp.maximum(g[..., j], g[..., i]))
    return g


def random_population(family: Union[str, FamilySpec], key: jax.Array,
                      pop: int) -> jax.Array:
    """``pop`` genomes uniform over the knob bounds, repaired
    (``f32[pop, K]``); the search's init and the random baseline's draw."""
    spec = _spec(family)
    lo, hi = genome_bounds(spec)
    u = jax.random.uniform(key, (int(pop), len(spec.knobs)))
    return repair_genome(spec, lo + u * (hi - lo))


__all__ = [
    "decode_genome",
    "default_genome",
    "genome_bounds",
    "genome_knobs",
    "random_population",
    "repair_genome",
]
