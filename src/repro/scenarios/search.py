"""Adversarial scenario search: evolve the workload that breaks a policy.

The paper's claim -- R-score policies "guarantee adequate consumption
rates" at lower cost -- is tested here by *optimizing against it*: a
genome (``scenarios.genome``) parameterizes a registered scenario family
(``burst timing/amplitude, churn rate, heavy-tail index, lifecycle
windows`` for the ``adversarial`` composite), and an evolutionary loop
(elites + tournament selection + uniform crossover + gaussian mutation,
all pure ``jnp``) maximizes the policy's SLO damage.  The fitness oracle
is the batched fleet sweep itself -- :meth:`FleetRunner.fitness` --
returning ``violation_frac`` plus (optionally) PR 8's burn-rate incident
counts per genome, so one oracle call evaluates a whole population in a
single compiled executable, and every generation after the first hits
the runner's warm compile cache (constant ``(B, T, N, cfg)`` shapes).

Determinism: one fixed scenario key is shared by *every* evaluation of a
search, so fitness is a pure function of the genome, a fixed ``seed``
replays the identical search, and the random-search baseline
(:func:`random_search`) is comparable eval-for-eval.  Early stopping is
per-generation: ``patience`` generations without ``min_delta``
improvement end the search, and the baseline is then run at the *actual*
eval budget the evolution consumed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.scenarios import FamilySpec, family_spec
from repro.fleet.runner import FleetRunner
from repro.lagsim.engine import LagSimConfig
from repro.scenarios.genome import (decode_genome, genome_bounds,
                                    genome_knobs, random_population,
                                    repair_genome)
from repro.scenarios.traces import Trace, trace_from_scenario


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Static knobs of one adversarial search (hashable).

    ``pop_size * generations`` bounds the fitness-oracle evals; each
    eval simulates ``scenarios_per_genome`` traces of shape
    ``(iters, n)``.  ``incident_weight > 0`` folds per-step incident
    counts into the fitness (requires an alerting ``LagSimConfig``)."""

    pop_size: int = 16
    generations: int = 8
    elite_frac: float = 0.25
    crossover_p: float = 0.5
    mutation_scale: float = 0.12
    patience: int = 3
    min_delta: float = 1e-4
    scenarios_per_genome: int = 1
    iters: int = 128
    n: int = 8
    capacity: float = 1.0
    incident_weight: float = 0.0

    def __post_init__(self) -> None:
        if int(self.pop_size) < 2:
            raise ValueError(
                f"pop_size must be >= 2, got {self.pop_size}")
        if int(self.generations) < 1 or int(self.patience) < 1:
            raise ValueError("generations and patience must be >= 1")
        if not 0.0 < float(self.elite_frac) < 1.0:
            raise ValueError(
                f"elite_frac must be in (0, 1), got {self.elite_frac!r}")
        if int(self.scenarios_per_genome) < 1:
            raise ValueError("scenarios_per_genome must be >= 1")

    @property
    def n_elites(self) -> int:
        return max(1, int(round(self.elite_frac * self.pop_size)))


@dataclasses.dataclass
class SearchResult:
    """One search's outcome: the worst workload found and how it got
    there.  ``history`` is best-so-far fitness per generation;
    ``evals`` the fitness-oracle evaluations actually spent (early
    stopping may end below ``pop_size * generations``)."""

    policy: str
    family: str
    method: str                     # "evolution" | "random"
    best_fitness: float
    best_violation_frac: float
    best_incidents: float
    best_genome: np.ndarray         # f32[K]
    best_knobs: Dict[str, float]
    history: List[float]
    evals: int
    generations_run: int
    seed: int

    def as_dict(self) -> Dict[str, Any]:
        """JSON-ready envelope row (BENCH_adversarial / golden fixture)."""
        return {
            "policy": self.policy, "family": self.family,
            "method": self.method,
            "best_fitness": round(float(self.best_fitness), 6),
            "best_violation_frac": round(float(self.best_violation_frac), 6),
            "best_incidents": round(float(self.best_incidents), 6),
            "best_genome": [round(float(g), 6) for g in self.best_genome],
            "best_knobs": {k: round(float(v), 6)
                           for k, v in self.best_knobs.items()},
            "history": [round(float(h), 6) for h in self.history],
            "evals": int(self.evals),
            "generations_run": int(self.generations_run),
            "seed": int(self.seed),
        }

    def witness_trace(self, config: SearchConfig, seed: int = 0,
                      batch: int = 4) -> Trace:
        """Materialize the witness genome as a replayable
        :class:`Trace` (provenance: policy + genome in ``meta``)."""
        trace = trace_from_scenario(
            self.family, jax.random.PRNGKey(seed), batch, config.iters,
            config.n, capacity=config.capacity,
            name=f"witness_{self.policy.lower()}", **self.best_knobs)
        trace.source = f"adversarial:{self.policy}"
        trace.meta["genome"] = [float(g) for g in self.best_genome]
        trace.meta["best_violation_frac"] = float(self.best_violation_frac)
        return trace


def family_representatives(backend: str = "jax") -> Dict[str, str]:
    """First registered policy per registry family (registration order
    = paper order), the envelope's per-family champions."""
    from repro.registry import get_spec, list_policies

    out: Dict[str, str] = {}
    for name in list_policies(backend=backend):
        fam = get_spec(name, backend=backend).family
        out.setdefault(fam, name)
    return out


def _scenario_oracle(spec: FamilySpec, cfg: SearchConfig):
    """jitted ``(genomes f32[P, K], key) -> (rates, active)`` with the
    population flattened into one fleet batch ``[P * S, iters, n]`` --
    the shape is constant across generations, so the runner's compile
    cache turns every generation after the first into a dispatch."""
    s = int(cfg.scenarios_per_genome)

    def one(genome, key):
        knobs = decode_genome(spec, repair_genome(spec, genome))
        return spec.masked_fn(key, s, cfg.iters, cfg.n,
                              capacity=cfg.capacity, **knobs)

    @jax.jit
    def batch(genomes, key):
        # one shared key: fitness differences are knob differences, not
        # noise realizations -- the determinism the comparisons rely on
        sp, ac = jax.vmap(lambda g: one(g, key))(genomes)
        p = genomes.shape[0]
        return (sp.reshape(p * s, cfg.iters, cfg.n),
                ac.reshape(p * s, cfg.iters, cfg.n))

    return batch


def _make_evolve(spec: FamilySpec, cfg: SearchConfig):
    """jitted one-generation transition ``(pop, fitness, key) -> pop``."""
    lo, hi = genome_bounds(spec)
    span = jnp.asarray(hi - lo)
    k_dim = len(spec.knobs)
    n_el = cfg.n_elites
    n_ch = int(cfg.pop_size) - n_el

    @jax.jit
    def evolve(pop, fit, key):
        order = jnp.argsort(-fit)
        elites = pop[order[:n_el]]
        k_t, k_x, k_m = jax.random.split(key, 3)
        # tournament-2: two candidate rows per parent, winner by fitness
        cand = jax.random.randint(k_t, (n_ch, 2, 2), 0, pop.shape[0])
        better = (fit[cand[..., 0]] >= fit[cand[..., 1]])[..., None]
        parents = jnp.where(better, pop[cand[..., 0]], pop[cand[..., 1]])
        keep = jax.random.bernoulli(k_x, cfg.crossover_p, (n_ch, k_dim))
        child = jnp.where(keep, parents[:, 0], parents[:, 1])
        noise = jax.random.normal(k_m, (n_ch, k_dim)) \
            * cfg.mutation_scale * span
        child = repair_genome(spec, child + noise)
        return jnp.concatenate([elites, child], axis=0)

    return evolve


def _evaluate(runner: FleetRunner, policy: str, sim: LagSimConfig,
              cfg: SearchConfig, oracle, pop, scen_key
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """-> (fitness f32[P], violation_frac f32[P], incidents f32[P]),
    each genome averaged over its ``scenarios_per_genome`` traces."""
    rates, active = oracle(pop, scen_key)
    fb = runner.fitness([policy], rates, sim, active=active,
                        incident_weight=cfg.incident_weight)
    p, s = pop.shape[0], int(cfg.scenarios_per_genome)
    mean = lambda a: np.asarray(a[0]).reshape(p, s).mean(axis=1)
    return mean(fb.fitness), mean(fb.violation_frac), mean(fb.incidents)


def _run(policy: str, family: str, method: str, config: SearchConfig,
         sim: LagSimConfig, seed: int, runner: Optional[FleetRunner],
         budget_evals: Optional[int]) -> SearchResult:
    spec = family_spec(family)
    if not spec.knobs:
        raise ValueError(
            f"family {family!r} registers no knobs; nothing to search")
    runner = runner if runner is not None else FleetRunner()
    oracle = _scenario_oracle(spec, config)
    key = jax.random.PRNGKey(int(seed))
    k_pop, k_scen, k_evo = jax.random.split(key, 3)
    budget = (int(budget_evals) if budget_evals is not None
              else config.pop_size * config.generations)
    evolve = _make_evolve(spec, config) if method == "evolution" else None
    pop = random_population(spec, k_pop, config.pop_size)
    best_fit = -np.inf
    best_vf = best_inc = 0.0
    best_genome = np.asarray(pop[0])
    history: List[float] = []
    evals = 0
    stall = 0
    gen = 0
    while evals < budget:
        fit, vf, inc = _evaluate(runner, policy, sim, config, oracle,
                                 pop, k_scen)
        evals += config.pop_size
        i = int(np.argmax(fit))
        if float(fit[i]) > best_fit + config.min_delta:
            stall = 0
        else:
            stall += 1
        if float(fit[i]) > best_fit:
            best_fit = float(fit[i])
            best_vf, best_inc = float(vf[i]), float(inc[i])
            best_genome = np.asarray(pop[i], np.float32).copy()
        history.append(best_fit)
        gen += 1
        if method == "evolution" and stall >= config.patience:
            break
        if evals < budget:
            k_g = jax.random.fold_in(k_evo, gen)
            if method == "evolution":
                pop = evolve(pop, jnp.asarray(fit), k_g)
            else:
                pop = random_population(spec, k_g, config.pop_size)
    return SearchResult(
        policy=policy.upper(), family=spec.name, method=method,
        best_fitness=best_fit, best_violation_frac=best_vf,
        best_incidents=best_inc, best_genome=best_genome,
        best_knobs=genome_knobs(spec, best_genome), history=history,
        evals=evals, generations_run=gen, seed=int(seed))


def attack(policy: str, *, family: str = "adversarial",
           config: SearchConfig = SearchConfig(),
           sim: LagSimConfig = LagSimConfig(), seed: int = 0,
           runner: Optional[FleetRunner] = None) -> SearchResult:
    """Evolve the scenario genome that maximizes ``policy``'s SLO damage
    (fixed ``seed`` -> bit-identical search)."""
    return _run(policy, family, "evolution", config, sim, seed, runner,
                None)


def random_search(policy: str, *, family: str = "adversarial",
                  config: SearchConfig = SearchConfig(),
                  sim: LagSimConfig = LagSimConfig(), seed: int = 0,
                  runner: Optional[FleetRunner] = None,
                  evals: Optional[int] = None) -> SearchResult:
    """Uniform-random baseline at an explicit eval budget (pass the
    evolution's ``result.evals`` for an eval-for-eval comparison)."""
    return _run(policy, family, "random", config, sim, seed, runner,
                evals)


__all__ = [
    "SearchConfig",
    "SearchResult",
    "attack",
    "family_representatives",
    "random_search",
]
