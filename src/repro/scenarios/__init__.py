"""Scenario engines v2: trace replay + adversarial search.

Two engines, one contract -- the PR-5 fleet pair of rates
``f32[B, T, N]`` and partition existence ``active: bool[B, T, N]``:

* ``scenarios.traces`` -- versioned on-disk traces (``.json`` /
  ``.npz``): save, load-with-validation, resample, and a padding-exact
  round trip through ``FleetRunner`` (a replayed trace reproduces the
  direct run bit for bit).
* ``scenarios.seeds``  -- the seed library: Kafka benchmark shapes
  (arXiv 2003.06452 insert plateaus, partition skew, lifecycle churn)
  materialized as deterministic traces.
* ``scenarios.genome`` -- genomes over the family registry's
  :class:`~repro.core.scenarios.KnobSpec` bounds: decode, repair
  (bounds + ordered-pair constraints), random populations.
* ``scenarios.search`` -- the adversarial loop: evolutionary search
  (elites/tournament/crossover/mutation, pure ``jnp``) against
  ``FleetRunner.fitness`` to maximize ``violation_frac`` + burn-rate
  incidents, with a random baseline and fixed-seed determinism.

Everything resolves lazily, so ``import repro.scenarios`` is cheap.
"""
from __future__ import annotations

_TRACE_EXPORTS = (
    "TRACE_VERSION",
    "Trace",
    "load_trace",
    "resample_trace",
    "save_trace",
    "trace_from_scenario",
    "validate_trace",
)

_SEED_EXPORTS = (
    "SEED_SHAPES",
    "list_seeds",
    "seed_trace",
)

_GENOME_EXPORTS = (
    "decode_genome",
    "default_genome",
    "genome_bounds",
    "genome_knobs",
    "random_population",
    "repair_genome",
)

_SEARCH_EXPORTS = (
    "SearchConfig",
    "SearchResult",
    "attack",
    "family_representatives",
    "random_search",
)

__all__ = sorted(_TRACE_EXPORTS + _SEED_EXPORTS + _GENOME_EXPORTS
                 + _SEARCH_EXPORTS, key=str.lower)

_HOME = {name: "traces" for name in _TRACE_EXPORTS}
_HOME.update({name: "seeds" for name in _SEED_EXPORTS})
_HOME.update({name: "genome" for name in _GENOME_EXPORTS})
_HOME.update({name: "search" for name in _SEARCH_EXPORTS})


def __getattr__(name: str):
    mod = _HOME.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
