"""Seed trace library: empirically-grounded Kafka workload shapes.

"How Fast Can We Insert?" (arXiv 2003.06452) benchmarks Kafka ingestion
end to end and reports three load shapes our synthetic suite should not
ignore: a *sustained insert plateau* (throughput steps up to a sustained
maximum, holds, and falls away -- their Fig. 4/5 steady-state runs),
*heavy partition skew* (per-partition throughput spread over an order of
magnitude once batching and producer keys interact), and *lifecycle
churn* (topics created and dropped between benchmark phases).  Each seed
below is the :mod:`repro.core.scenarios` ``adversarial`` composite
family pinned to one of those shapes, materialized as a versioned
:class:`~repro.scenarios.traces.Trace` with the provenance in ``meta``.

Seeds are deterministic: ``seed_trace(name)`` with the default key gives
the same bytes on every call, so they double as fixtures.  They are also
the adversarial search's sanity anchor -- a search that cannot beat the
*fixed* plateau seed on violation fraction is not searching.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Optional, Tuple

import jax

from repro.scenarios.traces import Trace, trace_from_scenario

#: seed name -> (description, adversarial-family knobs)
SEED_SHAPES: Dict[str, Tuple[str, Dict[str, Any]]] = {
    "kafka_insert_plateau": (
        "sustained insert plateau: rates step to ~2x capacity mid-trace "
        "and hold (arXiv 2003.06452 steady-state ingest)",
        {"base_rate": 0.15, "tail_sigma": 0.6, "burst_start_frac": 0.3,
         "burst_len_frac": 0.4, "burst_amp": 2.0, "noise": 0.05}),
    "kafka_partition_skew": (
        "heavy-tail partition skew: log-normal per-partition rates, a "
        "few whales carry most of the load (arXiv 2003.06452 batching/"
        "key skew)",
        {"base_rate": 0.35, "tail_sigma": 2.0, "burst_amp": 0.0,
         "burst_len_frac": 0.05, "noise": 0.1}),
    "kafka_lifecycle_churn": (
        "lifecycle churn: half the partitions exist only mid-trace and "
        "others flip on/off (topics created/dropped between benchmark "
        "phases)",
        {"base_rate": 0.25, "tail_sigma": 0.8, "burst_amp": 0.5,
         "burst_start_frac": 0.5, "burst_len_frac": 0.2, "churn_p": 0.05,
         "lifecycle_frac": 0.5, "birth_frac": 0.1, "death_frac": 0.8,
         "noise": 0.05}),
}


def list_seeds() -> Tuple[str, ...]:
    """Registered seed names, in registration order."""
    return tuple(SEED_SHAPES)


def seed_trace(name: str, key: Optional[jax.Array] = None, *,
               batch: int = 4, iters: int = 256, n: int = 16,
               capacity: float = 1.0) -> Trace:
    """Materialize one seed shape as a validated :class:`Trace`.

    Deterministic: the default key is fixed per seed name, so the same
    call gives bit-identical traces across sessions.
    """
    if name not in SEED_SHAPES:
        raise ValueError(
            f"unknown seed trace {name!r}; have {sorted(SEED_SHAPES)}")
    desc, knobs = SEED_SHAPES[name]
    if key is None:
        # crc32, not hash(): stable across interpreter sessions
        key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2 ** 31))
    trace = trace_from_scenario("adversarial", key, batch, iters, n,
                                capacity=capacity, name=name, **knobs)
    trace.source = f"seed:{name}"
    trace.meta["description"] = desc
    trace.meta["paper"] = "arXiv:2003.06452"
    return trace


__all__ = ["SEED_SHAPES", "list_seeds", "seed_trace"]
