"""Stable public facade of the reproduction.

One import gives the five verbs the paper's evaluation is made of, all
resolving policy names through ``repro.registry`` and all returning
versioned result dataclasses (``schema_version`` = ``API_VERSION``):

* ``pack``      -- one packing decision (any registered packer, either
                   backend) -> ``PackOutcome``;
* ``sweep``     -- every algorithm x a batch of speed streams through the
                   vmapped scan engine -> ``SweepOutcome``;
* ``simulate``  -- closed-loop lag twin: policies x traces with migration
                   downtime and SLO metrics -> ``SimulateOutcome``;
* ``optimize``  -- lambda-sweep annealed Pareto frontier of one instance
                   -> ``OptimizeOutcome``;
* ``evaluate``  -- the paper's Figs. 6-9 tables (CBS / avg R-score /
                   Pareto membership) on Eq. 11 streams -> ``EvaluateOutcome``;
* ``attack``    -- adversarial scenario search: evolve the workload
                   genome that maximizes one policy's SLO violation,
                   with a random-search baseline at equal evals
                   -> ``AttackOutcome``;
* ``replay``    -- run a versioned on-disk trace (``repro.scenarios``
                   format, or a ``Trace``) through the fleet path
                   -> ``ReplayOutcome``.

``sweep`` and ``simulate`` execute through the fleet layer
(``repro.fleet``): a shared ``default_fleet()`` runner buckets scenarios
by padded shape under a bounded compile cache and shards the batch axis
across available devices; both verbs take an optional ``active``
bool[B, T, N] partition mask (the variable-N contract) and an optional
``fleet=`` runner override.  ``FleetRunner`` / ``FleetConfig`` are
re-exported for callers that manage their own fleet.

Policy discovery re-exports the registry: ``list_policies``,
``make_policy``, ``get_spec``, ``packer_for``, ``PolicySpec``, ``Policy``.

``BenchReport`` is the shared envelope every ``BENCH_*.json`` is written
through (one schema across benchmark artifacts).  The CI API-surface step
runs ``selfcheck()``; the documented surface lives in README "Public
API" and is pinned by ``tests/test_api_surface.py``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.registry import (
    BACKENDS,
    FAMILIES,
    PACKER_FAMILIES,
    Policy,
    PolicySpec,
    get_spec,
    list_policies,
    make_policy,
    packer_for,
)
from repro.telemetry.spans import Tracer, default_tracer, span, traced

#: schema version stamped on every result dataclass and BENCH_*.json
API_VERSION = 1

__all__ = [
    "AlertConfig",
    "AlertRule",
    "API_VERSION",
    "attack",
    "AttackOutcome",
    "BACKENDS",
    "BenchReport",
    "ControlPlaneConfig",
    "default_fleet",
    "default_tracer",
    "evaluate",
    "EvaluateOutcome",
    "EventStream",
    "FAMILIES",
    "FleetConfig",
    "FleetRunner",
    "FUSED_MAX_PARTITIONS",
    "FusedPathError",
    "get_spec",
    "Incident",
    "list_policies",
    "load_trace",
    "make_policy",
    "optimize",
    "OptimizeOutcome",
    "otlp_metrics_json",
    "pack",
    "PACKER_FAMILIES",
    "packer_for",
    "PackOutcome",
    "Policy",
    "PolicySpec",
    "prometheus_exposition",
    "replay",
    "ReplayOutcome",
    "save_trace",
    "SearchConfig",
    "SearchResult",
    "seed_trace",
    "selfcheck",
    "simulate",
    "SimulateOutcome",
    "SketchConfig",
    "SketchSummary",
    "span",
    "sweep",
    "SweepOutcome",
    "TelemetryConfig",
    "TelemetryFrame",
    "Trace",
    "Tracer",
    "validate_exposition",
]

#: fleet re-exports resolve lazily (keeps ``import repro.api`` jax-free)
_FLEET_EXPORTS = ("FleetRunner", "FleetConfig")
#: lagsim re-exports resolve lazily for the same reason
_LAGSIM_EXPORTS = ("ControlPlaneConfig", "FUSED_MAX_PARTITIONS",
                   "FusedPathError")
#: in-loop recorder / sketch / alert / exporter re-exports resolve
#: lazily too (the exporters are jax-free but live behind
#: ``repro.telemetry``'s lazy map); the span half of telemetry is
#: stdlib-only and imported eagerly above
_TELEMETRY_EXPORTS = ("TelemetryConfig", "TelemetryFrame", "EventStream",
                      "SketchConfig", "SketchSummary", "AlertConfig",
                      "AlertRule", "Incident", "prometheus_exposition",
                      "validate_exposition", "otlp_metrics_json")
#: scenario-engine re-exports (trace format + adversarial search) --
#: lazy like the rest so ``import repro.api`` stays jax-free
_SCENARIO_EXPORTS = ("Trace", "SearchConfig", "SearchResult", "load_trace",
                     "save_trace", "seed_trace")


def __getattr__(name: str):
    if name in _FLEET_EXPORTS:
        from repro import fleet as _fleet

        return getattr(_fleet, name)
    if name in _LAGSIM_EXPORTS:
        from repro import lagsim as _lagsim

        return getattr(_lagsim, name)
    if name in _TELEMETRY_EXPORTS:
        from repro import telemetry as _telemetry

        return getattr(_telemetry, name)
    if name in _SCENARIO_EXPORTS:
        from repro import scenarios as _scenarios

        return getattr(_scenarios, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


_DEFAULT_FLEET = None


def default_fleet():
    """The module-level ``FleetRunner`` every api verb routes through.

    One shared runner means one bounded compile cache across ``sweep`` /
    ``simulate`` calls, so repeated bucket shapes hit warm executables.
    Pass ``fleet=`` to a verb to use a differently-configured runner.
    """
    global _DEFAULT_FLEET
    if _DEFAULT_FLEET is None:
        from repro.fleet import FleetRunner

        _DEFAULT_FLEET = FleetRunner()
    return _DEFAULT_FLEET


# ---------------------------------------------------------------------------
# result dataclasses (the shared versioned schema)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackOutcome:
    """One packing decision."""

    algorithm: str
    backend: str
    capacity: float
    n_bins: int
    assignment: Dict[Any, int]        # pid -> consumer (bin name)
    loads: Dict[int, float]           # consumer -> assigned write speed
    rscore: Optional[float] = None    # Eq. 10 vs ``prev`` (None: no prev)
    schema_version: int = API_VERSION


@dataclasses.dataclass
class SweepOutcome:
    """Batched scenario sweep, axes ``[algorithm, stream, iteration]``."""

    algorithms: Tuple[str, ...]
    bins: np.ndarray                  # i32[A, B, T]
    rscores: np.ndarray               # f32[A, B, T]
    migrations: np.ndarray            # i32[A, B, T]
    schema_version: int = API_VERSION


@dataclasses.dataclass
class SimulateOutcome:
    """Closed-loop lag sweep: SLO metrics per policy x stream."""

    policies: Tuple[str, ...]
    metrics: Dict[str, np.ndarray]    # metric -> f64[P, B]
    lag_total: np.ndarray             # f32[P, B, T] raw trajectories
    consumers: np.ndarray             # i32[P, B, T]
    migrations: np.ndarray            # i32[P, B, T]
    #: per-scenario recorder frames (``TelemetryFrame``) when the config
    #: carries a ``TelemetryConfig``; decode with ``EventStream.from_frame``
    telemetry: Optional[List[Any]] = None
    #: per-scenario streaming-sketch summaries when ``telemetry.sketch``
    #: is on: ``sketches[scenario][policy]`` is a ``SketchSummary``
    #: (merge across scenarios with ``telemetry.sketch.merge_summaries``)
    sketches: Optional[List[List[Any]]] = None
    #: per-scenario decoded ``Incident`` lists (``index == (policy,)``)
    #: when ``telemetry.alerts`` is on
    incidents: Optional[List[List[Any]]] = None
    schema_version: int = API_VERSION


@dataclasses.dataclass
class OptimizeOutcome:
    """Annealed lambda-sweep Pareto frontier of one packing instance."""

    lambdas: List[float]
    per_lambda: List[Tuple[float, float]]   # best (bins, rscore) per lambda
    front: List[Tuple[float, float]]        # non-dominated set
    hypervolume: float
    heuristics: Dict[str, dict]             # name -> frontier metrics
    schema_version: int = API_VERSION


@dataclasses.dataclass
class EvaluateOutcome:
    """The paper's Figs. 6-9 tables over Eq. 11 delta-streams."""

    algorithms: Tuple[str, ...]
    deltas: Tuple[int, ...]
    cbs: Dict[int, Dict[str, float]]        # Eq. 12 per delta
    avg_rscore: Dict[int, Dict[str, float]]  # Eq. 13 per delta
    pareto: Dict[int, List[str]]            # front membership per delta
    schema_version: int = API_VERSION


@dataclasses.dataclass
class AttackOutcome:
    """Adversarial search result: the worst workload found for one
    policy, plus the random-search baseline at equal oracle evals
    (``baseline_fitness`` / ``beats_baseline`` are ``None`` when the
    baseline was skipped)."""

    policy: str
    family: str
    best_fitness: float
    best_violation_frac: float
    best_incidents: float
    witness_genome: List[float]
    witness_knobs: Dict[str, float]
    history: List[float]              # best-so-far fitness per generation
    evals: int
    generations_run: int
    seed: int
    baseline_fitness: Optional[float] = None
    beats_baseline: Optional[bool] = None
    #: the full ``repro.scenarios.SearchResult`` pair (search, baseline)
    search: Any = None
    baseline: Any = None
    schema_version: int = API_VERSION


@dataclasses.dataclass
class ReplayOutcome:
    """One on-disk trace replayed through the fleet path."""

    trace_name: str
    source: str
    shape: Tuple[int, int, int]       # (B, T, N) as simulated
    resampled: bool
    policies: Tuple[str, ...]
    metrics: Dict[str, np.ndarray]    # metric -> f64[P, B]
    #: full per-policy trajectories (the ``simulate`` result the replay
    #: reduces to metrics)
    result: Optional[SimulateOutcome] = None
    schema_version: int = API_VERSION


@dataclasses.dataclass
class BenchReport:
    """Shared envelope for ``BENCH_*.json`` artifacts.

    ``as_dict`` keeps each benchmark's historical top-level keys
    (``config`` / ``families`` / anything in ``extra``) and stamps the
    shared schema fields, so one schema covers every artifact without
    breaking row emitters that index into the dict.
    """

    kind: str                          # e.g. "lagsim", "opt"
    config: Dict[str, Any]
    families: Dict[str, Any]
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema_version: int = API_VERSION

    def as_dict(self) -> Dict[str, Any]:
        reserved = {"schema_version", "kind", "config", "families"}
        clash = reserved & set(self.extra)
        if clash:
            raise ValueError(
                f"BenchReport.extra must not shadow envelope keys: "
                f"{sorted(clash)}")
        return {
            "schema_version": self.schema_version,
            "kind": self.kind,
            "config": self.config,
            "families": self.families,
            **self.extra,
        }

    def write(self, path: str) -> Dict[str, Any]:
        out = self.as_dict()
        with open(path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        return out


# ---------------------------------------------------------------------------
# the five verbs
# ---------------------------------------------------------------------------

@traced("api.pack")
def pack(speeds, capacity: float, *, algorithm: str = "BFD",
         prev: Optional[Mapping] = None, backend: str = "py") -> PackOutcome:
    """One packing decision with any registered packer.

    ``backend="py"``: ``speeds`` is a mapping pid -> write speed (or a
    sequence of (pid, speed)); ``prev`` maps pid -> previous consumer.
    ``backend="jax"``: ``speeds`` is f32[n], ``prev`` i32[n] (-1 =
    unassigned); pids are array indices.
    """
    fn = packer_for(algorithm, backend=backend)
    name = algorithm.upper()
    if backend == "py":
        speeds_of = dict(speeds)
        prev = dict(prev) if prev else None
        res = fn(speeds_of, capacity, prev=prev)
        assignment = dict(res.pid_to_bin)
        loads = {int(c): float(l) for c, l in res.loads.items()}
        n_bins = res.n_bins
    else:
        import jax.numpy as jnp

        sp = np.asarray(speeds, np.float64)
        pv = (np.full(sp.shape[0], -1, np.int32) if prev is None
              else np.asarray(prev, np.int32))
        res = fn(jnp.asarray(sp, jnp.float32), jnp.asarray(pv), capacity)
        bin_of = np.asarray(res.bin_of)
        n_bins = int(res.n_bins)
        assignment = {int(j): int(c) for j, c in enumerate(bin_of)}
        names = np.asarray(res.names)[:n_bins]
        lds = np.asarray(res.loads)[:n_bins]
        loads = {int(c): float(l) for c, l in zip(names, lds)}
        speeds_of = {int(j): float(w) for j, w in enumerate(sp)}
        prev = ({int(j): int(c) for j, c in enumerate(pv) if c >= 0}
                if prev is not None else None)
    r = None
    if prev:
        from repro.core.rscore import rscore

        r = rscore(prev, assignment, speeds_of, capacity)
    return PackOutcome(algorithm=name, backend=backend,
                       capacity=float(capacity), n_bins=int(n_bins),
                       assignment=assignment, loads=loads, rscore=r)


@traced("api.sweep")
def sweep(traces, capacity: float = 1.0, *,
          algorithms: Optional[Sequence[str]] = None, active=None,
          fleet=None) -> SweepOutcome:
    """Every algorithm x a batch of streams ``f32[B, T, N]``, executed
    through the fleet layer (bucketed compile cache + batch-axis device
    sharding).  ``active`` (bool[B, T, N], optional) masks partitions
    that do not exist at a step (they pack to ``-1``)."""
    if algorithms is None:
        algorithms = list_policies(family=PACKER_FAMILIES, backend="jax")
    runner = fleet if fleet is not None else default_fleet()
    res = runner.sweep(tuple(algorithms), traces, capacity, active=active)
    bins, rscores, migrations = res.stacked()
    return SweepOutcome(algorithms=res.algorithms, bins=bins,
                        rscores=rscores, migrations=migrations)


@traced("api.simulate")
def simulate(traces, *, policies: Optional[Sequence[str]] = None,
             config=None, active=None, fleet=None, control_plane=None,
             **cfg_overrides) -> SimulateOutcome:
    """Closed-loop lag twin over ``traces`` f32[B, T, N]: backlog, shared
    drain budgets and migration downtime per policy, reduced to SLO
    metrics (violation fraction, peak lag, time-to-drain,
    consumer-seconds, migrations).  Executes through the fleet layer;
    ``active`` (bool[B, T, N], optional) marks masked partitions as
    unreadable-and-empty.

    ``control_plane`` (a ``ControlPlaneConfig`` or a mapping of its
    knobs) runs every policy behind an emulated scaler control plane:
    polling, observation/actuation delay, cooldown, replica clamps, and
    the scale-event rebalance storm.  Inconsistent knobs raise a named
    ``ValueError`` before anything compiles.

    ``telemetry=TelemetryConfig(...)`` (a config override) turns on the
    in-loop observability surface: ``record_frames`` captures per-step
    frames (``.telemetry``), ``sketch=SketchConfig(...)`` streams O(1)
    whole-run aggregates (``.sketches``), and
    ``alerts=AlertConfig(rules=...)`` evaluates SLO burn-rate /
    lag-growth / storm / thrash rules in-loop (``.incidents``).  Export
    any of them with ``prometheus_exposition`` / ``otlp_metrics_json``.

    ``fused_steps=K`` (a config override) routes heuristic-family
    policies through the fused K-step engine (``repro.lagsim.fused``):
    bit-identical trajectories, sketch summaries and incidents, at a
    fraction of the unfused scan's dispatch cost.  Optimizer policies
    and control-plane-wrapped configs raise ``FusedPathError``;
    reactive baselines, ``n > FUSED_MAX_PARTITIONS`` and per-step frame
    recording (an O(T) surface the fused engine does not emit) fall
    back to the unfused scan per policy."""
    import dataclasses as _dc

    from repro.lagsim import ControlPlaneConfig as _CPC
    from repro.lagsim import LagSimConfig

    if policies is None:
        policies = list_policies(backend="jax")
    cfg = config if config is not None else LagSimConfig()
    if control_plane is not None:
        if isinstance(control_plane, Mapping):
            control_plane = _CPC(**control_plane)
        cfg_overrides["control_plane"] = control_plane
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    cfg.resolve(traces.shape[-1] if hasattr(traces, "shape")
                else np.asarray(traces).shape[-1])  # fail fast on bad knobs
    runner = fleet if fleet is not None else default_fleet()
    res = runner.simulate(tuple(policies), traces, cfg, active=active)
    st = res.stacked()
    metrics = {k: np.asarray(v)
               for k, v in res.summarize(cfg, stacked=st).items()}
    sketches = None
    if res.sketch is not None:
        sketches = [[s for _, s in res.sketch_summaries(i)]
                    for i in range(len(res.sketch))]
    incidents = None
    if res.incidents is not None:
        incidents = [res.scenario_incidents(i)
                     for i in range(len(res.incidents))]
    return SimulateOutcome(policies=res.policies, metrics=metrics,
                           lag_total=st["lag_total"],
                           consumers=st["consumers"],
                           migrations=st["migrations"],
                           telemetry=res.telemetry,
                           sketches=sketches, incidents=incidents)


@traced("api.optimize")
def optimize(speeds, prev=None, capacity: float = 1.0, *,
             lambdas: Sequence[float] = (0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0),
             restarts: int = 4, steps: int = 250, seed: int = 0,
             score_heuristics: Union[bool, Sequence[str]] = True
             ) -> OptimizeOutcome:
    """Trace the bins-vs-R-score Pareto frontier of one instance with the
    batched annealer, and (optionally) place registered heuristics
    against it by domination status and hypervolume share."""
    import jax

    from repro.opt import anneal_frontier, heuristic_point

    sp = np.asarray(speeds, np.float64)
    pv = (np.full(sp.shape[0], -1, np.int32) if prev is None
          else np.asarray(prev, np.int32))
    fr = anneal_frontier(sp, pv, capacity, jax.random.key(seed),
                         lambdas=tuple(lambdas), restarts=restarts,
                         steps=steps)
    if score_heuristics is True:
        names = list_policies(family=PACKER_FAMILIES, backend="jax")
    elif score_heuristics:
        names = tuple(score_heuristics)
    else:
        names = ()
    heur = {name: fr.heuristic_metrics(heuristic_point(name, sp, pv, capacity))
            for name in names}
    return OptimizeOutcome(lambdas=fr.lambdas, per_lambda=fr.per_lambda,
                           front=fr.front, hypervolume=fr.hypervolume,
                           heuristics=heur)


@traced("api.evaluate")
def evaluate(*, algorithms: Optional[Sequence[str]] = None,
             deltas: Sequence[int] = (5, 15, 25), n_partitions: int = 30,
             n_measurements: int = 120, capacity: float = 1.0,
             seed: int = 0) -> EvaluateOutcome:
    """The paper's evaluation (Figs. 6-9): Cardinal Bin Score (Eq. 12),
    average R-score (Eq. 13) and Pareto-front membership per
    delta-stream (Eq. 11), through the batched sweep engine."""
    from repro.core.metrics import cbs_from_bins, pareto_front
    from repro.core.streams import generate_stream

    if algorithms is None:
        algorithms = list_policies(family=PACKER_FAMILIES, backend="jax")
    algorithms = tuple(a.upper() for a in algorithms)
    deltas = tuple(int(d) for d in deltas)
    batch = np.stack([
        generate_stream(n_partitions, n_measurements, d, capacity, seed=seed)
        for d in deltas
    ])
    out = sweep(batch, capacity, algorithms=algorithms)
    cbs: Dict[int, Dict[str, float]] = {}
    avg_r: Dict[int, Dict[str, float]] = {}
    pareto: Dict[int, List[str]] = {}
    for i, d in enumerate(deltas):
        cbs[d] = dict(zip(algorithms,
                          cbs_from_bins(out.bins[:, i, :]).tolist()))
        avg_r[d] = dict(zip(algorithms,
                            out.rscores[:, i, :].mean(axis=1).tolist()))
        pts = {a: (cbs[d][a], avg_r[d][a]) for a in algorithms}
        pareto[d] = sorted(pareto_front(pts))
    return EvaluateOutcome(algorithms=algorithms, deltas=deltas, cbs=cbs,
                           avg_rscore=avg_r, pareto=pareto)


@traced("api.attack")
def attack(policy: str, *, family: str = "adversarial", config=None,
           sim=None, seed: int = 0, baseline: bool = True,
           fleet=None) -> AttackOutcome:
    """Evolve the scenario genome that maximizes ``policy``'s SLO
    violation (``repro.scenarios.search``), then -- with ``baseline=True``
    -- run uniform random search at the *same* fitness-oracle eval budget
    and report whether the evolution strictly beat it.

    ``config`` is a ``SearchConfig`` (population, generations, trace
    shape, incident weight); ``sim`` a ``LagSimConfig`` for the fitness
    oracle.  Fixed ``seed`` -> bit-identical search.  The witness genome
    replays via ``SearchResult.witness_trace`` + :func:`replay`.
    """
    from repro.lagsim import LagSimConfig
    from repro.scenarios import search as _search

    cfg = config if config is not None else _search.SearchConfig()
    sim_cfg = sim if sim is not None else LagSimConfig()
    runner = fleet if fleet is not None else default_fleet()
    res = _search.attack(policy, family=family, config=cfg, sim=sim_cfg,
                         seed=seed, runner=runner)
    base = None
    if baseline:
        base = _search.random_search(policy, family=family, config=cfg,
                                     sim=sim_cfg, seed=seed, runner=runner,
                                     evals=res.evals)
    return AttackOutcome(
        policy=res.policy, family=res.family,
        best_fitness=res.best_fitness,
        best_violation_frac=res.best_violation_frac,
        best_incidents=res.best_incidents,
        witness_genome=[float(g) for g in res.best_genome],
        witness_knobs=dict(res.best_knobs),
        history=list(res.history), evals=res.evals,
        generations_run=res.generations_run, seed=int(seed),
        baseline_fitness=None if base is None else base.best_fitness,
        beats_baseline=(None if base is None
                        else res.best_fitness > base.best_fitness),
        search=res, baseline=base)


@traced("api.replay")
def replay(trace, *, policies: Optional[Sequence[str]] = None,
           config=None, iters: Optional[int] = None,
           method: str = "hold", fleet=None,
           **cfg_overrides) -> ReplayOutcome:
    """Replay an on-disk trace (a path to a ``.json``/``.npz`` written by
    ``repro.scenarios.save_trace``, or a ``Trace``) through the fleet
    path -- load, validate, optionally resample to ``iters`` steps, and
    run :func:`simulate` on the trace's rates + mask.

    The trace's recorded ``capacity`` drives the sim unless the caller
    overrides it (``config=`` or ``capacity=``).  Replay is
    padding-exact: the metrics equal a direct run of the same arrays.
    """
    from repro.scenarios import load_trace as _load
    from repro.scenarios import resample_trace as _resample

    tr = _load(trace) if isinstance(trace, str) else trace
    resampled = False
    if iters is not None and int(iters) != tr.iters:
        tr = _resample(tr, int(iters), method=method)
        resampled = True
    if config is None and "capacity" not in cfg_overrides:
        cfg_overrides["capacity"] = float(tr.capacity)
    out = simulate(tr.rates, policies=policies, config=config,
                   active=tr.active, fleet=fleet, **cfg_overrides)
    return ReplayOutcome(
        trace_name=tr.name, source=tr.source,
        shape=(tr.batch, tr.iters, tr.n), resampled=resampled,
        policies=out.policies, metrics=out.metrics, result=out)


# ---------------------------------------------------------------------------
# surface checks (CI)
# ---------------------------------------------------------------------------

def selfcheck() -> None:
    """CI smoke: the exported surface is intact, matches the documented
    surface (README "Public API", when the repo checkout is present), and
    the registry is populated for every family on its expected backends."""
    import os
    import re

    import sys

    mod = sys.modules[__name__]
    # hasattr, not a globals() lookup: the fleet re-exports resolve through
    # the module-level __getattr__ to stay lazy
    missing = [name for name in __all__ if not hasattr(mod, name)]
    assert not missing, f"__all__ exports missing objects: {missing}"
    assert __all__ == sorted(__all__, key=str.lower), (
        "__all__ must stay sorted (case-insensitive) so the documented "
        "surface is diffable")
    readme = os.path.join(os.path.dirname(__file__), "..", "..", "README.md")
    if os.path.exists(readme):            # repo checkout (not an install)
        with open(readme) as f:
            text = f.read()
        m = re.search(r"## Public API\n(.*?)(?:\n## |\Z)", text, re.S)
        assert m, "README.md must keep a '## Public API' section"
        documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`",
                                    m.group(1)))
        undocumented = set(__all__) - documented
        assert not undocumented, (
            f"exports missing from README Public API: {sorted(undocumented)}")
    for family in FAMILIES:
        names = list_policies(family=family)
        assert names, f"no policies registered for family {family!r}"
    packers_py = list_policies(family=PACKER_FAMILIES, backend="py")
    packers_jax = list_policies(family=PACKER_FAMILIES, backend="jax")
    assert packers_py == packers_jax, (
        "every packer must be registered on both backends: "
        f"{packers_py} != {packers_jax}")
    assert len(packers_jax) == 12, packers_jax


if __name__ == "__main__":
    selfcheck()
    for fam in FAMILIES:
        print(f"{fam:<10} {', '.join(list_policies(family=fam))}")
    print("repro.api selfcheck OK "
          f"(API_VERSION={API_VERSION}, {len(__all__)} exports)")
