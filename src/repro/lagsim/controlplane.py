"""Faithful control-plane emulation for the closed-loop lag twin.

The reactive baselines in ``repro.registry`` are *idealized*: they observe
the current lag instantly, reassign instantly, and never pay hysteresis.
Real autoscalers do none of that.  KEDA evaluates its triggers every
``pollingInterval`` seconds, holds scale-downs for ``cooldownPeriod``,
and clamps to ``[minReplicaCount, maxReplicaCount]``; the Cloud Run
Kafka scaler adds metric-collection delay and slow actuation; and any
Kafka consumer-group scale event triggers a rebalance during which the
touched consumers' partitions are unreadable (the paper's downtime
model, applied to the *scaler itself*).

``wrap_policy`` turns any registered scan-safe ``Policy`` into one that
runs behind such a control plane:

* **observation delay** -- the inner policy sees speeds/lag from
  ``observation_delay`` steps ago (ring buffer; delay 0 is the identity);
* **polling** -- decisions are only *taken* every ``polling_interval``
  steps; between polls the last applied assignment is held;
* **actuation delay** -- an accepted decision applies
  ``actuation_delay`` steps later (single pending slot, latest accepted
  decision wins);
* **cooldown** -- after a decision applies, no new decision is accepted
  for ``cooldown_period`` steps (KEDA-style hysteresis);
* **replica clamp** -- the consumer count is floored at
  ``min_replicas``; assignments that use more than ``max_replicas``
  consumers are rank-folded onto the first ``max_replicas`` of them;
* **warm-up storm** -- when an applied decision changes any consumer's
  partition set, every partition owned by a *touched* consumer becomes
  unreadable for ``warmup_steps`` steps (the engine reads the
  ``warming`` countdown off ``ControlPlaneState``).

With the zero-friction config (``polling_interval=1``, zero delays,
zero cooldown, ``min_replicas=1``, ``max_replicas=None``,
``warmup_steps=0``) the wrapped policy reproduces the bare policy
bit-for-bit -- ``tests/test_controlplane.py`` pins this against golden
fixtures.  Everything here is pure ``jax.numpy``/``lax`` data flow (no
``cond`` on pytrees, the inner policy state always advances), so the
wrapper is scan-safe, vmappable, and mask-exact under the variable-N
fleet contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

NEG = -1


def _check_int(name: str, value: Any, what: str = "steps") -> None:
    if isinstance(value, bool) or not isinstance(value, (int, np.integer)):
        raise ValueError(
            f"{name}={value!r} must be an integer number of {what}; the "
            f"control plane is a discrete-step state machine")


@dataclasses.dataclass(frozen=True)
class ControlPlaneConfig:
    """Static control-plane knobs (hashable; rides in ``LagSimConfig``).

    Defaults are the zero-friction identity: poll every step, no delays,
    no cooldown, no replica clamp, no warm-up.  Inconsistent knob
    combinations raise a named ``ValueError`` at construction instead of
    producing silent scan-deep nonsense.
    """

    polling_interval: int = 1       # KEDA pollingInterval (steps)
    observation_delay: int = 0      # metric-collection staleness (steps)
    actuation_delay: int = 0        # decision -> rebalance latency (steps)
    cooldown_period: int = 0        # KEDA cooldownPeriod (steps)
    min_replicas: int = 1           # KEDA minReplicaCount
    max_replicas: Optional[int] = None   # KEDA maxReplicaCount (None: free)
    warmup_steps: int = 0           # rebalance-storm downtime on scale

    def __post_init__(self) -> None:
        _check_int("polling_interval", self.polling_interval)
        _check_int("observation_delay", self.observation_delay)
        _check_int("actuation_delay", self.actuation_delay)
        _check_int("cooldown_period", self.cooldown_period)
        _check_int("warmup_steps", self.warmup_steps)
        _check_int("min_replicas", self.min_replicas, "replicas")
        if self.max_replicas is not None:
            _check_int("max_replicas", self.max_replicas, "replicas")
        if self.polling_interval < 1:
            raise ValueError(
                f"polling_interval={self.polling_interval} must be >= 1: "
                f"the control plane evaluates its triggers at most once "
                f"per step, never more")
        if self.observation_delay < 0:
            raise ValueError(
                f"observation_delay={self.observation_delay} must be >= 0: "
                f"the scaler cannot observe metrics from the future")
        if self.actuation_delay < 0:
            raise ValueError(
                f"actuation_delay={self.actuation_delay} must be >= 0: "
                f"a decision cannot apply before it is taken")
        if self.cooldown_period < 0:
            raise ValueError(
                f"cooldown_period={self.cooldown_period} must be >= 0 "
                f"steps; use 0 to disable the cooldown")
        if 0 < self.cooldown_period < self.polling_interval:
            raise ValueError(
                f"cooldown_period={self.cooldown_period} < polling_interval="
                f"{self.polling_interval}: the cooldown would always expire "
                f"before the next poll could observe it; use "
                f"cooldown_period=0 or >= polling_interval")
        if self.warmup_steps < 0:
            raise ValueError(
                f"warmup_steps={self.warmup_steps} must be >= 0: a replica "
                f"cannot warm up for a negative number of steps")
        if self.min_replicas < 1:
            raise ValueError(
                f"min_replicas={self.min_replicas} must be >= 1: a consumer "
                f"group needs at least one member to make progress")
        if (self.max_replicas is not None
                and self.max_replicas < self.min_replicas):
            raise ValueError(
                f"max_replicas={self.max_replicas} < min_replicas="
                f"{self.min_replicas}: the replica clamp is empty")

    @property
    def is_zero_friction(self) -> bool:
        """True when the wrapper is the bit-for-bit identity."""
        return (self.polling_interval == 1 and self.observation_delay == 0
                and self.actuation_delay == 0 and self.cooldown_period == 0
                and self.min_replicas == 1 and self.max_replicas is None
                and self.warmup_steps == 0)

    def knobs(self) -> dict:
        """The hyperparameter dict a registered REAL policy family takes
        (the lag twin passes these as ``strict=False`` overrides, so one
        grid knob configures self-wrapped and engine-wrapped policies
        alike)."""
        return dict(
            polling_interval=self.polling_interval,
            observation_delay=self.observation_delay,
            actuation_delay=self.actuation_delay,
            cooldown_period=self.cooldown_period,
            min_replicas=self.min_replicas,
            max_replicas=self.max_replicas,
            warmup_steps=self.warmup_steps)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ControlPlaneState:
    """Scan-carried state of a control-plane-wrapped policy.

    The engine type-checks for this class to find the ``warming``
    countdown (partitions on warming consumers are unreadable), so the
    wrapper never needs an engine-side sidechannel.
    """

    tick: jax.Array            # i32    step counter
    obs_speeds: jax.Array      # f32[D+1, N]  observation ring buffer
    obs_lag: jax.Array         # f32[D+1, N]
    obs_active: jax.Array      # bool[D+1, N]
    held_n: jax.Array          # i32    consumer count of the held decision
    pending_assign: jax.Array  # i32[N] accepted-but-not-applied assignment
    pending_n: jax.Array       # i32
    pending_at: jax.Array      # i32    step at which the pending applies
    pending_valid: jax.Array   # bool
    cooldown_until: jax.Array  # i32    no decision accepted before this step
    warming: jax.Array         # i32[N] rebalance-storm countdown
    inner: Any                 # wrapped policy's own state pytree


def _fold_to_max(assign: jax.Array, n_bins: jax.Array, *, k: int, m: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Clamp an assignment to at most ``k`` consumers.

    Used consumer ids are ranked by id; partitions on a consumer of rank
    ``r >= k`` are folded onto the used consumer of rank ``r % k``.
    When at most ``k`` consumers are used this is the exact identity
    (``m`` is the consumer-id universe, ids are < m)."""
    valid = assign >= 0
    sent = jnp.int32(m)
    safe = jnp.where(valid, assign, sent)
    used = jnp.zeros(m + 1, bool).at[safe].set(True)[:m]
    rank = jnp.cumsum(used.astype(jnp.int32)) - 1       # rank of used id i
    ids = jnp.arange(m, dtype=jnp.int32)
    id_of_rank = (jnp.zeros(m, jnp.int32)
                  .at[jnp.where(used, rank, sent)].set(ids, mode="drop"))
    r = rank[jnp.clip(assign, 0, m - 1)]
    folded = id_of_rank[r % jnp.int32(k)]
    new_assign = jnp.where(valid & (r >= jnp.int32(k)), folded, assign)
    return new_assign, jnp.minimum(n_bins, jnp.int32(k))


def wrap_policy(inner_init: Callable, inner_step: Callable,
                cp: ControlPlaneConfig) -> Tuple[Callable, Callable]:
    """Wrap a scan-safe ``(init, step)`` policy pair behind ``cp``.

    The inner policy runs on *delayed* observations every step (its
    state always advances -- no ``where`` over opaque pytrees such as
    PRNG keys), but only poll-step decisions that differ from the held
    assignment are accepted, and an accepted decision applies
    ``actuation_delay`` steps later, starting the cooldown and the
    warm-up storm on the consumers it touched.
    """
    if not isinstance(cp, ControlPlaneConfig):
        raise ValueError(
            f"control plane config must be a ControlPlaneConfig, got "
            f"{type(cp).__name__}")
    d1 = cp.observation_delay + 1

    def init(n_partitions: int) -> ControlPlaneState:
        n = int(n_partitions)
        return ControlPlaneState(
            tick=jnp.int32(0),
            obs_speeds=jnp.zeros((d1, n), jnp.float32),
            obs_lag=jnp.zeros((d1, n), jnp.float32),
            obs_active=jnp.ones((d1, n), bool),
            held_n=jnp.int32(0),
            pending_assign=jnp.full((n,), NEG, jnp.int32),
            pending_n=jnp.int32(0),
            pending_at=jnp.int32(0),
            pending_valid=jnp.zeros((), bool),
            cooldown_until=jnp.int32(0),
            warming=jnp.zeros((n,), jnp.int32),
            inner=inner_init(n))

    def step(speeds, lag, prev_assign, state: ControlPlaneState,
             active=None):
        n = speeds.shape[0]
        m = 2 * n + 2                   # engine's consumer-id universe
        act_now = None if active is None else active.astype(bool)
        tick = state.tick
        # --- observe: write now, read observation_delay steps back ------
        idx = tick % jnp.int32(d1)
        obs_speeds = state.obs_speeds.at[idx].set(
            speeds.astype(jnp.float32))
        obs_lag = state.obs_lag.at[idx].set(lag.astype(jnp.float32))
        rd = (idx + jnp.int32(1)) % jnp.int32(d1)   # slot of step t - D
        sp_d, lag_d = obs_speeds[rd], obs_lag[rd]
        if act_now is None:
            obs_active = state.obs_active
            cand, cand_n, inner = inner_step(sp_d, lag_d, prev_assign,
                                             state.inner)
        else:
            obs_active = state.obs_active.at[idx].set(act_now)
            cand, cand_n, inner = inner_step(sp_d, lag_d, prev_assign,
                                             state.inner, obs_active[rd])
        cand = cand.astype(jnp.int32)
        cand_n = cand_n.astype(jnp.int32)
        # --- clamp to [min_replicas, max_replicas] ----------------------
        if cp.max_replicas is not None:
            cand, cand_n = _fold_to_max(cand, cand_n, k=cp.max_replicas,
                                        m=m)
        if cp.min_replicas > 1:
            # floor the billed count; the extra replicas idle (KEDA
            # minReplicaCount keeps them alive regardless of load)
            cand_n = jnp.maximum(cand_n, jnp.int32(cp.min_replicas))
        if act_now is None:
            cand_out, held_out = cand, prev_assign
        else:
            cand_out = jnp.where(act_now, cand, jnp.int32(NEG))
            held_out = jnp.where(act_now, prev_assign, jnp.int32(NEG))
        # --- decide: poll gating + cooldown hysteresis ------------------
        poll = (tick % jnp.int32(cp.polling_interval)) == 0
        is_change = ((cand_n != state.held_n)
                     | jnp.any(cand_out != held_out))
        accept = poll & is_change & (tick >= state.cooldown_until)
        pending_assign = jnp.where(accept, cand_out, state.pending_assign)
        pending_n = jnp.where(accept, cand_n, state.pending_n)
        pending_at = jnp.where(
            accept, tick + jnp.int32(cp.actuation_delay), state.pending_at)
        pending_valid = accept | state.pending_valid
        # --- actuate: apply the pending decision when it matures --------
        do_apply = pending_valid & (pending_at <= tick)
        out_assign = jnp.where(do_apply, pending_assign, held_out)
        out_n = jnp.where(do_apply, pending_n, state.held_n)
        if cp.min_replicas > 1:
            # minReplicaCount keeps replicas alive (and billed) even
            # before the first decision applies
            out_n = jnp.maximum(out_n, jnp.int32(cp.min_replicas))
        if act_now is not None:
            out_assign = jnp.where(act_now, out_assign, jnp.int32(NEG))
        # --- warm-up storm on the consumers this apply touched ----------
        warm_next = jnp.maximum(state.warming - 1, 0)
        if cp.warmup_steps > 0:
            sent = jnp.int32(m)
            old_bin = jnp.where(held_out >= 0, held_out, sent)
            new_bin = jnp.where(out_assign >= 0, out_assign, sent)
            changed = old_bin != new_bin
            touched = jnp.zeros(m + 1, bool)
            touched = touched.at[old_bin].max(changed)
            touched = touched.at[new_bin].max(changed)
            part_touched = ((out_assign >= 0)
                            & touched[jnp.clip(out_assign, 0, m - 1)])
            warming = jnp.where(do_apply & part_touched,
                                jnp.int32(cp.warmup_steps), warm_next)
        else:
            warming = warm_next
        new_state = ControlPlaneState(
            tick=tick + 1, obs_speeds=obs_speeds, obs_lag=obs_lag,
            obs_active=obs_active, held_n=out_n,
            pending_assign=pending_assign, pending_n=pending_n,
            pending_at=pending_at,
            pending_valid=pending_valid & ~do_apply,
            cooldown_until=jnp.where(
                do_apply, tick + jnp.int32(cp.cooldown_period),
                state.cooldown_until),
            warming=warming, inner=inner)
        return out_assign, out_n, new_state

    # the engine probes this marker to avoid double-wrapping policies
    # (KEDA_LAG_REAL etc.) that already built their own control plane
    step._controlplane_wrapped = True       # type: ignore[attr-defined]
    step._controlplane_config = cp          # type: ignore[attr-defined]
    init._controlplane_wrapped = True       # type: ignore[attr-defined]
    return init, step


__all__ = [
    "ControlPlaneConfig",
    "ControlPlaneState",
    "wrap_policy",
]
