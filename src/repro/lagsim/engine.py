"""Closed-loop lag digital twin: one ``lax.scan`` per stream, vmapped over
the scenario batch.

``serving/simulation.py`` ticks one Python-object world at a time
(broker + JSON mailboxes + replica objects); this engine keeps only the
state that determines consumer-group lag -- per-partition backlog, the
assignment, and migration downtime -- and evolves it as pure arrays, so a
whole fleet of scenarios x policies compiles into a handful of XLA
programs.  Per step ``t``:

  1. each partition produces ``rate[t] * dt`` bytes of backlog;
  2. the policy (a bin-packing algorithm or a reactive baseline, see
     ``policies.py``) maps the current speeds / backlog / previous
     assignment to a new assignment and a consumer count;
  3. partitions whose owner changed become unreadable for
     ``migration_steps`` steps -- the paper's rebalancing cost (data
     cannot be read while a queue migrates) made physical;
  4. every consumer drains up to ``capacity * dt`` bytes from its
     readable partitions, proportionally to their backlog (shared-budget
     water-filling; the fused Pallas kernel in
     ``kernels/lag_update.py`` implements the same update).

The recorded trajectories (total/max lag, consumers, migrations,
unreadable partitions) feed the SLO metrics in ``metrics.py``.  A golden
test cross-validates the twin against ``serving/simulation.py`` on a
constant-rate scenario (tests/test_lagsim.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.lag_update import (lag_update_reference,
                                      lag_update_single)
from repro.lagsim.controlplane import (ControlPlaneConfig, ControlPlaneState,
                                       wrap_policy)
from repro.lagsim.fused import fused_mode, simulate_fused, sweep_fused
from repro.registry import make_policy
from repro.telemetry.alerts import AlertState, alert_init, alert_step
from repro.telemetry.record import (CounterState, TelemetryConfig,
                                    TelemetryFrame, frame_from_outputs,
                                    frame_from_ring, record_step, ring_init,
                                    ring_write)
from repro.telemetry.sketch import SketchState, sketch_init, sketch_update

NEG = -1


@dataclasses.dataclass(frozen=True)
class LagSimConfig:
    """Static knobs of the twin (hashable: one jit cache entry per config).

    ``capacity`` is the consumer drain rate in bytes/s (the paper's C),
    ``dt`` the seconds per step.  ``lag_threshold`` / ``slo_lag`` /
    ``max_consumers`` default to values derived from capacity and the
    partition count when left ``None`` (see ``resolve``).
    """

    capacity: float = 1.0
    dt: float = 1.0
    migration_steps: int = 2          # downtime steps for a moved partition
    lag_threshold: Optional[float] = None    # KEDA_LAG target (bytes)
    target_utilization: float = 0.75         # RATE_THRESHOLD target
    max_consumers: Optional[int] = None      # reactive clamp; default n
    scale_down_patience: int = 3             # stabilization window (steps)
    slo_lag: Optional[float] = None          # metrics threshold (bytes)
    use_kernel: bool = False                 # Pallas fused update in the scan
    fused_steps: int = 0              # K > 0: fused multi-step path (fused.py)
    fused_kernel: bool = False        # fused path launches kernels/loop_fused
    control_plane: Optional[ControlPlaneConfig] = None  # scaler friction
    telemetry: Optional[TelemetryConfig] = None  # in-loop flight recorder

    @property
    def telemetry_on(self) -> bool:
        """True when the in-loop recorder captures this config's runs."""
        return self.telemetry is not None and self.telemetry.enabled

    @property
    def slo_lag_or_default(self) -> float:
        """The metrics threshold; defaults to one consumer-step of drain."""
        return (self.slo_lag if self.slo_lag is not None
                else self.capacity * self.dt)

    def resolve(self, n: int) -> "LagSimConfig":
        """Fill derived defaults for an ``n``-partition workload."""
        if (self.control_plane is not None
                and not isinstance(self.control_plane, ControlPlaneConfig)):
            # one choke point hit by both the direct and the fleet path:
            # fail fast with a named error instead of a scan-deep crash
            raise ValueError(
                f"control_plane must be a ControlPlaneConfig (or None), got "
                f"{type(self.control_plane).__name__}; build one via "
                f"repro.api.ControlPlaneConfig(...)")
        if (self.telemetry is not None
                and not isinstance(self.telemetry, TelemetryConfig)):
            raise ValueError(
                f"telemetry must be a TelemetryConfig (or None), got "
                f"{type(self.telemetry).__name__}; build one via "
                f"repro.api.TelemetryConfig(...)")
        if int(self.fused_steps) < 0:
            raise ValueError(
                f"fused_steps must be >= 0 (0 disables the fused path), "
                f"got {self.fused_steps}")
        if self.fused_kernel and not self.fused_steps:
            raise ValueError(
                "fused_kernel=True requires fused_steps > 0: the megakernel "
                "block size is fused_steps (steps advanced per launch)")
        tele = self.telemetry
        if (tele is not None and tele.sketch is not None
                and tele.sketch.hist_max is None):
            # default histogram range: eight consumer-steps of drain per
            # partition covers any workload the SLO metrics call healthy
            tele = dataclasses.replace(
                tele, sketch=dataclasses.replace(
                    tele.sketch,
                    hist_max=8.0 * self.capacity * self.dt * n))
        return dataclasses.replace(
            self,
            lag_threshold=(self.lag_threshold if self.lag_threshold is not None
                           else 2.0 * self.capacity * self.dt),
            max_consumers=(self.max_consumers if self.max_consumers is not None
                           else n),
            slo_lag=self.slo_lag_or_default,
            telemetry=tele,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LagTrace:
    """Per-step trajectories of one simulated stream (axes ``[..., T]``).

    ``telemetry`` is the in-loop flight-recorder frame when the config's
    ``TelemetryConfig`` is on (``None`` otherwise -- the recorder-free
    path is bit-identical to the pre-telemetry engine)."""

    lag_total: jax.Array    # f32  total backlog after draining
    lag_max: jax.Array      # f32  worst single-partition backlog
    consumers: jax.Array    # i32  consumers billed this step
    migrations: jax.Array   # i32  partitions that changed owner
    unreadable: jax.Array   # i32  partitions in migration downtime
    telemetry: Optional[TelemetryFrame] = None  # recorder frame [.., R, K]
    sketch: Optional[SketchState] = None    # streaming aggregators (O(1))
    incidents: Optional[AlertState] = None  # in-loop alert/incident state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LagSweepResult:
    """Stacked trajectories of a policy sweep, indexed ``[policy, stream, t]``."""

    lag_total: jax.Array    # f32[P, B, T]
    lag_max: jax.Array      # f32[P, B, T]
    consumers: jax.Array    # i32[P, B, T]
    migrations: jax.Array   # i32[P, B, T]
    unreadable: jax.Array   # i32[P, B, T]
    policies: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    telemetry: Optional[TelemetryFrame] = None  # frame [P, B, R, K]
    sketch: Optional[SketchState] = None    # aggregators, leading [P, B]
    incidents: Optional[AlertState] = None  # alert state, leading [P, B]

    def for_policy(self, name: str) -> LagTrace:
        p = self.policies.index(name.upper())
        pick = lambda obj: (None if obj is None else
                            jax.tree_util.tree_map(lambda a: a[p], obj))
        return LagTrace(self.lag_total[p], self.lag_max[p], self.consumers[p],
                        self.migrations[p], self.unreadable[p],
                        telemetry=pick(self.telemetry),
                        sketch=pick(self.sketch),
                        incidents=pick(self.incidents))


def _check_rates_shape(rates, n: int, what: str, array_name: str) -> None:
    """Satellite fix: a partition-count mismatch used to surface as an
    opaque broadcast error deep inside ``lax.scan``; fail fast instead,
    naming both shapes."""
    got = tuple(getattr(rates, "shape", np.shape(rates)))
    if got[-1:] != (n,):
        raise ValueError(
            f"{array_name} has shape {got}, but rates.shape[-1] gives the "
            f"policy n = {n} partitions to pack; {what}")


def _simulate(trace: jax.Array, initial_lag: jax.Array, policy: str,
              cfg: LagSimConfig, active: Optional[jax.Array] = None,
              record_assign: bool = False,
              valid: Optional[jax.Array] = None):
    """Unjitted core: ``trace`` f32[T, N] -> LagTrace of f32/i32[T].

    ``active`` (bool[T, N], optional) marks which partitions exist at each
    step.  A masked partition is *unreadable and empty*: it produces no
    backlog, is assigned to no consumer (``NEG``), drains no budget, and
    its recorded lag is exactly zero.  Deaths cost no migration (the
    consumer just stops reading); rebirths start with no sticky memory.

    With ``record_assign=True`` the per-step assignment ``i32[T, N]`` is
    recorded alongside the trace and a ``(LagTrace, assigns)`` pair is
    returned (regression goldens pin full trajectories this way).

    With ``cfg.telemetry`` on, the flight recorder threads a fixed-shape
    channel vector through the scan (an extra scan output, or a carried
    ring buffer when ``telemetry.ring`` is set) and the returned
    ``LagTrace.telemetry`` holds the recorded ``TelemetryFrame``.  The
    recorder only *reads* values the step already computes, so telemetry
    on/off never changes the simulated trajectories, and the off path
    emits the exact pre-telemetry jaxpr.

    ``telemetry.sketch`` / ``telemetry.alerts`` additionally carry
    streaming aggregators (``repro.telemetry.sketch``) and an in-loop
    alert evaluator (``repro.telemetry.alerts``) through the scan --
    O(1) observability state regardless of T.  ``valid`` (bool[T],
    optional, fleet-internal) gates sketch/alert updates on padded
    bucket steps so a padded run's observability state is bit-identical
    to the direct run's.
    """
    n = trace.shape[1]
    if cfg.fused_steps and fused_mode(policy, cfg, n) == "fused":
        # heuristic family under fused_steps: the multi-step fused path
        # (repro.lagsim.fused) replaces the per-step scan, bit-exactly
        return simulate_fused(trace, initial_lag, policy, cfg, active=active,
                              record_assign=record_assign, valid=valid)
    m = 2 * n + 2                       # packer bin-name universe
    cfg = cfg.resolve(n)
    cap_step = jnp.float32(cfg.capacity * cfg.dt)
    cp = cfg.control_plane
    # strict=False: the engine passes its uniform reactive knob set to every
    # policy; specs that do not declare a knob simply ignore it.  With a
    # control plane configured, its knobs join the set, so a REAL policy
    # family (which declares them and self-wraps) sees the same grid values
    # the engine uses to wrap a plain policy below.
    extra = {} if cp is None else cp.knobs()
    pol = make_policy(
        policy, n, jnp.float32(cfg.capacity), backend="jax", strict=False,
        lag_threshold=jnp.float32(cfg.lag_threshold),
        target_utilization=jnp.float32(cfg.target_utilization),
        max_consumers=cfg.max_consumers,
        scale_down_patience=cfg.scale_down_patience, **extra)
    init, policy_step = pol.init, pol.step
    if cp is not None and not getattr(policy_step, "_controlplane_wrapped",
                                      False):
        init, policy_step = wrap_policy(init, policy_step, cp)
    # the warm-up storm only exists behind a control plane; probing the
    # step marker keeps self-wrapped (REAL) policies storm-correct even
    # when cfg.control_plane is None
    has_cp = getattr(policy_step, "_controlplane_wrapped", False)
    tele = cfg.telemetry if cfg.telemetry_on else None
    frames_on = tele is not None and tele.record_frames
    sketch_on = tele is not None and tele.sketch is not None
    alerts_on = tele is not None and tele.alerts is not None
    ring_mode = frames_on and tele.ring is not None
    need_vec = frames_on or sketch_on
    tele_names: list = [None]        # filled at trace time by record_step

    def drain(lag, produced, assign, readable, act_t):
        if cfg.use_kernel:
            # rank-1 kernel entry: no lag[None] expand + [0] squeeze pair
            # in the jaxpr of every scanned step
            return lag_update_single(
                lag, produced, assign, readable.astype(jnp.int32),
                jnp.full((m,), cap_step, jnp.float32), active=act_t)
        return lag_update_reference(lag, produced, assign, readable,
                                    cap_step, m=m, active=act_t)

    def step(carry, xs):
        lag, assign, down, pstate = carry[:4]
        ci = 4
        if ring_mode:
            tick, rbuf = carry[4:6]
            ci = 6
        if sketch_on:
            sk = carry[ci]
            ci += 1
        if alerts_on:
            al = carry[ci]
        valid_t = None
        if active is None:
            if valid is None:
                rate_t, act_t = xs, None
            else:
                (rate_t, valid_t), act_t = xs, None
            produced = rate_t * jnp.float32(cfg.dt)
        else:
            if valid is None:
                rate_t, act_t = xs
            else:
                rate_t, act_t, valid_t = xs
            produced = jnp.where(act_t, rate_t * jnp.float32(cfg.dt), 0.0)
        observed = lag + produced       # backlog a lag-reactive scaler sees
        if active is None:
            new_assign, n_active, pstate = policy_step(
                rate_t, observed, assign, pstate)
        else:
            new_assign, n_active, pstate = policy_step(
                rate_t, observed, assign, pstate, act_t)
        # NEG never counts as a move: a dying partition hands off nothing
        moved = (assign >= 0) & (new_assign >= 0) & (new_assign != assign)
        down = jnp.where(moved, jnp.int32(cfg.migration_steps),
                         jnp.maximum(down - 1, 0))
        readable = (down == 0) & (new_assign >= 0)
        blocked = down > 0
        storm_mask = None
        if has_cp:
            # rebalance storm: partitions on a warming consumer are
            # unreadable while that consumer rejoins the group
            storm = pstate.warming > 0
            readable = readable & ~storm
            storm_mask = storm & (new_assign >= 0)
            blocked = blocked | storm_mask
        new_lag = drain(lag, produced, new_assign, readable, act_t)
        unreadable = blocked if act_t is None else (blocked & act_t)
        ys = (jnp.sum(new_lag), jnp.max(new_lag),
              n_active.astype(jnp.int32),
              jnp.sum(moved.astype(jnp.int32)),
              jnp.sum(unreadable.astype(jnp.int32)))
        if tele is not None and storm_mask is not None and act_t is not None:
            storm_mask = storm_mask & act_t
        if need_vec:
            vec, tele_names[0] = record_step(
                tele, speeds=rate_t, new_lag=new_lag, moved=moved,
                blocked=unreadable, storm=storm_mask, n_consumers=n_active,
                act_t=act_t, capacity=cfg.capacity, pstate=pstate)
            if frames_on and not ring_mode:
                ys = ys + (vec,)
        if record_assign:
            ys = ys + (new_assign,)
        new_carry = (new_lag, new_assign, down, pstate)
        if ring_mode:
            new_carry = new_carry + (tick + 1, ring_write(rbuf, tick, vec))
        if sketch_on:
            new_carry = new_carry + (
                sketch_update(tele.sketch, sk, vec, valid=valid_t),)
        if alerts_on:
            storm_ct = (jnp.float32(0.0) if storm_mask is None
                        else jnp.sum(storm_mask.astype(jnp.float32)))
            new_carry = new_carry + (alert_step(
                tele.alerts, al, lag_total=ys[0], consumers=n_active,
                unreadable=ys[4], storm_parts=storm_ct,
                slo_lag=cfg.slo_lag, valid=valid_t),)
        return new_carry, ys

    if active is None:
        xs = (trace.astype(jnp.float32) if valid is None
              else (trace.astype(jnp.float32), valid.astype(bool)))
    else:
        xs = ((trace.astype(jnp.float32), active.astype(bool))
              if valid is None
              else (trace.astype(jnp.float32), active.astype(bool),
                    valid.astype(bool)))
    carry0 = (initial_lag.astype(jnp.float32), jnp.full(n, NEG, jnp.int32),
              jnp.zeros(n, jnp.int32), init(n))
    if tele is not None:
        pstate0 = carry0[3]
        full_names = tele.base_channels + (
            tuple(pstate0.names) if isinstance(pstate0, CounterState) else ())
    if ring_mode:
        carry0 = carry0 + (jnp.int32(0), ring_init(tele, len(full_names)))
    if sketch_on:
        carry0 = carry0 + (sketch_init(tele.sketch, full_names),)
    if alerts_on:
        carry0 = carry0 + (alert_init(tele.alerts),)
    carry_end, ys = lax.scan(step, carry0, xs)
    tot, mx, cons, migs, unread = ys[:5]
    idx = 5
    frame = None
    if frames_on:
        t_total = trace.shape[0]
        if ring_mode:
            frame = frame_from_ring(tele, tele_names[0], carry_end[5],
                                    t_total)
        else:
            frame = frame_from_outputs(tele, tele_names[0], ys[idx], t_total)
            idx += 1
    ci = 6 if ring_mode else 4
    sk_state = None
    if sketch_on:
        sk_state = carry_end[ci]
        ci += 1
    al_state = carry_end[ci] if alerts_on else None
    out = LagTrace(lag_total=tot, lag_max=mx, consumers=cons,
                   migrations=migs, unreadable=unread, telemetry=frame,
                   sketch=sk_state, incidents=al_state)
    return (out, ys[idx]) if record_assign else out


@functools.partial(jax.jit,
                   static_argnames=("policy", "cfg", "record_assign"))
def _simulate_jit(trace, initial_lag, policy: str, cfg: LagSimConfig,
                  active=None, record_assign: bool = False, valid=None):
    return _simulate(trace, initial_lag, policy, cfg, active, record_assign,
                     valid)


def simulate_lag(trace: jax.Array, *, policy: str,
                 cfg: LagSimConfig = LagSimConfig(),
                 initial_lag: Optional[jax.Array] = None,
                 active: Optional[jax.Array] = None,
                 record_assign: bool = False):
    """Run one policy over one stream ``f32[T, N]`` -> ``LagTrace`` of [T].

    ``initial_lag`` (f32[N], default zeros) seeds the per-partition backlog
    -- e.g. to resume from a measured system state or to study spike
    recovery from a known excursion.  ``active`` (bool[T, N], optional)
    masks partitions that do not exist at a step: unreadable and empty
    (see ``_simulate``).  ``record_assign=True`` returns
    ``(LagTrace, assigns i32[T, N])`` instead of the trace alone.
    """
    trace = jnp.asarray(trace)
    if trace.ndim != 2:
        raise ValueError(
            f"trace must be f32[T, N] (one stream); got shape {trace.shape}")
    n = trace.shape[1]
    if initial_lag is None:
        initial_lag = jnp.zeros(n, jnp.float32)
    else:
        _check_rates_shape(
            initial_lag, n, "initial_lag must seed every partition's "
            f"backlog, shape ({n},)", "initial_lag")
    if active is not None:
        active = jnp.asarray(active)
        if active.shape != trace.shape:
            raise ValueError(
                f"active mask has shape {active.shape} but the rates trace "
                f"has shape {trace.shape}; the mask must name every "
                f"(step, partition) cell")
    return _simulate_jit(trace, jnp.asarray(initial_lag, jnp.float32),
                         policy.upper(), cfg, active,
                         record_assign=record_assign)


def _sweep_impl(policies: Tuple[str, ...], traces: jax.Array,
                cfg: LagSimConfig, active: Optional[jax.Array] = None,
                valid: Optional[jax.Array] = None) -> LagSweepResult:
    """Unjitted sweep core, shared by the module-level jit below and the
    fleet execution layer (``repro.fleet``), which jits it under its own
    bounded per-bucket cache.  ``valid`` (bool[B, T], fleet-internal)
    gates sketch/alert updates on padded bucket steps."""
    zero0 = jnp.zeros(traces.shape[2], jnp.float32)
    fused_fields = {}
    if cfg.fused_steps:
        # route the heuristic family through the fused multi-step path as
        # ONE family-batched run; everything else keeps the per-step scan
        # (fused_mode raises a named error for fused-incompatible configs)
        modes = {p: fused_mode(p, cfg, traces.shape[2]) for p in policies}
        group = tuple(p for p in policies if modes[p] == "fused")
        if group:
            fused_fields = sweep_fused(group, traces, cfg, active=active,
                                       valid=valid)

    def run_policy(p):
        if active is None and valid is None:
            return jax.vmap(lambda tr: _simulate(tr, zero0, p, cfg))(traces)
        if valid is None:
            return jax.vmap(
                lambda tr, ac: _simulate(tr, zero0, p, cfg, ac))(
                    traces, active)
        if active is None:
            return jax.vmap(
                lambda tr, va: _simulate(tr, zero0, p, cfg, valid=va))(
                    traces, valid)
        return jax.vmap(
            lambda tr, ac, va: _simulate(tr, zero0, p, cfg, ac, valid=va))(
                traces, active, valid)

    per_policy = [LagTrace(**fused_fields[p]) if p in fused_fields
                  else run_policy(p) for p in policies]
    for attr, what in (("telemetry", "telemetry channels"),
                       ("sketch", "sketch channels")):
        objs = [getattr(tr, attr) for tr in per_policy]
        if any(o is not None for o in objs):
            # stacking across policies needs one channel universe; fail
            # with names, not a cryptic treedef mismatch from tree_map
            per_names = {p: (None if o is None else o.names)
                         for p, o in zip(policies, objs)}
            if len(set(per_names.values())) != 1:
                raise ValueError(
                    f"policies in one sweep must record identical {what} "
                    f"(custom CounterState counters differ): "
                    f"{per_names}; sweep them separately via simulate_lag")
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_policy)
    return LagSweepResult(
        lag_total=stacked.lag_total, lag_max=stacked.lag_max,
        consumers=stacked.consumers, migrations=stacked.migrations,
        unreadable=stacked.unreadable, policies=policies,
        telemetry=stacked.telemetry, sketch=stacked.sketch,
        incidents=stacked.incidents)


@functools.partial(jax.jit, static_argnames=("policies", "cfg"))
def _sweep_jit(policies: Tuple[str, ...], traces: jax.Array,
               cfg: LagSimConfig, active=None, valid=None) -> LagSweepResult:
    return _sweep_impl(policies, traces, cfg, active, valid)


def sweep_lag(policies: Tuple[str, ...], traces: jax.Array,
              cfg: LagSimConfig = LagSimConfig(),
              active: Optional[jax.Array] = None) -> LagSweepResult:
    """Closed-loop sweep: every policy over a batch of streams f32[B, T, N].

    ``active`` (bool[B, T, N], optional) is the per-stream partition
    existence mask.  Each policy's scan is vmapped over the batch axis;
    with batch size 1 a row is bit-identical to ``simulate_lag`` on the
    single stream (tests/test_lagsim.py).  Names are case-normalized
    before the jit boundary so equivalent spellings share one
    compile-cache entry.
    """
    traces = jnp.asarray(traces)
    if traces.ndim != 3:
        raise ValueError(
            f"traces must be f32[B, T, N]; got shape {traces.shape}")
    if active is not None:
        active = jnp.asarray(active)
        if active.shape != traces.shape:
            raise ValueError(
                f"active mask has shape {active.shape} but the rates "
                f"traces have shape {traces.shape}; the mask must name "
                f"every (stream, step, partition) cell")
    return _sweep_jit(tuple(p.upper() for p in policies), traces, cfg,
                      active)
