"""Vectorized closed-loop lag simulator with SLO metrics.

A digital twin of the consumer-group control loop: per-partition backlog
evolves under a production trace, a scaling policy (the paper's bin-packing
algorithms or KEDA-style reactive baselines) and migration downtime, as one
``jax.lax.scan`` per stream vmapped over the scenario batch.  See
``engine.py`` for the step semantics, ``policies.py`` for the policy
catalogue and ``metrics.py`` for the SLO reductions.
"""
from .controlplane import ControlPlaneConfig, ControlPlaneState, wrap_policy
from .engine import (
    LagSimConfig,
    LagSweepResult,
    LagTrace,
    simulate_lag,
    sweep_lag,
)
from .fused import FUSED_MAX_PARTITIONS, FusedPathError, fused_mode
from .metrics import SLO_METRIC_NAMES, longest_excursion, slo_summary, summarize_sweep
from .policies import (
    OPTIMIZER_POLICY_NAMES,
    PACKING_POLICY_NAMES,
    REACTIVE_BASELINE_NAMES,
)


def __getattr__(name: str):
    # deprecated: forwards to the policies shim (which warns once and
    # resolves through repro.registry)
    if name == "ALL_POLICY_NAMES":
        from . import policies as _policies
        return _policies.ALL_POLICY_NAMES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ControlPlaneConfig",
    "ControlPlaneState",
    "FUSED_MAX_PARTITIONS",
    "FusedPathError",
    "LagSimConfig",
    "LagSweepResult",
    "LagTrace",
    "OPTIMIZER_POLICY_NAMES",
    "PACKING_POLICY_NAMES",
    "REACTIVE_BASELINE_NAMES",
    "SLO_METRIC_NAMES",
    "fused_mode",
    "longest_excursion",
    "simulate_lag",
    "slo_summary",
    "summarize_sweep",
    "sweep_lag",
    "wrap_policy",
]
