"""Scaling-policy catalogue of the closed-loop lag simulator.

Since the ``repro.registry`` redesign every policy -- the paper's 12
packers, the ``ANNEAL``/``ANNEAL_STICKY`` optimizers and the
``KEDA_LAG``/``RATE_THRESHOLD`` reactive baselines -- is registered in
one place (``repro.registry.builtin``) behind the scan-safe protocol::

  init(n) -> state0                                  (pytree carried by scan)
  step(speeds, lag, prev_assign, state)
      -> (assign i32[N], n_consumers i32, state')

``speeds`` are the step's true per-partition write rates (the twin's
monitor is an oracle); ``lag`` is the backlog *including* this step's
production, which is what a lag-reactive scaler observes.

This module remains as the lagsim-facing view of the registry: the
family name tables below are derived from it, and the old
``make_policy`` entry point forwards to ``repro.registry.make_policy``
(which is what ``engine.py`` now calls directly).
``ALL_POLICY_NAMES`` is deprecated -- use
``repro.registry.list_policies(backend="jax")``.
"""
from __future__ import annotations

from typing import Tuple

from repro.registry import PACKER_FAMILIES, list_policies
from repro.registry import make_policy as _registry_make_policy
from repro.registry.builtin import (  # noqa: F401  (re-exported constants)
    ANNEAL_CHAINS,
    ANNEAL_STEPS,
    ANNEAL_STICKY_LAMBDA,
)

PACKING_POLICY_NAMES: Tuple[str, ...] = list_policies(
    family=PACKER_FAMILIES, backend="jax")
REACTIVE_BASELINE_NAMES: Tuple[str, ...] = list_policies(family="reactive")
OPTIMIZER_POLICY_NAMES: Tuple[str, ...] = list_policies(family="optimizer")


def __getattr__(name: str):
    # deprecation shim: the concatenated name table is now the registry's
    # jax-backend listing (tests/test_registry.py pins the warning)
    if name == "ALL_POLICY_NAMES":
        from repro.registry.compat import warn_deprecated

        warn_deprecated(__name__, "ALL_POLICY_NAMES",
                        "repro.registry.list_policies(backend='jax')")
        return list_policies(backend="jax")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def make_policy(name: str, n: int, capacity, *, lag_threshold,
                target_utilization, max_consumers, scale_down_patience):
    """Build ``(init, step)`` for ``name`` over ``n`` partitions.

    Compatibility wrapper over ``repro.registry.make_policy``:
    ``capacity``/``lag_threshold`` are in bytes *per step* (the engine
    pre-multiplies by dt).  Unknown names raise ValueError.
    """
    policy = _registry_make_policy(
        name, n, capacity, backend="jax", strict=False,
        lag_threshold=lag_threshold, target_utilization=target_utilization,
        max_consumers=max_consumers, scale_down_patience=scale_down_patience)
    return policy.init, policy.step
