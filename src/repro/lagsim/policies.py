"""Scaling policies for the closed-loop lag simulator.

Two families share one scan-safe interface:

* **Packing policies** -- every name in ``jaxpack.ALL_ALGORITHM_NAMES``.
  Each step repacks the current write speeds with the previous assignment
  as ``prev`` (sticky naming), exactly like the controller's REASSIGN
  state; the bin names are the consumer ids.

* **Optimizer policies** -- the batched simulated annealer
  (``repro.opt.anneal``) run once per simulated step, best-of-chains:

  - ``ANNEAL``: minimizes the consumer count alone (lambda = 0) -- a
    near-optimal but rebalance-oblivious upper baseline that shows what
    pure bin minimization costs in migration churn;
  - ``ANNEAL_STICKY``: minimizes ``bins + lambda * Rscore`` (lambda =
    ``ANNEAL_STICKY_LAMBDA``), trading a consumer or two for stability
    like the paper's Modified Any Fit family does.

  Both carry their PRNG key in the policy state, so trajectories are
  deterministic per stream and the whole sweep stays scan-safe.

* **Reactive baselines** -- the industry-standard scalers the paper is
  implicitly compared against (KEDA Kafka scaler / Cloud Run Kafka
  autoscaler, see SNIPPETS.md):

  - ``KEDA_LAG``: desired consumers = ceil(total_lag / lag_threshold),
    KEDA's ``lagThreshold`` rule, clamped to [1, max_consumers].
  - ``RATE_THRESHOLD``: desired consumers = ceil(total_write_rate /
    (target_utilization * capacity)) -- a consumption-rate target with no
    notion of per-partition fit.

  Both assign partitions eagerly by ``partition % n`` (Kafka's eager
  round-robin rebalance): whenever ``n`` changes, most partitions migrate
  and eat downtime -- the rebalancing cost the R-score is designed to
  avoid.  Scale-down waits for ``scale_down_patience`` consecutive
  under-target steps (KEDA's stabilization window); scale-up is immediate.

A policy is ``(init, step)``:

  init(n) -> state0                                  (pytree carried by scan)
  step(speeds, lag, prev_assign, state)
      -> (assign i32[N], n_consumers i32, state')

``speeds`` are the step's true per-partition write rates (the twin's
monitor is an oracle); ``lag`` is the backlog *including* this step's
production, which is what a lag-reactive scaler observes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.jaxpack import ALL_ALGORITHM_NAMES, packer_for

REACTIVE_BASELINE_NAMES: Tuple[str, ...] = ("KEDA_LAG", "RATE_THRESHOLD")
OPTIMIZER_POLICY_NAMES: Tuple[str, ...] = ("ANNEAL", "ANNEAL_STICKY")
ALL_POLICY_NAMES: Tuple[str, ...] = (
    ALL_ALGORITHM_NAMES + REACTIVE_BASELINE_NAMES + OPTIMIZER_POLICY_NAMES)

ANNEAL_STICKY_LAMBDA = 4.0      # R-score weight of ANNEAL_STICKY
ANNEAL_CHAINS = 6               # chains per decision step
ANNEAL_STEPS = 48               # anneal steps per decision step


def _make_packing_policy(name: str, n: int, capacity):
    packer = packer_for(name)

    def init(n_partitions: int):
        return jnp.int32(0)            # stateless; prev_assign is the memory

    def step(speeds, lag, prev_assign, state):
        res = packer(speeds, prev_assign, capacity)
        return res.bin_of, res.n_bins, state

    return init, step


def _make_anneal_policy(name: str, n: int, capacity, *, lam: float,
                        chains: int = ANNEAL_CHAINS,
                        steps: int = ANNEAL_STEPS):
    from repro.opt.anneal import anneal_assign

    def init(n_partitions: int):
        # per-policy deterministic key; split every step so consecutive
        # decisions explore independently while staying scan-safe
        return jax.random.key(0x0A11EA1)

    def step(speeds, lag, prev_assign, key):
        key, sub = jax.random.split(key)
        assign, n_bins = anneal_assign(speeds, prev_assign, capacity, sub,
                                       lam=lam, chains=chains, steps=steps)
        return assign, n_bins, key

    return init, step


def _make_reactive_policy(kind: str, n: int, capacity, *, lag_threshold,
                          target_utilization, max_consumers,
                          scale_down_patience):
    pid = jnp.arange(n, dtype=jnp.int32)
    max_c = jnp.int32(max_consumers)
    patience = jnp.int32(scale_down_patience)

    def init(n_partitions: int):
        return (jnp.int32(1), jnp.int32(0))     # (n_current, under_count)

    def step(speeds, lag, prev_assign, state):
        n_cur, under = state
        if kind == "lag":
            want = jnp.ceil(jnp.sum(lag) / lag_threshold)
        else:
            want = jnp.ceil(jnp.sum(speeds) / (target_utilization * capacity))
        want = jnp.clip(want.astype(jnp.int32), 1, max_c)
        under = jnp.where(want < n_cur, under + 1, jnp.int32(0))
        go_down = under >= patience
        n_new = jnp.where(want > n_cur, want,
                          jnp.where(go_down, want, n_cur))
        under = jnp.where(go_down, jnp.int32(0), under)
        assign = pid % n_new
        return assign, n_new, (n_new, under)

    return init, step


def make_policy(name: str, n: int, capacity, *, lag_threshold,
                target_utilization, max_consumers, scale_down_patience):
    """Build ``(init, step)`` for ``name`` over ``n`` partitions.

    ``capacity``/``lag_threshold`` are in bytes *per step* (the engine
    pre-multiplies by dt).  Unknown names raise ValueError.
    """
    key = name.upper()
    if key in ALL_ALGORITHM_NAMES:
        return _make_packing_policy(key, n, capacity)
    if key == "ANNEAL":
        return _make_anneal_policy(key, n, capacity, lam=0.0)
    if key == "ANNEAL_STICKY":
        return _make_anneal_policy(key, n, capacity,
                                   lam=ANNEAL_STICKY_LAMBDA)
    if key == "KEDA_LAG":
        return _make_reactive_policy(
            "lag", n, capacity, lag_threshold=lag_threshold,
            target_utilization=target_utilization, max_consumers=max_consumers,
            scale_down_patience=scale_down_patience)
    if key == "RATE_THRESHOLD":
        return _make_reactive_policy(
            "rate", n, capacity, lag_threshold=lag_threshold,
            target_utilization=target_utilization, max_consumers=max_consumers,
            scale_down_patience=scale_down_patience)
    raise ValueError(
        f"unknown policy {name!r}; have {sorted(ALL_POLICY_NAMES)}")
