"""SLO metrics over simulated lag trajectories.

The paper's claim is qualitative ("guarantees adequate consumption
rates ... at lower operational costs"); these metrics make it measurable
per (policy, scenario):

* ``peak_lag``        -- worst total backlog ever observed (bytes).
* ``mean_lag``        -- time-averaged total backlog (bytes).
* ``violation_frac``  -- fraction of steps with total lag above the SLO
                         threshold (a lag-based availability SLO).
* ``time_to_drain``   -- longest single excursion above the threshold
                         (seconds): how long a spike takes to drain.
* ``consumer_seconds``-- integral of the consumer count over time: the
                         operational cost the paper minimizes.
* ``total_migrations``-- partitions moved over the run (rebalance churn;
                         the R-score prices exactly this).

All functions are plain numpy over trailing-time arrays ``[..., T]`` so
they work on a single ``LagTrace`` and on stacked ``[P, B, T]`` sweeps
alike.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

SLO_METRIC_NAMES = ("peak_lag", "mean_lag", "violation_frac", "time_to_drain",
                    "consumer_seconds", "total_migrations")


def longest_excursion(mask: np.ndarray) -> np.ndarray:
    """Length (in steps) of the longest run of ``True`` along the last axis."""
    mask = np.asarray(mask, bool)
    run = np.zeros(mask.shape[:-1], np.int64)
    best = np.zeros_like(run)
    for t in range(mask.shape[-1]):
        run = np.where(mask[..., t], run + 1, 0)
        best = np.maximum(best, run)
    return best


def slo_summary(lag_total, consumers, migrations, *, slo_lag: float,
                dt: float = 1.0) -> Dict[str, np.ndarray]:
    """Reduce trajectories ``[..., T]`` to the SLO metric dict ``[...]``."""
    lag_total = np.asarray(lag_total)
    consumers = np.asarray(consumers)
    migrations = np.asarray(migrations)
    over = lag_total > slo_lag
    return {
        "peak_lag": lag_total.max(axis=-1),
        "mean_lag": lag_total.mean(axis=-1),
        "violation_frac": over.mean(axis=-1),
        "time_to_drain": longest_excursion(over) * dt,
        "consumer_seconds": consumers.sum(axis=-1) * dt,
        "total_migrations": migrations.sum(axis=-1),
    }


def summarize_sweep(result, cfg) -> Dict[str, np.ndarray]:
    """SLO summary of a ``LagSweepResult`` under ``cfg`` (arrays ``[P, B]``).

    Pass the same config the sweep ran with; an unset ``slo_lag`` uses the
    config's own default (``cfg.slo_lag_or_default``).
    """
    return slo_summary(result.lag_total, result.consumers, result.migrations,
                       slo_lag=cfg.slo_lag_or_default, dt=cfg.dt)
