"""Fused multi-step path of the lag twin for the heuristic packer family.

The unfused engine pays per-step dispatch inside ``lax.scan``: pack ->
migrate -> drain as separate XLA ops, ~a hundred microseconds per step
at paper shapes (N~10, B~2) where the math itself is nanoseconds
(``packer_latency``'s dispatch-only column).  This module removes the
sequential bottleneck by splitting one simulated step into what is truly
carry-dependent and what is not:

* the heuristic bin STRUCTURE of a step -- which creation slot each item
  lands in (``slot_of``), which item created each slot (``creator``) and
  the bin count ``k`` -- depends only on that step's speeds, never on
  the previous assignment, so it is precomputed WIDE over all ``T``
  steps and all ``R = policies x streams`` rows in a handful of fused
  tensor ops (the same select logic as ``kernels/binpack_select``,
  vectorized with a masked double-min instead of argmin);
* only the Sec. IV-C sticky NAMING and the lag/downtime carry are
  sequential.  They run in one lean ``lax.scan`` whose body is a few
  dozen elementwise ops on ``[R, N]`` rows, with the bin-name universe
  (``2n+2`` names) packed into int32 bitmasks -- hence the
  ``FUSED_MAX_PARTITIONS`` gate (``2n+1 <= 30`` bits).

The decomposition is bit-exact: ``fused == unfused`` for every
trajectory field, every scenario family (``topic_lifecycle`` masking
included), direct and fleet-padded (tests/test_fused_loop.py; the
``python -m repro.lagsim.fused`` smoke asserts it in CI).

Routing (``LagSimConfig.fused_steps > 0``):

=====================  ==========================================
policy / config        fused path behavior
=====================  ==========================================
heuristic family       fused (this module; ``fused_kernel=True``
                       launches ``kernels/loop_fused`` instead)
sticky family          falls back to the unfused scan (the Modified
                       Any Fit schedule is carry-dependent)
reactive (idealized)   falls back to the unfused scan
reactive (REAL)        raises :class:`FusedPathError` (control-plane
                       wrapped: host-visible scaler state)
optimizer (ANNEAL*)    raises :class:`FusedPathError` (PRNG carry)
control_plane set      raises :class:`FusedPathError`
telemetry frames/ring  falls back (O(T) frame recording is
                       unfused-only; sketch/alert aggregates are
                       emitted by the fused path, bit-equal)
n > 14 partitions      falls back (int32 name-bitmask limit)
use_kernel=True        falls back (per-step drain-kernel bits
                       differ from the reference drain's fusion)
=====================  ==========================================

``fused_steps``/K is the megakernel's steps-per-launch block size
(``kernels/loop_fused``); the XLA fused engine below computes the whole
trace in one program, so its results are K-invariant by construction
(T not divisible by K included).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.registry import get_spec

NEG = -1
_TINY = 1e-30          # python literal: never a traced const (matches lag_update)
_BIG_SLOT = 127        # > any slot index; the tie-break filler of the min-select

#: the sticky-naming bitmask packs the ``2n+2`` bin-name universe into an
#: int32 (bit ``2n+1`` must stay below the sign bit), so the fused path
#: covers ``n <= 14`` partitions and falls back above
FUSED_MAX_PARTITIONS = 14

_STRAT_CODE = {"next": 0, "first": 1, "best": 2, "worst": 3}


class FusedPathError(ValueError):
    """``fused_steps`` was combined with a policy or config whose state
    cannot live inside the fused loop (ANNEAL* PRNG carry, control-plane
    wrapped scalers).  Drop ``fused_steps`` or the offending piece."""


def _controlplane_wrapped(spec) -> bool:
    """True for self-wrapped REAL scaler families: their hyperparams carry
    the control-plane knob set (``ControlPlaneConfig.knobs()``)."""
    from repro.lagsim.controlplane import ControlPlaneConfig

    return bool(set(ControlPlaneConfig().knobs()) & set(spec.hyperparams))


def fused_mode(policy: str, cfg, n: int) -> str:
    """Route one policy under ``cfg.fused_steps > 0``: ``"fused"`` or
    ``"unfused"`` (documented fallback).  Raises :class:`FusedPathError`
    for the combinations the fused path refuses (see the module table).
    """
    spec = get_spec(policy, backend="jax")
    if spec.family == "optimizer":
        raise FusedPathError(
            f"fused_steps is incompatible with optimizer policy "
            f"{spec.name!r}: its PRNG-carrying anneal state cannot run "
            f"inside the fused loop; drop fused_steps or the policy")
    if cfg.control_plane is not None:
        raise FusedPathError(
            "fused_steps is incompatible with control_plane: scaler "
            "friction (polling/delay/cooldown/rebalance storm) wraps every "
            "policy in state the fused loop does not model; drop "
            "fused_steps or control_plane")
    if spec.family == "reactive" and _controlplane_wrapped(spec):
        raise FusedPathError(
            f"fused_steps is incompatible with control-plane-wrapped "
            f"policy {spec.name!r}; drop fused_steps or use the idealized "
            f"variant of the scaler")
    if spec.family != "heuristic":
        return "unfused"
    if n > FUSED_MAX_PARTITIONS:
        return "unfused"
    tele = cfg.telemetry
    if tele is not None and tele.enabled and tele.record_frames:
        # O(T) frame recording (ring mode included) is unfused-only
        return "unfused"
    if getattr(cfg, "use_kernel", False):
        # the per-step drain kernel and the inlined reference drain agree
        # in value but not always in bits (XLA fuses the reference path
        # with its surroundings); the fused path computes reference-drain
        # bits, so use_kernel runs stay on the per-step scan
        return "unfused"
    return "fused"


def _heuristic_consts(policies: Sequence[str], b: int):
    """Per-row select constants for a family-batched run of ``P`` heuristic
    policies over ``b`` streams (row ``r = p * b + stream``)."""
    strategies, decreasing = [], []
    for name in policies:
        hyper = get_spec(name, backend="jax").hyperparams
        strategies.append(hyper["strategy"])
        decreasing.append(bool(hyper["decreasing"]))
    strat = jnp.asarray([_STRAT_CODE[s] for s in strategies],
                        jnp.int32).repeat(b)
    is_next = (strat == 0)[None, :]                       # [1, R]
    # one score per strategy, minimized with lowest-slot tie-break:
    #   first: slot index            best: -load (tightest fit wins)
    #   worst: +load (most slack)    next: handled by the is_next branch
    a_sgn = jnp.where(strat == 2, -1.0,
                      jnp.where(strat == 3, 1.0, 0.0))[None, :, None]
    b_is_first = jnp.where(strat == 1, 1.0, 0.0)[None, :, None]
    return decreasing, is_next, a_sgn, b_is_first


def _prep(traces: jax.Array, dec_flags: Sequence[bool],
          active: Optional[jax.Array]):
    """Sorted per-step views for every (policy, stream) row.

    For a Decreasing policy the item order is ``pack_jax``'s stable
    non-increasing sort ``lexsort((arange(n), -speeds))``, computed here
    as a pairwise rank (strictly-greater plus equal-with-lower-index)
    scattered through a one-hot -- no sort primitive, fully batched.
    Returns ``(sp_ord, order, pos, act_ord)`` each ``[R, T, N]``:
    speeds/item-index/rank in traversal order, plus the active mask in
    traversal order (``None`` when unmasked).
    """
    b, t, n = traces.shape
    p = len(dec_flags)
    iota_n = jnp.arange(n, dtype=jnp.int32)
    s = traces
    gt = s[..., :, None] < s[..., None, :]
    eq_lo = ((s[..., :, None] == s[..., None, :])
             & (iota_n[None, :] > iota_n[:, None]).T)
    rank_d = jnp.sum(gt | eq_lo, axis=-1).astype(jnp.int32)      # [B, T, N]
    oh = rank_d[..., :, None] == iota_n[None, None, None, :]
    order_d = jnp.sum(jnp.where(oh, iota_n[:, None], 0), -2).astype(jnp.int32)
    sp_d = jnp.sum(jnp.where(oh, s[..., None], 0.0), axis=-2)
    idn = jnp.broadcast_to(iota_n, (b, t, n))
    dec = jnp.asarray(dec_flags, bool)[:, None, None, None]
    ex = lambda a: jnp.broadcast_to(a, (p,) + a.shape)
    order = jnp.where(dec, ex(order_d), ex(idn)).reshape(p * b, t, n)
    pos = jnp.where(dec, ex(rank_d), ex(idn)).reshape(p * b, t, n)
    sp_ord = jnp.where(dec, ex(sp_d), ex(traces)).reshape(p * b, t, n)
    act_ord = None
    if active is not None:
        act_r = ex(active).reshape(p * b, t, n)
        act_ord = jnp.take_along_axis(act_r, order, axis=-1)
    return sp_ord, order, pos, act_ord


def _struct(sp_ord, ord_idx, act_ord, capacity, is_next, a_sgn, b_is_first):
    """Carry-free pack structure, wide over the leading ``(T, R)`` axes.

    Mirrors ``pack_jax``'s item scan minus the naming: ``slot_ord[i]`` is
    the creation slot of the i-th item in traversal order (``NEG`` for an
    inactive item, which leaves every piece of state untouched --
    ``pack_jax``'s mask contract), ``creator[s]`` the item that created
    slot ``s`` and ``k`` the bin count.
    """
    n = sp_ord.shape[-1]
    m = n + 1
    lead = sp_ord.shape[:-1]
    iota_m = jnp.arange(m, dtype=jnp.int32)
    iota_mf = iota_m.astype(jnp.float32)
    inf = jnp.float32(jnp.inf)
    big = jnp.int32(_BIG_SLOT)
    b_off = b_is_first * iota_mf
    loads = jnp.full(lead + (m,), inf, jnp.float32)
    creator = jnp.full(lead + (m,), NEG, jnp.int32)
    k = jnp.zeros(lead, jnp.int32)
    lastload = jnp.zeros(lead, jnp.float32)
    slot_ord = []
    for i in range(n):
        w = sp_ord[..., i]
        j = ord_idx[..., i]
        d = loads + w[..., None]
        fits = d <= capacity
        score = jnp.where(fits, a_sgn * loads + b_off, inf)
        mn = jnp.min(score, axis=-1)
        s_sel = jnp.min(jnp.where(score == mn[..., None], iota_m, big), -1)
        found_sel = mn < inf
        ok_next = (k > 0) & (lastload + w <= capacity)
        slot = jnp.where(is_next, k - 1, s_sel)
        found = jnp.where(is_next, ok_next, found_sel)
        slot = jnp.where(found, slot, k)
        coh = iota_m == slot[..., None]
        if act_ord is None:
            upd = coh
            act_i = None
        else:
            act_i = act_ord[..., i]
            upd = coh & act_i[..., None]
        loads = jnp.where(
            upd, jnp.where(found[..., None], d, w[..., None]), loads)
        creator = jnp.where(upd & ~found[..., None], j[..., None], creator)
        new_lastload = jnp.where(found & (slot == k - 1), lastload + w,
                                 jnp.where(~found, w, lastload))
        if act_i is None:
            lastload = new_lastload
            k = k + (~found).astype(jnp.int32)
            slot_ord.append(slot)
        else:
            lastload = jnp.where(act_i, new_lastload, lastload)
            k = k + (act_i & ~found).astype(jnp.int32)
            slot_ord.append(jnp.where(act_i, slot, jnp.int32(NEG)))
    return jnp.stack(slot_ord, -1), creator, k


def _fused_wide(policies: Tuple[str, ...], traces: jax.Array, cfg,
                active: Optional[jax.Array],
                initial_lag: Optional[jax.Array]):
    """The fused run itself: structure precompute + one lean scan.

    Returns wide per-step arrays ``(lag_t, asg_t, down_t)`` each
    ``[T, R, N]`` plus the bin counts ``kk [T, R]`` (R-rows ordered
    ``policy-major``: row ``p * B + stream``).
    """
    b, t, n = traces.shape
    m = n + 1
    p = len(policies)
    r = p * b
    dec_flags, is_next, a_sgn, b_is_first = _heuristic_consts(policies, b)
    capacity = jnp.float32(cfg.capacity)
    cap_step = jnp.float32(cfg.capacity * cfg.dt)
    dt = jnp.float32(cfg.dt)
    mig = jnp.int32(cfg.migration_steps)
    iota_n = jnp.arange(n, dtype=jnp.int32)
    one = jnp.int32(1)

    sp_ord, order, pos, act_ord = _prep(traces, dec_flags, active)
    tw = lambda a: jnp.moveaxis(a, 0, 1)        # [R, T, ...] -> [T, R, ...]
    slot_ord, creator, kk = _struct(
        tw(sp_ord), tw(order), None if act_ord is None else tw(act_ord),
        capacity, is_next, a_sgn, b_is_first)
    slot_of = jnp.take_along_axis(slot_ord, tw(pos), axis=-1)   # [T, R, N]

    rates_tn = jnp.moveaxis(traces, 0, 1)                       # [T, B, N]
    act_tn = None if active is None else jnp.moveaxis(active, 0, 1)

    def one_step(carry, xs):
        lag, prev, down = carry
        if act_tn is None:
            rate_b, slot_t, creator_t, k_t = xs
            act_r = None
        else:
            rate_b, slot_t, creator_t, k_t, act_b = xs
            act_r = jnp.broadcast_to(act_b[None], (p, b, n)).reshape(r, n)
        rate_r = jnp.broadcast_to(rate_b[None], (p, b, n)).reshape(r, n)
        produced = (rate_r * dt if act_r is None
                    else jnp.where(act_r, rate_r * dt, 0.0))
        # sticky naming (Sec. IV-C): slots in creation order; a slot keeps
        # its creator's previous bin name when still unclaimed, else takes
        # the lowest unused name.  ``claimed``/``seen`` track name bits,
        # ``q`` the lowest-unused pointer, advanced by bit tricks.
        p_all = jnp.sum(jnp.where(creator_t[:, :, None] == iota_n[None, None],
                                  prev[:, None, :], 0), axis=-1)
        p_all = jnp.where(creator_t >= 0, p_all, NEG)
        claimed = jnp.zeros((r,), jnp.int32)
        seen = jnp.zeros((r,), jnp.int32)
        q = jnp.zeros((r,), jnp.int32)
        new_assign = jnp.full((r, n), NEG, jnp.int32)
        for s in range(n):
            v = p_all[:, s]
            vbit = one << jnp.maximum(v, 0)
            live = s < k_t
            cand = (v >= 0) & ((seen & vbit) == 0)
            seen = jnp.where(v >= 0, seen | vbit, seen)
            win = cand & (v >= q) & live
            fall = live & ~win
            nm = jnp.where(win, v, q)
            new_assign = jnp.where((slot_t == s) & live[:, None],
                                   nm[:, None], new_assign)
            claimed = jnp.where(win, claimed | vbit, claimed)
            adv = fall | (win & (v == q))
            mask = claimed | ((one << (q + 1)) - 1)
            low = (~mask) & (mask + 1)
            q = jnp.where(adv, lax.population_count(low - 1), q)
        moved = (prev >= 0) & (new_assign >= 0) & (new_assign != prev)
        down = jnp.where(moved, mig, jnp.maximum(down - 1, 0))
        readable = (down == 0) & (new_assign >= 0)
        # drain in slot space (slot <-> name is a bijection per step, so
        # the per-bin sums match lag_update_reference's name-space sums)
        avail = lag + produced
        live_p = readable & (slot_t >= 0)
        iota_m = jnp.arange(m, dtype=jnp.int32)
        onehot = ((slot_t[:, None, :] == iota_m[None, :, None])
                  & live_p[:, None, :])
        per_bin = jnp.sum(jnp.where(onehot, avail[:, None, :], 0.0), axis=-1)
        ratio = jnp.minimum(1.0, cap_step / jnp.maximum(per_bin, _TINY))
        frac = jnp.where(
            live_p,
            jnp.take_along_axis(ratio, jnp.maximum(slot_t, 0), axis=-1), 0.0)
        new_lag = jnp.maximum(avail * (1.0 - frac), 0.0)
        if act_r is not None:
            new_lag = jnp.where(act_r, new_lag, 0.0)
        new_carry = (new_lag, new_assign, down)
        return new_carry, new_carry

    lag0 = (jnp.zeros((r, n), jnp.float32) if initial_lag is None
            else jnp.broadcast_to(
                initial_lag.astype(jnp.float32), (r, n)))
    carry0 = (lag0, jnp.full((r, n), NEG, jnp.int32),
              jnp.zeros((r, n), jnp.int32))
    xs = (rates_tn, slot_of, creator, kk)
    if act_tn is not None:
        xs = xs + (act_tn,)
    _, (lag_t, asg_t, down_t) = lax.scan(one_step, carry0, xs)
    return lag_t, asg_t, down_t, kk, carry0[1]


def _shape_pb(x, p, b):
    """[T, R] -> [P, B, T] (row r = p * B + stream)."""
    t = x.shape[0]
    return x.reshape(t, p, b).transpose(1, 2, 0)


def _obs_states(tele, cfg, names, vec_w, lag_tot, kk, unread_ct, valid_tr, r):
    """Post-hoc sketch/alert aggregation: replay the per-step channel
    vectors (already bit-equal to the unfused recorder's) through the
    same ``sketch_update``/``alert_step`` sequence, vmapped over rows.
    Same values, same order, same float ops => bit-identical states."""
    from repro.telemetry.alerts import alert_init, alert_step
    from repro.telemetry.sketch import sketch_init, sketch_update

    sketch_on = tele.sketch is not None
    alerts_on = tele.alerts is not None
    has_valid = valid_tr is not None
    rows = jnp.arange(r)
    sk0 = (jax.vmap(lambda _: sketch_init(tele.sketch, names))(rows)
           if sketch_on else None)
    al0 = (jax.vmap(lambda _: alert_init(tele.alerts))(rows)
           if alerts_on else None)

    def step(carry, xs_t):
        sk, al = carry
        if has_valid:
            vec_t, lt, co, un, va = xs_t
        else:
            vec_t, lt, co, un = xs_t
        if sketch_on:
            if has_valid:
                sk = jax.vmap(
                    lambda s, v, g: sketch_update(tele.sketch, s, v, valid=g)
                )(sk, vec_t, va)
            else:
                sk = jax.vmap(
                    lambda s, v: sketch_update(tele.sketch, s, v))(sk, vec_t)
        if alerts_on:
            def one(a, lt1, co1, un1, va1=None):
                return alert_step(
                    tele.alerts, a, lag_total=lt1, consumers=co1,
                    unreadable=un1, storm_parts=jnp.float32(0.0),
                    slo_lag=cfg.slo_lag, valid=va1)
            if has_valid:
                al = jax.vmap(one)(al, lt, co, un, va)
            else:
                al = jax.vmap(one)(al, lt, co, un)
        return (sk, al), None

    xs = (vec_w, lag_tot, kk, unread_ct)
    if has_valid:
        xs = xs + (valid_tr,)
    (sk, al), _ = lax.scan(step, (sk0, al0), xs)
    return sk, al


def sweep_fused(policies: Tuple[str, ...], traces: jax.Array, cfg,
                active: Optional[jax.Array] = None,
                valid: Optional[jax.Array] = None,
                initial_lag: Optional[jax.Array] = None,
                record_assign: bool = False) -> Dict[str, dict]:
    """Family-batched fused sweep of heuristic ``policies`` over
    ``traces f32[B, T, N]``.

    Returns ``{policy: field dict}`` with the exact ``LagTrace`` fields
    the unfused ``_simulate`` vmap would produce (``[B, T]`` arrays;
    sketch/alert states with leading ``[B]``), so the engine can splice
    fused rows into a mixed sweep transparently.  With
    ``record_assign=True`` each dict also carries ``assigns i32[B, T, N]``.
    """
    traces = traces.astype(jnp.float32)
    if active is not None:
        active = active.astype(bool)
    b, t, n = traces.shape
    p = len(policies)
    r = p * b
    cfg = cfg.resolve(n)

    tele_cfg = cfg.telemetry if cfg.telemetry_on else None
    obs_on = tele_cfg is not None and (tele_cfg.sketch is not None
                                       or tele_cfg.alerts is not None)
    if cfg.fused_kernel and not obs_on:
        # recorder-free run: the Pallas megakernel advances fused_steps
        # steps per launch with the carry resident in VMEM.  With sketch
        # or alerts on, the XLA fused path below emits the aggregates.
        return _sweep_kernel(policies, traces, cfg, active, initial_lag,
                             record_assign)

    lag_t, asg_t, down_t, kk, prev0 = _fused_wide(
        policies, traces, cfg, active, initial_lag)
    prev_t = jnp.concatenate([prev0[None], asg_t[:-1]], axis=0)
    moved_t = (prev_t >= 0) & (asg_t >= 0) & (asg_t != prev_t)
    blocked_t = down_t > 0
    if active is None:
        act_w = None
        unread_t = blocked_t
    else:
        act_w = jnp.broadcast_to(
            jnp.moveaxis(active, 0, 1)[:, None], (t, p, b, n)).reshape(
                t, r, n)
        unread_t = blocked_t & act_w

    lag_tot = jnp.sum(lag_t, axis=-1)                       # [T, R]
    lag_max = jnp.max(lag_t, axis=-1)
    migs = jnp.sum(moved_t.astype(jnp.int32), axis=-1)
    unread = jnp.sum(unread_t.astype(jnp.int32), axis=-1)

    tele = cfg.telemetry if cfg.telemetry_on else None
    sk = al = None
    if tele is not None and (tele.sketch is not None
                             or tele.alerts is not None):
        names_box = [None]
        if tele.sketch is not None:
            from repro.telemetry.record import record_step

            rate_w = jnp.broadcast_to(
                jnp.moveaxis(traces, 0, 1)[:, None], (t, p, b, n)).reshape(
                    t, r, n)

            def one_vec(rate, new_lag, moved, blocked, k_t, act):
                vec, names_box[0] = record_step(
                    tele, speeds=rate, new_lag=new_lag, moved=moved,
                    blocked=blocked, storm=None, n_consumers=k_t, act_t=act,
                    capacity=cfg.capacity, pstate=jnp.int32(0))
                return vec

            if act_w is None:
                vec_w = jax.vmap(jax.vmap(
                    lambda rt, nl, mv, bl, k_t: one_vec(rt, nl, mv, bl, k_t,
                                                        None)))(
                    rate_w, lag_t, moved_t, unread_t, kk)
            else:
                vec_w = jax.vmap(jax.vmap(one_vec))(
                    rate_w, lag_t, moved_t, unread_t, kk, act_w)
        else:
            vec_w = jnp.zeros((t, 1), jnp.float32)   # alerts-only: unused
        valid_tr = None
        if valid is not None:
            valid_tr = jnp.broadcast_to(
                valid.astype(bool).T[:, None], (t, p, b)).reshape(t, r)
        sk, al = _obs_states(tele, cfg, names_box[0], vec_w, lag_tot,
                             kk, unread, valid_tr, r)

    out: Dict[str, dict] = {}
    for pi, name in enumerate(policies):
        fields = dict(
            lag_total=_shape_pb(lag_tot, p, b)[pi],
            lag_max=_shape_pb(lag_max, p, b)[pi],
            consumers=_shape_pb(kk, p, b)[pi],
            migrations=_shape_pb(migs, p, b)[pi],
            unreadable=_shape_pb(unread, p, b)[pi],
            telemetry=None,
            sketch=None if sk is None else jax.tree_util.tree_map(
                lambda a: a.reshape((p, b) + a.shape[1:])[pi], sk),
            incidents=None if al is None else jax.tree_util.tree_map(
                lambda a: a.reshape((p, b) + a.shape[1:])[pi], al),
        )
        if record_assign:
            fields["assigns"] = asg_t.reshape(
                t, p, b, n)[:, pi].transpose(1, 0, 2)       # [B, T, N]
        out[name] = fields
    return out


def _sweep_kernel(policies, traces, cfg, active, initial_lag, record_assign):
    """Fused path via the Pallas megakernel (``cfg.fused_kernel``): one
    launch per policy advances ``fused_steps`` steps per grid block with
    the carry resident in VMEM (interpret mode on CPU).  Recorder-free:
    the engine routes telemetry-on runs through the XLA fused path."""
    from repro.kernels.loop_fused import loop_fused_batch

    out: Dict[str, dict] = {}
    for name in policies:
        hyper = get_spec(name, backend="jax").hyperparams
        tot, mx, cons, migs, unread, asg = loop_fused_batch(
            traces, strategy=hyper["strategy"],
            decreasing=bool(hyper["decreasing"]), capacity=cfg.capacity,
            dt=cfg.dt, migration_steps=cfg.migration_steps,
            fused_steps=cfg.fused_steps, active=active,
            initial_lag=initial_lag)
        fields = dict(lag_total=tot, lag_max=mx, consumers=cons,
                      migrations=migs, unreadable=unread,
                      telemetry=None, sketch=None, incidents=None)
        if record_assign:
            fields["assigns"] = asg
        out[name] = fields
    return out


def simulate_fused(trace: jax.Array, initial_lag: jax.Array, policy: str,
                   cfg, active: Optional[jax.Array] = None,
                   record_assign: bool = False,
                   valid: Optional[jax.Array] = None):
    """Single-stream fused run, mirroring ``engine._simulate``'s contract
    (returns a ``LagTrace`` of ``[T]`` arrays, or ``(trace, assigns)``).
    """
    from repro.lagsim.engine import LagTrace

    fields = sweep_fused(
        (policy,), trace[None], cfg,
        active=None if active is None else active[None],
        valid=None if valid is None else valid[None],
        initial_lag=initial_lag, record_assign=record_assign)[policy]
    assigns = fields.pop("assigns", None)
    out = LagTrace(**jax.tree_util.tree_map(lambda a: a[0], fields))
    return (out, assigns[0]) if record_assign else out


def _smoke() -> None:      # pragma: no cover - exercised by CI, not pytest
    """CI fused smoke: jnp fused == unfused bit-for-bit on a masked
    lifecycle workload, and the interpret-mode megakernel == the fused
    engine (its pinned oracle) on the same run."""
    import numpy as np

    from repro.core.scenarios import generate_masked_scenario
    from repro.lagsim.engine import LagSimConfig, sweep_lag

    pols = ("NF", "FFD", "BFD", "WF")
    speeds, act = generate_masked_scenario(
        "topic_lifecycle", jax.random.key(0), 2, 33, 6)
    base = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
    ref = sweep_lag(pols, speeds, base, active=act)
    for cfg, label in (
            (LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2,
                          fused_steps=8), "fused engine"),
            (LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2,
                          fused_steps=8, fused_kernel=True),
             "fused megakernel")):
        got = sweep_lag(pols, speeds, cfg, active=act)
        for f in ("lag_total", "lag_max", "consumers", "migrations",
                  "unreadable"):
            a, b_ = np.asarray(getattr(got, f)), np.asarray(getattr(ref, f))
            assert np.array_equal(a, b_), (
                f"{label}: field {f} diverged from the unfused oracle")
        print(f"fused smoke OK: {label} == unfused bit-for-bit "
              f"({len(pols)} policies, masked lifecycle, T % K != 0)")


if __name__ == "__main__":      # pragma: no cover
    _smoke()
