"""Token pipeline: deterministic synthetic shards -> bin-packed loader pool
-> fixed-shape (inputs, labels) batches, with resumable state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import modified_any_fit, group_view


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    shard_id: int
    seed: int
    rate: float = 1.0          # relative throughput (item size for packing)


class SyntheticShard:
    """Deterministic infinite token stream (stands in for a tokenized file).

    Tokens are drawn from a per-shard PRNG stream; ``state`` is the number of
    tokens consumed, so checkpoint/restore resumes exactly.
    """

    def __init__(self, spec: ShardSpec, vocab_size: int):
        self.spec = spec
        self.vocab = vocab_size
        self.offset = 0

    def take(self, n: int) -> np.ndarray:
        # counter-based: regenerate from the absolute offset (seekable)
        out = np.empty(n, np.int32)
        BLK = 65536
        pos = self.offset
        got = 0
        while got < n:
            blk_idx = pos // BLK
            rng = np.random.default_rng((self.spec.seed, blk_idx))
            blk = rng.integers(0, self.vocab, size=BLK, dtype=np.int32)
            lo = pos % BLK
            take = min(BLK - lo, n - got)
            out[got:got + take] = blk[lo:lo + take]
            got += take
            pos += take
        self.offset = pos
        return out

    def state(self) -> int:
        return self.offset

    def seek(self, offset: int) -> None:
        self.offset = int(offset)


class LoaderPool:
    """Assign shards to loader workers with the Modified Best Fit packer.

    ``capacity`` is one loader's ingest rate; the pool size (bin count) is
    decided by the packer, and re-packs keep shards sticky to their loader
    (low Rscore = few shard reopenings, which on a real FS means fewer
    cold reads).
    """

    def __init__(self, shards: Sequence[ShardSpec], capacity: float):
        self.shards = list(shards)
        self.capacity = float(capacity)
        self.assignment: Dict[int, int] = {}
        self.repack()

    def repack(self, rates: Optional[Mapping[int, float]] = None) -> int:
        speeds = {s.shard_id: (rates or {}).get(s.shard_id, s.rate)
                  for s in self.shards}
        res = modified_any_fit(speeds, self.capacity,
                               group_view(self.assignment), fit="best",
                               sort_key="max_partition")
        self.assignment = dict(res.pid_to_bin)
        return res.n_bins

    def loader_of(self, shard_id: int) -> int:
        return self.assignment[shard_id]

    def n_loaders(self) -> int:
        return len(set(self.assignment.values()))


class TokenPipeline:
    """Round-robin over shards into fixed (batch, seq+1) token blocks;
    yields {"inputs": (B, S), "labels": (B, S)} next-token pairs."""

    def __init__(self, batch_size: int, seq_len: int, vocab_size: int,
                 n_shards: int = 16, seed: int = 0,
                 loader_capacity: float = 4.0):
        specs = [ShardSpec(i, seed * 1000 + i, rate=1.0 + (i % 3))
                 for i in range(n_shards)]
        self.pool = LoaderPool(specs, capacity=loader_capacity)
        self.shards = [SyntheticShard(s, vocab_size) for s in specs]
        self.batch_size = batch_size
        self.seq_len = seq_len
        self._next_shard = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        need = self.seq_len + 1
        rows = []
        for _ in range(self.batch_size):
            sh = self.shards[self._next_shard]
            self._next_shard = (self._next_shard + 1) % len(self.shards)
            rows.append(sh.take(need))
        block = np.stack(rows)                     # (B, S+1)
        return {"inputs": block[:, :-1].astype(np.int32),
                "labels": block[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- resumable state ----------------------------------------------------
    def state(self) -> Dict:
        return {"offsets": [s.state() for s in self.shards],
                "next_shard": self._next_shard}

    def load_state(self, state: Dict) -> None:
        for s, off in zip(self.shards, state["offsets"]):
            s.seek(off)
        self._next_shard = int(state["next_shard"])
