"""Training data pipeline.

Shards (synthetic deterministic token streams standing in for files) are
assigned to loader workers by the same bin-packing autoscaler that drives
serving: shard throughput (bytes/s measured by the monitor abstraction) are
the item sizes, loader ingest capacity is the bin size.  The controller
re-packs when shard rates drift -- the paper's technique applied to the
training input path.
"""
from .pipeline import LoaderPool, ShardSpec, SyntheticShard, TokenPipeline

__all__ = ["LoaderPool", "ShardSpec", "SyntheticShard", "TokenPipeline"]
