"""Built-in policy registrations: the paper's 12 packers (both backends),
the annealing optimizers, and the reactive baselines.

Registration order is load-bearing -- ``list_policies`` reports it and the
benchmarks key their row order off it:

  NF NFD FF FFD BF BFD WF WFD        (Sec. II-B classical, heuristic)
  MWF MBF MWFP MBFP                  (Sec. IV-B Algorithm 1, sticky)
  KEDA_LAG RATE_THRESHOLD            (idealized reactive baselines)
  KEDA_LAG_REAL CLOUD_RUN_CPU_LAG    (control-plane-real reactive scalers)
  ANNEAL ANNEAL_STICKY               (2024 follow-up optimizers)

Every packer name is registered twice -- backend ``py`` wraps the
reference implementation (``binpack.py`` / ``modified.py``), backend
``jax`` the jitted ``lax.scan`` port (``jaxpack.py``) -- and the
cross-backend parity tests in ``tests/test_jaxpack.py`` iterate exactly
this both-backends set.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import binpack, modified
from repro.core.assignment import group_view
from repro.core.jaxpack import modified_any_fit_jax, pack_jax

from . import register

# ---------------------------------------------------------------------------
# optimizer-policy constants (shared with the lagsim shim)
# ---------------------------------------------------------------------------
ANNEAL_STICKY_LAMBDA = 4.0      # R-score weight of ANNEAL_STICKY
ANNEAL_CHAINS = 6               # chains per decision step
ANNEAL_STEPS = 48               # anneal steps per decision step

# identity of each classical member: name -> (fit strategy, decreasing)
CLASSICAL_SPECS = (
    ("NF", "next", False), ("NFD", "next", True),
    ("FF", "first", False), ("FFD", "first", True),
    ("BF", "best", False), ("BFD", "best", True),
    ("WF", "worst", False), ("WFD", "worst", True),
)
# identity of each Modified Any Fit member: name -> (fit, consumer sort key)
MODIFIED_SPECS = (
    ("MWF", "worst", "cumulative"), ("MBF", "best", "cumulative"),
    ("MWFP", "worst", "max_partition"), ("MBFP", "best", "max_partition"),
)


# ---------------------------------------------------------------------------
# packer -> Policy adapters
# ---------------------------------------------------------------------------

def _jax_packing_policy(packer, capacity):
    """Scan-safe Policy over a jax one-shot packer: each step repacks the
    current speeds with the previous assignment as ``prev`` (sticky
    naming), exactly like the controller's REASSIGN state.  ``active``
    masks partitions that do not currently exist (they pack to ``NEG``)."""

    def init(n_partitions: int):
        return jnp.int32(0)            # stateless; prev_assign is the memory

    def step(speeds, lag, prev_assign, state, active=None):
        res = packer(speeds, prev_assign, capacity, active=active)
        return res.bin_of, res.n_bins, state

    return init, step


def _py_packing_policy(packer, capacity, **kwargs):
    """Reference-backend Policy: same protocol on numpy arrays, delegating
    to the dict-based reference packer.  Masked partitions are simply
    dropped from the speed map -- the reference packers' native notion of
    a partition that does not exist."""

    def init(n_partitions: int):
        return None

    def step(speeds, lag, prev_assign, state, active=None):
        speeds = np.asarray(speeds)
        prev = np.asarray(prev_assign)
        act = (np.ones(speeds.shape[0], bool) if active is None
               else np.asarray(active, bool))
        sp = {j: float(w) for j, w in enumerate(speeds) if act[j]}
        prev_map = {j: int(c) for j, c in enumerate(prev)
                    if int(c) >= 0 and act[j]}
        res = packer(sp, float(capacity), prev=prev_map, **kwargs)
        assign = np.full(speeds.shape[0], -1, np.int32)
        for pid, cid in res.pid_to_bin.items():
            assign[pid] = cid
        return assign, np.int32(res.n_bins), state

    return init, step


# ---------------------------------------------------------------------------
# Sec. II-B classical heuristics (family "heuristic", both backends)
# ---------------------------------------------------------------------------

def _register_classical(name: str, strategy: str, decreasing: bool) -> None:
    hyper = {"strategy": strategy, "decreasing": decreasing, "sticky": True}
    summary = (f"{'offline decreasing ' if decreasing else 'online '}"
               f"{strategy}-fit any-fit heuristic")

    def jax_packer(speeds, prev, capacity, active=None):
        return pack_jax(speeds, prev, capacity, strategy=strategy,
                        decreasing=decreasing, active=active)

    # the one-shot py packer IS the reference entry (no re-wrapping: fixes
    # to binpack propagate to every registry consumer)
    @register(name, family="heuristic", backend="py", hyperparams=hyper,
              packer=binpack.CLASSICAL[name], paper_section="II-B",
              summary=summary)
    def _build_py(n, capacity, *, strategy=strategy, decreasing=decreasing,
                  sticky=True):
        def packer(speeds, cap, prev=None, **_):
            return binpack.pack(speeds, cap, strategy=strategy,
                                decreasing=decreasing, prev=prev,
                                sticky=sticky)
        return _py_packing_policy(packer, capacity)

    @register(name, family="heuristic", backend="jax", hyperparams=hyper,
              packer=jax_packer, paper_section="II-B", summary=summary)
    def _build_jax(n, capacity, *, strategy=strategy, decreasing=decreasing,
                   sticky=True):
        def packer(speeds, prev, cap, active=None):
            return pack_jax(speeds, prev, cap, strategy=strategy,
                            decreasing=decreasing, sticky=sticky,
                            active=active)
        return _jax_packing_policy(packer, capacity)


# ---------------------------------------------------------------------------
# Sec. IV-B Algorithm 1 / IV-C sticky naming (family "sticky", both backends)
# ---------------------------------------------------------------------------

def _register_modified(name: str, fit: str, sort_key: str) -> None:
    hyper = {"fit": fit, "sort_key": sort_key}
    summary = (f"Modified Any Fit: {fit}-fit insert, consumers sorted by "
               f"{sort_key.replace('_', ' ')}")

    def jax_packer(speeds, prev, capacity, active=None):
        return modified_any_fit_jax(speeds, prev, capacity, fit=fit,
                                    sort_key=sort_key, active=active)

    # the one-shot py packer IS the reference entry (no re-wrapping)
    @register(name, family="sticky", backend="py", hyperparams=hyper,
              packer=modified.MODIFIED[name], paper_section="IV-B/IV-C",
              summary=summary)
    def _build_py(n, capacity, *, fit=fit, sort_key=sort_key):
        def packer(speeds, cap, prev=None, **_):
            group = group_view(prev) if prev is not None else None
            return modified.modified_any_fit(speeds, cap, group, fit=fit,
                                             sort_key=sort_key)
        return _py_packing_policy(packer, capacity)

    @register(name, family="sticky", backend="jax", hyperparams=hyper,
              packer=jax_packer, paper_section="IV-B/IV-C", summary=summary)
    def _build_jax(n, capacity, *, fit=fit, sort_key=sort_key):
        def packer(speeds, prev, cap, active=None):
            return modified_any_fit_jax(speeds, prev, cap, fit=fit,
                                        sort_key=sort_key, active=active)
        return _jax_packing_policy(packer, capacity)


for _name, _strategy, _dec in CLASSICAL_SPECS:
    _register_classical(_name, _strategy, _dec)
for _name, _fit, _key in MODIFIED_SPECS:
    _register_modified(_name, _fit, _key)


# ---------------------------------------------------------------------------
# reactive baselines (family "reactive", jax backend)
# ---------------------------------------------------------------------------

def _reactive_policy(kind: str, n: int, capacity, *, lag_threshold,
                     target_utilization, max_consumers, scale_down_patience):
    """KEDA-style reactive scaler: desired consumer count from a lag or
    rate threshold, eager ``partition % n`` assignment (Kafka's eager
    round-robin rebalance), immediate scale-up, patience-gated
    scale-down.  With an ``active`` mask, dead partitions contribute no
    lag/rate signal and take no round-robin seat (live partitions are
    ranked by position among the live set, so an all-active mask
    reproduces the unmasked ``pid % n`` assignment exactly)."""
    pid = jnp.arange(n, dtype=jnp.int32)
    if max_consumers is None:
        max_consumers = n
    if lag_threshold is None:
        lag_threshold = 2.0 * capacity
    max_c = jnp.int32(max_consumers)
    patience = jnp.int32(scale_down_patience)

    def init(n_partitions: int):
        return (jnp.int32(1), jnp.int32(0))     # (n_current, under_count)

    def step(speeds, lag, prev_assign, state, active=None):
        n_cur, under = state
        if active is not None:
            act = active.astype(bool)
            speeds = jnp.where(act, speeds, 0.0)
            lag = None if lag is None else jnp.where(act, lag, 0.0)
        lag_want = jnp.ceil(jnp.sum(lag) / lag_threshold)
        rate_want = jnp.ceil(jnp.sum(speeds)
                             / (target_utilization * capacity))
        if kind == "lag":
            want = lag_want
        elif kind == "rate":
            want = rate_want
        else:                   # "cpu_lag": KEDA multi-trigger semantics --
            want = jnp.maximum(lag_want, rate_want)   # max over triggers
        want = jnp.clip(want.astype(jnp.int32), 1, max_c)
        under = jnp.where(want < n_cur, under + 1, jnp.int32(0))
        go_down = under >= patience
        n_new = jnp.where(want > n_cur, want,
                          jnp.where(go_down, want, n_cur))
        under = jnp.where(go_down, jnp.int32(0), under)
        if active is None:
            assign = pid % n_new
        else:
            rank = jnp.cumsum(act.astype(jnp.int32)) - 1   # pid among live
            assign = jnp.where(act, rank % n_new, jnp.int32(-1))
        return assign, n_new, (n_new, under)

    return init, step


@register("KEDA_LAG", family="reactive", backend="jax",
          hyperparams={"lag_threshold": None, "target_utilization": 0.75,
                       "max_consumers": None, "scale_down_patience": 3},
          paper_section="reactive baseline",
          summary="KEDA lagThreshold rule: consumers = "
                  "ceil(total_lag / lag_threshold)")
def _build_keda_lag(n, capacity, *, lag_threshold=None,
                    target_utilization=0.75, max_consumers=None,
                    scale_down_patience=3):
    return _reactive_policy(
        "lag", n, capacity, lag_threshold=lag_threshold,
        target_utilization=target_utilization, max_consumers=max_consumers,
        scale_down_patience=scale_down_patience)


@register("RATE_THRESHOLD", family="reactive", backend="jax",
          hyperparams={"lag_threshold": None, "target_utilization": 0.75,
                       "max_consumers": None, "scale_down_patience": 3},
          paper_section="reactive baseline",
          summary="consumption-rate target: consumers = "
                  "ceil(total_rate / (target_utilization * C))")
def _build_rate_threshold(n, capacity, *, lag_threshold=None,
                          target_utilization=0.75, max_consumers=None,
                          scale_down_patience=3):
    return _reactive_policy(
        "rate", n, capacity, lag_threshold=lag_threshold,
        target_utilization=target_utilization, max_consumers=max_consumers,
        scale_down_patience=scale_down_patience)


# ---------------------------------------------------------------------------
# realistic reactive scalers (family "reactive", jax backend):
# the idealized rules above, run behind a faithful control plane
# ---------------------------------------------------------------------------

#: control-plane knobs every REAL scaler family declares (step units);
#: ``repro.lagsim`` overrides them from ``LagSimConfig.control_plane``
_KEDA_REAL_CP = {"polling_interval": 3, "observation_delay": 1,
                 "actuation_delay": 1, "cooldown_period": 20,
                 "min_replicas": 1, "max_replicas": None, "warmup_steps": 2}
_CLOUD_RUN_CP = {"polling_interval": 5, "observation_delay": 2,
                 "actuation_delay": 2, "cooldown_period": 10,
                 "min_replicas": 1, "max_replicas": None, "warmup_steps": 3}


def _real_reactive(kind, n, capacity, *, lag_threshold, target_utilization,
                   max_consumers, scale_down_patience, **cp_knobs):
    # lazy import, mirroring _anneal_policy: keeps registry import cheap
    # and free of a registry <-> lagsim cycle
    from repro.lagsim.controlplane import ControlPlaneConfig, wrap_policy
    inner = _reactive_policy(
        kind, n, capacity, lag_threshold=lag_threshold,
        target_utilization=target_utilization, max_consumers=max_consumers,
        scale_down_patience=scale_down_patience)
    return wrap_policy(*inner, ControlPlaneConfig(**cp_knobs))


@register("KEDA_LAG_REAL", family="reactive", backend="jax",
          hyperparams={"lag_threshold": None, "target_utilization": 0.75,
                       "max_consumers": None, "scale_down_patience": 3,
                       **_KEDA_REAL_CP},
          paper_section="reactive baseline",
          summary="KEDA lagThreshold rule behind a faithful control plane "
                  "(pollingInterval/cooldownPeriod/warm-up storm)")
def _build_keda_lag_real(n, capacity, *, lag_threshold=None,
                         target_utilization=0.75, max_consumers=None,
                         scale_down_patience=3, **cp_knobs):
    cp = {**_KEDA_REAL_CP, **cp_knobs}
    return _real_reactive(
        "lag", n, capacity, lag_threshold=lag_threshold,
        target_utilization=target_utilization, max_consumers=max_consumers,
        scale_down_patience=scale_down_patience, **cp)


@register("CLOUD_RUN_CPU_LAG", family="reactive", backend="jax",
          hyperparams={"lag_threshold": None, "target_utilization": 0.75,
                       "max_consumers": None, "scale_down_patience": 3,
                       **_CLOUD_RUN_CP},
          paper_section="reactive baseline",
          summary="Cloud Run style CPU+lag dual trigger (max of both) "
                  "behind a slow-polling control plane")
def _build_cloud_run_cpu_lag(n, capacity, *, lag_threshold=None,
                             target_utilization=0.75, max_consumers=None,
                             scale_down_patience=3, **cp_knobs):
    cp = {**_CLOUD_RUN_CP, **cp_knobs}
    return _real_reactive(
        "cpu_lag", n, capacity, lag_threshold=lag_threshold,
        target_utilization=target_utilization, max_consumers=max_consumers,
        scale_down_patience=scale_down_patience, **cp)


# ---------------------------------------------------------------------------
# global optimizers (family "optimizer", jax backend)
# ---------------------------------------------------------------------------

def _anneal_policy(capacity, *, lam, chains, steps):
    """Best-of-chains simulated-annealing repack once per decision step.
    The PRNG key rides in the policy state (split every step), so
    trajectories are deterministic per stream and the whole sweep stays
    scan-safe.  ``active`` masks items out of the anneal: no chain may
    move them, they count toward no bin, and they come back as ``NEG``."""
    from repro.opt.anneal import anneal_assign

    def init(n_partitions: int):
        # per-policy deterministic key; split every step so consecutive
        # decisions explore independently while staying scan-safe
        return jax.random.key(0x0A11EA1)

    def step(speeds, lag, prev_assign, key, active=None):
        key, sub = jax.random.split(key)
        assign, n_bins = anneal_assign(speeds, prev_assign, capacity, sub,
                                       lam=lam, chains=chains, steps=steps,
                                       active=active)
        return assign, n_bins, key

    return init, step


@register("ANNEAL", family="optimizer", backend="jax",
          hyperparams={"lam": 0.0, "chains": ANNEAL_CHAINS,
                       "steps": ANNEAL_STEPS},
          paper_section="2024 follow-up",
          summary="batched SA minimizing consumer count alone "
                  "(rebalance-oblivious upper baseline)")
def _build_anneal(n, capacity, *, lam=0.0, chains=ANNEAL_CHAINS,
                  steps=ANNEAL_STEPS):
    return _anneal_policy(capacity, lam=lam, chains=chains, steps=steps)


@register("ANNEAL_STICKY", family="optimizer", backend="jax",
          hyperparams={"lam": ANNEAL_STICKY_LAMBDA, "chains": ANNEAL_CHAINS,
                       "steps": ANNEAL_STEPS},
          paper_section="2024 follow-up",
          summary="batched SA over bins + lambda*Rscore "
                  "(stability-priced optimizer)")
def _build_anneal_sticky(n, capacity, *, lam=ANNEAL_STICKY_LAMBDA,
                         chains=ANNEAL_CHAINS, steps=ANNEAL_STEPS):
    return _anneal_policy(capacity, lam=lam, chains=chains, steps=steps)
