"""One policy registry for every assignment policy in the repo.

The paper's whole argument is a race between assignment policies: the
Sec. II-B classical heuristics, the Sec. IV-B/IV-C sticky Modified Any
Fit family, the global optimizers of the 2024 follow-up, and the
reactive scalers (KEDA-style) they displace.  Historically each family
shipped its own interface; this package is the single extension point
they all register through:

* ``PolicySpec``   -- name, family (``heuristic|sticky|optimizer|
  reactive``), backend (``py|jax``), hyperparams, and pointers to the
  builder / raw packer, plus the paper section it reproduces.
* ``Policy``       -- the scan-safe protocol every policy satisfies::

      init(n) -> state                                (pytree)
      step(speeds, lag, prev, state, active=None)
          -> (assign i32[N], n_consumers i32, state')

  ``active`` (bool[N], optional) is the partition-existence mask of the
  variable-N fleet contract: an inactive partition must come back
  assigned ``-1``, contribute to no consumer's load, and never raise the
  consumer count; ``active=None`` means all partitions exist and must
  reproduce the pre-mask behaviour bit-for-bit.  ``jax``-backend
  policies are pure ``jax.lax`` control flow, so a ``Policy`` can run
  inside the lag twin's jitted scan; ``py``-backend policies satisfy the
  same signature on numpy arrays (reference semantics, used by the
  controller and the parity tests).

  A policy may publish custom per-step counters to the in-loop flight
  recorder by wrapping its state as
  ``repro.telemetry.CounterState(counters=f32[K], inner=state,
  names=(...))``: when ``LagSimConfig.telemetry`` is on, the engine
  appends those named counters to every recorded step's channel vector
  (see ``repro.telemetry.record``).  Policies that don't care keep
  returning their plain state -- the recorder only adds its base
  channels then.
* ``register``     -- decorator that publishes a builder
  ``(n, capacity, **hyperparams) -> (init, step)`` under a spec.
* ``make_policy``  -- ``name -> Policy`` with hyperparameter overrides.
* ``list_policies`` / ``get_spec`` -- discovery, filterable by family
  and backend, in registration order (which benchmarks rely on).
* ``packer_for``   -- the raw one-shot packer of a heuristic/sticky
  policy (``py``: dict-based ``PackResult``; ``jax``: ``PackedJax``).

Built-in policies live in ``repro.registry.builtin`` and are loaded
lazily on first lookup, so importing this module is cheap and free of
import cycles.  Adding a policy is one decorated builder::

    from repro.registry import register

    @register("MY_POLICY", family="reactive", backend="jax",
              hyperparams={"gain": 2.0}, paper_section="--",
              summary="toy proportional scaler")
    def _build(n, capacity, *, gain=2.0):
        def init(n): ...
        def step(speeds, lag, prev, state): ...
        return init, step
"""
from __future__ import annotations

import dataclasses
import types
from typing import (Any, Callable, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple, Union)

FAMILIES: Tuple[str, ...] = ("heuristic", "sticky", "optimizer", "reactive")
BACKENDS: Tuple[str, ...] = ("py", "jax")
#: the families whose members are one-shot bin packers (have a ``packer``)
PACKER_FAMILIES: Tuple[str, ...] = ("heuristic", "sticky")


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Registered metadata of one (name, backend) policy variant."""

    name: str                      # canonical upper-case name
    family: str                    # heuristic | sticky | optimizer | reactive
    backend: str                   # py | jax
    hyperparams: Mapping[str, Any]  # default knobs, overridable in make_policy
    builder: Callable              # (n, capacity, **hyperparams) -> (init, step)
    packer: Optional[Callable] = None   # raw one-shot packer (packer families)
    paper_section: str = ""        # e.g. "II-B", "IV-C", "2024 follow-up"
    summary: str = ""              # one-line description


class Policy(NamedTuple):
    """A built policy: the scan-safe (init, step) pair plus its spec.

    ``step(speeds, lag, prev, state, active=None)`` -- the trailing
    ``active`` mask is optional (all-active when omitted); builders must
    accept it even if they ignore partitions' existence.
    """

    init: Callable[[int], Any]
    step: Callable[..., Tuple[Any, Any, Any]]
    spec: PolicySpec


_REGISTRY: Dict[Tuple[str, str], PolicySpec] = {}
_ORDER: List[str] = []          # canonical names in first-registration order
_BUILTINS_LOADED = False
_BUILTINS_LOADING = False       # reentrancy guard: builtin.py calls register()


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED, _BUILTINS_LOADING
    if _BUILTINS_LOADED or _BUILTINS_LOADING:
        return
    _BUILTINS_LOADING = True
    try:
        from . import builtin  # noqa: F401  (registers on import)
    except BaseException:
        # a failed builtin import must stay loud on retry, never leave a
        # silently empty/partial registry behind
        _REGISTRY.clear()
        _ORDER.clear()
        raise
    finally:
        _BUILTINS_LOADING = False
    _BUILTINS_LOADED = True


def register(name: str, *, family: str, backend: str,
             hyperparams: Optional[dict] = None,
             packer: Optional[Callable] = None,
             paper_section: str = "", summary: str = "") -> Callable:
    """Decorator: publish ``builder(n, capacity, **hyperparams)`` as policy
    ``name`` on ``backend``.  Duplicate (name, backend) pairs are an error:
    the registry is the single source of truth for what a name means."""
    # load builtins first so user registrations collide loudly (and land
    # after the builtins in registration order, which list_policies reports)
    _ensure_builtins()
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; have {FAMILIES}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    canonical = name.upper()

    def deco(builder: Callable) -> Callable:
        key = (canonical, backend)
        if key in _REGISTRY:
            raise ValueError(
                f"policy {canonical!r} already registered for backend "
                f"{backend!r}")
        _REGISTRY[key] = PolicySpec(
            name=canonical, family=family, backend=backend,
            hyperparams=types.MappingProxyType(dict(hyperparams or {})),
            builder=builder, packer=packer, paper_section=paper_section,
            summary=summary)
        if canonical not in _ORDER:
            _ORDER.append(canonical)
        return builder

    return deco


def _family_tuple(family: Union[None, str, Sequence[str]]) -> Optional[Tuple[str, ...]]:
    if family is None:
        return None
    fams = (family,) if isinstance(family, str) else tuple(family)
    for f in fams:
        if f not in FAMILIES:
            raise ValueError(f"unknown family {f!r}; have {FAMILIES}")
    return fams


def list_policies(family: Union[None, str, Sequence[str]] = None,
                  backend: Optional[str] = None) -> Tuple[str, ...]:
    """Registered policy names, in registration order, optionally filtered
    by ``family`` (a name or a tuple of names) and/or ``backend``."""
    _ensure_builtins()
    fams = _family_tuple(family)
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    out = []
    for name in _ORDER:
        for bk in BACKENDS:
            spec = _REGISTRY.get((name, bk))
            if spec is None:
                continue
            if fams is not None and spec.family not in fams:
                continue
            if backend is not None and spec.backend != backend:
                continue
            out.append(name)
            break
    return tuple(out)


def get_spec(name: str, backend: Optional[str] = None) -> PolicySpec:
    """The ``PolicySpec`` of ``name``; with ``backend=None`` the ``jax``
    variant is preferred (it is the scan-safe one) and ``py`` is the
    fallback."""
    _ensure_builtins()
    canonical = name.upper()
    backends = (backend,) if backend is not None else ("jax", "py")
    for bk in backends:
        spec = _REGISTRY.get((canonical, bk))
        if spec is not None:
            return spec
    registered_on = tuple(bk for bk in BACKENDS
                          if (canonical, bk) in _REGISTRY)
    if registered_on:
        raise ValueError(
            f"policy {canonical!r} is not registered for backend "
            f"{backend!r} (available backends: {registered_on})")
    raise ValueError(
        f"unknown policy {name!r}; have {sorted(set(_ORDER))}")


def make_policy(name: str, n: int, capacity: float = 1.0, *,
                backend: Optional[str] = None, strict: bool = True,
                **overrides) -> Policy:
    """Build the ``Policy`` (init/step pair) for ``name`` over ``n``
    partitions of consumer capacity ``capacity``.

    ``overrides`` update the spec's default hyperparams.  With
    ``strict=True`` (default) an override the spec does not declare raises
    ``ValueError`` -- typos must not silently vanish; ``strict=False``
    ignores extras, so a caller may pass one uniform knob set to every
    policy (the lag twin does exactly that).
    """
    spec = get_spec(name, backend=backend)
    hyper = dict(spec.hyperparams)
    unknown = set(overrides) - set(hyper)
    if unknown and strict:
        raise ValueError(
            f"policy {spec.name!r} does not take hyperparams "
            f"{sorted(unknown)}; declared: {sorted(hyper)}")
    hyper.update({k: v for k, v in overrides.items() if k in hyper})
    init, step = spec.builder(n, capacity, **hyper)
    return Policy(init=init, step=step, spec=spec)


def packer_for(name: str, backend: str = "jax") -> Callable:
    """The raw one-shot packer registered for ``name`` on ``backend``.

    ``jax``: ``fn(speeds f32[n], prev i32[n], capacity, active=None) ->
    PackedJax``, scan-safe; ``active`` (bool[n]) masks partitions that do
    not exist (they pack to ``-1``).  ``py``: ``fn(speeds, capacity,
    prev=None, ...) -> PackResult`` on dicts (reference semantics; a
    masked partition is simply absent from the ``speeds`` map).  Policies
    outside the packer families (optimizers, reactive scalers) have no
    one-shot packer and raise ``ValueError``.
    """
    _ensure_builtins()
    spec = _REGISTRY.get((name.upper(), backend))
    if spec is None:
        raise ValueError(
            f"unknown algorithm {name!r} for backend {backend!r}; have "
            f"{sorted(list_policies(family=PACKER_FAMILIES, backend=backend))}")
    if spec.packer is None:
        raise ValueError(
            f"policy {spec.name!r} ({spec.family}) has no one-shot packer")
    return spec.packer


__all__ = [
    "BACKENDS",
    "FAMILIES",
    "PACKER_FAMILIES",
    "Policy",
    "PolicySpec",
    "get_spec",
    "list_policies",
    "make_policy",
    "packer_for",
    "register",
]
