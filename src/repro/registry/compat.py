"""Deprecation shims for the pre-registry policy name tables.

``modified.ALL_ALGORITHMS``, ``jaxpack.ALL_ALGORITHM_NAMES`` and
``lagsim.policies.ALL_POLICY_NAMES`` predate ``repro.registry``; they keep
working through module ``__getattr__`` hooks that forward to the registry
and emit one ``DeprecationWarning`` per attribute per process (pinned by
``tests/test_registry.py``).  New code should call
``repro.registry.list_policies`` / ``packer_for`` instead.
"""
from __future__ import annotations

import warnings
from typing import Set, Tuple

_WARNED: Set[Tuple[str, str]] = set()


def warn_deprecated(module: str, attr: str, replacement: str) -> None:
    """Emit the deprecation warning for ``module.attr`` exactly once per
    process (repeat accesses stay silent so hot loops cannot spam)."""
    key = (module, attr)
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(
        f"{module}.{attr} is deprecated; use {replacement} "
        f"(see repro.registry)", DeprecationWarning, stacklevel=3)


def _reset_deprecation_warnings() -> None:
    """Test hook: make the next access of every shimmed attribute warn
    again."""
    _WARNED.clear()
