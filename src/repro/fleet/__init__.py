"""Fleet execution layer: bucketed, sharded scenario runs.

See ``repro.fleet.runner`` for the design.  Public surface:

* ``FleetRunner``  -- the executor (``sweep`` / ``simulate`` verbs).
* ``FleetConfig``  -- bucket sizes, compile-cache bound, sharding knobs.
* ``FleetSweepResult`` / ``FleetLagResult`` -- per-scenario results in
  input order, sliced back to true shapes.
"""
from .runner import (
    FleetConfig,
    FleetLagResult,
    FleetRunner,
    FleetSweepResult,
)

__all__ = [
    "FleetConfig",
    "FleetLagResult",
    "FleetRunner",
    "FleetSweepResult",
]
