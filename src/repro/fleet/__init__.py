"""Fleet execution layer: bucketed, sharded scenario runs.

See ``repro.fleet.runner`` for the design.  Public surface:

* ``FleetRunner``  -- the executor (``sweep`` / ``simulate`` verbs).
* ``FleetConfig``  -- bucket sizes, compile-cache bound, sharding knobs.
* ``FleetSweepResult`` / ``FleetLagResult`` -- per-scenario results in
  input order, sliced back to true shapes.
* ``FleetProgress`` -- live observability snapshot handed to the
  optional ``progress`` callback of ``FleetRunner.simulate``.
"""
from .runner import (
    FleetConfig,
    FleetLagResult,
    FleetProgress,
    FleetRunner,
    FleetSweepResult,
)

__all__ = [
    "FleetConfig",
    "FleetLagResult",
    "FleetProgress",
    "FleetRunner",
    "FleetSweepResult",
]
