"""Sharded fleet execution: shape-bucketed, device-parallel scenario runs.

The mask contract (``active: bool[T, N]``, see ``core/jaxpack.py`` and
``lagsim/engine.py``) makes *padding exact*: a padded partition is just an
inactive one (packs to ``NEG``, produces no backlog, opens no bin) and a
padded timestep is sliced off the trailing end of every trajectory.  This
module turns that into a production execution layer:

* **Bucketing** -- scenarios of heterogeneous shape ``(T_i, N_i)`` are
  padded up to the next configured bucket ``(T_b, N_b)`` and grouped, so
  a fleet of thousands of ragged scenarios compiles a handful of XLA
  programs instead of one per shape.
* **Bounded jit cache** -- one compiled executable per (verb, policy
  tuple, bucket, config) key, kept in an LRU of ``max_compile_cache``
  entries.  Churning shapes can never grow the cache without bound; the
  eviction/hit/miss counters are exported via ``FleetRunner.stats()``.
* **Batch sharding** -- the scenario (batch) axis is sharded across
  devices with ``jax.sharding.NamedSharding`` over a 1-D mesh; every
  per-scenario scan is independent, so the sharded result equals the
  single-device result exactly.  Works on CPU hosts via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI smoke
  asserts the equality) and on real multi-device backends unchanged.

``FleetRunner`` is the single execution path of the repo's drivers:
``repro.api.sweep`` / ``repro.api.simulate``, the lag-SLO benchmark and
``benchmarks/paper_eval.py`` all route through it.

Caveat: the stochastic ANNEAL policies draw their Gumbel noise over a
``(chains, N * M)`` plane, so *padding* N changes the PRNG stream and
therefore the (still valid) trajectories; padding is bit-exact for every
deterministic policy (all 12 packers and both reactive baselines), and
*sharding* is bit-exact for every policy, stochastic or not.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.jaxpack import _sweep_streams_impl
from repro.lagsim.engine import LagSimConfig, _sweep_impl
from repro.lagsim.metrics import slo_summary
from repro.telemetry.alerts import (AlertConfig, AlertState, Incident,
                                    decode_incidents, incident_counts,
                                    incident_matrix)
from repro.telemetry.record import TelemetryFrame
from repro.telemetry.sketch import (SketchConfig, SketchState, SketchSummary,
                                    merge_summaries, summaries_from_state)
from repro.telemetry.spans import instant as _instant
from repro.telemetry.spans import span as _span


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Static knobs of a ``FleetRunner``.

    ``t_buckets`` / ``n_buckets``: ascending padded sizes; a scenario's
    ``T`` (``N``) is rounded up to the smallest bucket that holds it, or
    left exact when it exceeds every bucket (or when the tuple is empty
    -- the default, which never pads and buckets by exact shape).
    ``max_compile_cache``: LRU bound on live compiled executables.
    ``shard``: shard the batch axis across ``devices`` (default: all of
    ``jax.devices()``); the batch is padded with all-inactive dummy
    scenarios up to a device multiple, then sliced back.
    """

    t_buckets: Tuple[int, ...] = ()
    n_buckets: Tuple[int, ...] = ()
    max_compile_cache: int = 16
    shard: bool = True
    devices: Optional[Tuple[Any, ...]] = None

    def __post_init__(self):
        if self.max_compile_cache < 1:
            raise ValueError(
                f"max_compile_cache must be >= 1, got {self.max_compile_cache}")
        for name in ("t_buckets", "n_buckets"):
            b = getattr(self, name)
            if tuple(sorted(b)) != tuple(b):
                raise ValueError(f"{name} must be ascending, got {b}")


@dataclasses.dataclass
class FleetSweepResult:
    """Per-scenario packing traces, in input order (arrays ``[A, T_i]``)."""

    algorithms: Tuple[str, ...]
    bins: List[np.ndarray]          # i32[A, T_i]
    rscores: List[np.ndarray]       # f32[A, T_i]
    migrations: List[np.ndarray]    # i32[A, T_i]

    def stacked(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Stack a uniform-``T`` fleet into ``[A, B, T]`` arrays."""
        return (np.stack(self.bins, axis=1), np.stack(self.rscores, axis=1),
                np.stack(self.migrations, axis=1))


#: trajectory fields of ``FleetLagResult`` (the stackable [P, T_i] arrays;
#: ``policies`` is static and ``telemetry`` holds per-scenario frames)
_TRAJ_FIELDS = ("lag_total", "lag_max", "consumers", "migrations",
                "unreadable")


@dataclasses.dataclass
class FleetLagResult:
    """Per-scenario closed-loop trajectories, in input order ([P, T_i])."""

    policies: Tuple[str, ...]
    lag_total: List[np.ndarray]     # f32[P, T_i]
    lag_max: List[np.ndarray]       # f32[P, T_i]
    consumers: List[np.ndarray]     # i32[P, T_i]
    migrations: List[np.ndarray]    # i32[P, T_i]
    unreadable: List[np.ndarray]    # i32[P, T_i]
    #: per-scenario recorder frames (channels ``[P, T_i, K]``), present
    #: iff the config's ``TelemetryConfig`` is on; decode each with
    #: ``EventStream.from_frame``
    telemetry: Optional[List[TelemetryFrame]] = None
    #: per-scenario final streaming-sketch states (leading ``[P]`` policy
    #: axis, numpy leaves) plus the per-scenario *resolved*
    #: ``SketchConfig`` (``hist_max`` filled at the scenario's true N) --
    #: padded bucket steps are valid-gated out, so a padded scenario's
    #: state is bit-identical to a direct ``simulate_lag`` run's
    sketch: Optional[List[SketchState]] = None
    sketch_configs: Optional[List[SketchConfig]] = None
    #: per-scenario final alert states (leading ``[P]``); see
    #: :meth:`scenario_incidents`
    incidents: Optional[List[AlertState]] = None
    alert_config: Optional[AlertConfig] = None
    dt: float = 1.0

    def sketch_summaries(self, scenario: int
                         ) -> List[Tuple[Tuple[int, ...], SketchSummary]]:
        """Finalized ``[(policy_index,), SketchSummary]`` pairs for one
        scenario (requires the run's ``SketchConfig`` to have been on)."""
        if self.sketch is None:
            raise ValueError(
                "this fleet run carried no sketches; enable them via "
                "TelemetryConfig(sketch=SketchConfig(...))")
        return summaries_from_state(self.sketch[scenario],
                                    self.sketch_configs[scenario])

    def scenario_incidents(self, scenario: int) -> List[Incident]:
        """Decoded incidents for one scenario (``index`` = policy)."""
        if self.incidents is None:
            raise ValueError(
                "this fleet run carried no alerting; enable it via "
                "TelemetryConfig(alerts=AlertConfig(rules=default_rules()))")
        return decode_incidents(self.incidents[scenario], self.alert_config,
                                dt=self.dt)

    def stacked(self) -> Dict[str, np.ndarray]:
        """Stack a uniform-``T`` fleet into ``[P, B, T]`` arrays."""
        return {name: np.stack(getattr(self, name), axis=1)
                for name in _TRAJ_FIELDS}

    def summarize(self, cfg: LagSimConfig,
                  stacked: Optional[Dict[str, np.ndarray]] = None
                  ) -> Dict[str, np.ndarray]:
        """SLO summary of a uniform-``T`` fleet under ``cfg`` (the single
        reduction ``lagsim.metrics`` defines; arrays ``[P, B]``).  Pass a
        precomputed ``stacked()`` dict to avoid re-stacking."""
        st = self.stacked() if stacked is None else stacked
        return slo_summary(st["lag_total"], st["consumers"],
                           st["migrations"],
                           slo_lag=cfg.slo_lag_or_default, dt=cfg.dt)


@dataclasses.dataclass
class FleetFitness:
    """One fitness-oracle evaluation for the adversarial scenario search
    (arrays ``[P, B]``: policy x scenario, in input order).

    ``fitness = violation_frac + incident_weight * incidents / T`` --
    the SLO-violation fraction plus (optionally) the per-step rate of
    burn/invariant incidents, so a genome is rewarded both for lag the
    SLO sees and for the pages it causes."""

    policies: Tuple[str, ...]
    violation_frac: np.ndarray      # f32[P, B]
    incidents: np.ndarray           # f32[P, B] total incidents per stream
    fitness: np.ndarray             # f32[P, B]
    incident_weight: float = 0.0


@dataclasses.dataclass
class FleetProgress:
    """One live observability snapshot, handed to the ``progress``
    callback of :meth:`FleetRunner.simulate` after each bucket group
    finishes (host-side only -- the compiled programs never see it).

    ``sketch`` is the merge of every finished scenario's summaries
    (``None`` until sketches exist, or when scenarios use different
    histogram edges and cannot merge); ``incidents`` the cumulative
    per-rule incident counts."""

    done: int                           # scenarios finished so far
    total: int                          # scenarios in this call
    bucket: str                         # bucket label just finished
    sketch: Optional[SketchSummary] = None
    incidents: Dict[str, int] = dataclasses.field(default_factory=dict)


def _round_up(x: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if b >= x:
            return b
    return x


class FleetRunner:
    """Bucketed, sharded executor for scenario fleets.

    One runner owns one bounded compile cache; share it across calls (the
    benchmarks keep a module-level runner) so repeated bucket shapes hit
    warm executables.
    """

    def __init__(self, config: FleetConfig = FleetConfig()):
        self.config = config
        # key -> (executable, bucket label); the label follows the entry
        # so its eviction is charged to the right bucket
        self._cache: "OrderedDict[Any, Tuple[Callable, str]]" = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._bucket_counts: Dict[Tuple[int, int], int] = {}
        self._per_bucket: Dict[str, Dict[str, int]] = {}
        self._dispatched: set = set()

    # -- observability ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Snapshot: cache behaviour and scenarios executed per bucket.

        ``per_bucket`` breaks the global hit/miss/eviction counters down
        by padded bucket label (``"TxN"``).
        """
        return {
            "cache_entries": len(self._cache),
            "cache_hits": self._hits,
            "cache_misses": self._misses,
            "cache_evictions": self._evictions,
            "buckets": {f"{t}x{n}": c
                        for (t, n), c in sorted(self._bucket_counts.items())},
            "per_bucket": {b: dict(c)
                           for b, c in sorted(self._per_bucket.items())},
            "devices": len(self._devices()),
        }

    def reset(self) -> None:
        """Zero every counter (global and per-bucket) without dropping
        compiled executables -- warm cache, fresh statistics.  Use before
        a measured region; ``clear()`` drops the executables too."""
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._bucket_counts.clear()
        self._per_bucket.clear()

    def clear(self) -> None:
        self._cache.clear()
        self._dispatched.clear()

    # -- internals ----------------------------------------------------------

    def _devices(self) -> Tuple[Any, ...]:
        return (self.config.devices if self.config.devices is not None
                else tuple(jax.devices()))

    def _bucket_stats(self, bucket: str) -> Dict[str, int]:
        return self._per_bucket.setdefault(
            bucket, {"hits": 0, "misses": 0, "evictions": 0})

    def _compiled(self, key: Any, build: Callable[[], Callable],
                  args: Tuple[Any, ...], bucket: str) -> Callable:
        """Executable for ``key``, compiling ahead-of-time on a miss.

        The jitted builder is lowered and compiled *here* (jax AOT), not
        lazily on first call -- so ``fleet.trace_lower`` / ``fleet.compile``
        spans carry the true compile cost and the first ``fleet.dispatch``
        is a dispatch, nothing more (BENCH_fleet first-call times used to
        conflate the two).
        """
        entry = self._cache.get(key)
        if entry is not None:
            self._hits += 1
            self._bucket_stats(bucket)["hits"] += 1
            _instant("fleet.cache_hit", bucket=bucket)
            self._cache.move_to_end(key)
            return entry[0]
        self._misses += 1
        self._bucket_stats(bucket)["misses"] += 1
        _instant("fleet.cache_miss", bucket=bucket)
        fn = build()
        with _span("fleet.trace_lower", bucket=bucket):
            lowered = fn.lower(*args)
        with _span("fleet.compile", bucket=bucket):
            compiled = lowered.compile()
        while len(self._cache) >= self.config.max_compile_cache:
            _, (_, gone) = self._cache.popitem(last=False)
            self._evictions += 1
            self._bucket_stats(gone)["evictions"] += 1
            _instant("fleet.cache_evict", bucket=gone)
        self._cache[key] = (compiled, bucket)
        return compiled

    def _dispatch(self, key: Any, fn: Callable, args: Tuple[Any, ...],
                  bucket: str):
        """Run the executable under a ``fleet.dispatch`` span; the span's
        ``first`` arg marks the first dispatch of this cache key (still
        distinct from compile, which happened in ``_compiled``)."""
        first = key not in self._dispatched
        self._dispatched.add(key)
        with _span("fleet.dispatch", bucket=bucket, first=first):
            return jax.block_until_ready(fn(*args))

    def _normalize(self, scenarios, active) -> List[Tuple[jax.Array,
                                                          Optional[jax.Array]]]:
        """-> list of (speeds f32[T, N], active bool[T, N] | None)."""
        if hasattr(scenarios, "ndim") and getattr(scenarios, "ndim") == 3:
            sp = jnp.asarray(scenarios, jnp.float32)
            if active is not None:
                ac = jnp.asarray(active, bool)
                if ac.shape != sp.shape:
                    raise ValueError(
                        f"active mask has shape {ac.shape} but the scenario "
                        f"batch has shape {sp.shape}")
                return [(sp[b], ac[b]) for b in range(sp.shape[0])]
            return [(sp[b], None) for b in range(sp.shape[0])]
        if active is not None:
            raise ValueError(
                "pass per-scenario masks as (speeds, active) pairs when "
                "scenarios is a sequence")
        items: List[Tuple[jax.Array, Optional[jax.Array]]] = []
        for s in scenarios:
            if isinstance(s, tuple):
                sp, ac = s
                sp = jnp.asarray(sp, jnp.float32)
                ac = None if ac is None else jnp.asarray(ac, bool)
                if ac is not None and ac.shape != sp.shape:
                    raise ValueError(
                        f"scenario mask shape {ac.shape} != speeds shape "
                        f"{sp.shape}")
            else:
                sp, ac = jnp.asarray(s, jnp.float32), None
            if sp.ndim != 2:
                raise ValueError(
                    f"each scenario must be f32[T, N]; got shape {sp.shape}")
            items.append((sp, ac))
        return items

    def _group(self, items, extra_key=lambda sp, ac: ()):
        """Bucket scenarios: {(Tb, Nb, use_mask, *extra): [(idx, sp, ac)]}.

        ``use_mask`` is True as soon as any member needs padding or
        carries an explicit mask -- then every member gets one (all-True
        where absent), keeping the whole group under a single jaxpr.
        """
        groups: Dict[Any, List[Tuple[int, jax.Array, Optional[jax.Array]]]] = {}
        metas = []
        for idx, (sp, ac) in enumerate(items):
            t, n = sp.shape
            tb = _round_up(t, self.config.t_buckets)
            nb = _round_up(n, self.config.n_buckets)
            metas.append((idx, sp, ac, tb, nb))
        masked_buckets = {
            (tb, nb) for (_, sp, ac, tb, nb) in metas
            if ac is not None or (tb, nb) != sp.shape
        }
        for idx, sp, ac, tb, nb in metas:
            use_mask = (tb, nb) in masked_buckets
            key = (tb, nb, use_mask) + tuple(extra_key(sp, ac))
            groups.setdefault(key, []).append((idx, sp, ac))
            self._bucket_counts[(tb, nb)] = (
                self._bucket_counts.get((tb, nb), 0) + 1)
        return groups

    def _pad_and_stack(self, members, tb: int, nb: int, use_mask: bool,
                       n_dev: int):
        """-> (speeds [Bp, tb, nb], active [Bp, tb, nb] | None)."""
        sps, acs = [], []
        for _, sp, ac in members:
            t, n = sp.shape
            pad = ((0, tb - t), (0, nb - n))
            sps.append(jnp.pad(sp, pad))
            if use_mask:
                ac = jnp.ones((t, n), bool) if ac is None else ac
                acs.append(jnp.pad(ac, pad))        # pads with False
        n_pad = (-len(sps)) % n_dev
        for _ in range(n_pad):          # dummy scenarios for the shard grid
            sps.append(jnp.zeros((tb, nb), jnp.float32))
            if use_mask:
                acs.append(jnp.zeros((tb, nb), bool))
        speeds = jnp.stack(sps)
        active = jnp.stack(acs) if use_mask else None
        return speeds, active

    def _uniform_batch(self, scenarios, active, n_dev: int):
        """Fast-path probe: an already-stacked ``f32[B, T, N]`` batch that
        needs no bucket padding and no batch padding (B a device multiple)
        passes straight through, skipping the per-scenario unbatch /
        re-pad / re-stack round trip of the ragged path -- this is the
        common case of ``repro.api`` and the benchmark drivers."""
        if not (hasattr(scenarios, "ndim") and getattr(scenarios, "ndim") == 3):
            return None
        b, t, n = scenarios.shape
        if (_round_up(t, self.config.t_buckets) != t
                or _round_up(n, self.config.n_buckets) != n
                or b % n_dev):
            return None
        sp = jnp.asarray(scenarios, jnp.float32)
        ac = None
        if active is not None:
            ac = jnp.asarray(active, bool)
            if ac.shape != sp.shape:
                raise ValueError(
                    f"active mask has shape {ac.shape} but the scenario "
                    f"batch has shape {sp.shape}")
        self._bucket_counts[(t, n)] = self._bucket_counts.get((t, n), 0) + b
        return sp, ac

    def _device_put(self, speeds, active):
        devices = self._devices()
        if not self.config.shard or len(devices) <= 1:
            return speeds, active
        mesh = Mesh(np.asarray(devices), ("batch",))
        sharding = NamedSharding(mesh, PartitionSpec("batch"))
        speeds = jax.device_put(speeds, sharding)
        if active is not None:
            active = jax.device_put(active, sharding)
        return speeds, active

    def _n_dev(self) -> int:
        devices = self._devices()
        return len(devices) if self.config.shard else 1

    # -- verbs --------------------------------------------------------------

    def _run_sweep(self, algorithms, speeds, act, capacity, tb: int, nb: int):
        speeds, act = self._device_put(speeds, act)
        key = ("sweep", algorithms, tb, nb, act is not None, speeds.shape[0])
        bucket = f"{tb}x{nb}"
        args = (speeds, jnp.float32(capacity), act)
        fn = self._compiled(key, lambda: jax.jit(functools.partial(
            _sweep_streams_impl, algorithms)), args, bucket)
        res = self._dispatch(key, fn, args, bucket)
        return (np.asarray(res.bins), np.asarray(res.rscores),
                np.asarray(res.migrations))

    def sweep(self, algorithms: Sequence[str], scenarios, capacity: float = 1.0,
              *, active=None) -> FleetSweepResult:
        """Run every algorithm over a fleet of scenarios.

        ``scenarios``: f32[B, T, N] (optionally with ``active`` bool
        [B, T, N]) or a sequence of ``f32[T_i, N_i]`` / ``(speeds,
        active)`` entries of heterogeneous shape.  Results come back
        sliced to each scenario's true ``(T_i,)`` length, in input order.
        """
        with _span("fleet.sweep", algorithms=len(algorithms)):
            return self._sweep(algorithms, scenarios, capacity, active)

    def _sweep(self, algorithms, scenarios, capacity, active
               ) -> FleetSweepResult:
        algorithms = tuple(a.upper() for a in algorithms)
        n_dev = self._n_dev()
        fast = self._uniform_batch(scenarios, active, n_dev)
        if fast is not None:
            speeds, act = fast
            b, t, n = speeds.shape
            bins, rs, migs = self._run_sweep(algorithms, speeds, act,
                                             capacity, t, n)
            return FleetSweepResult(
                algorithms=algorithms,
                bins=[bins[:, i] for i in range(b)],
                rscores=[rs[:, i] for i in range(b)],
                migrations=[migs[:, i] for i in range(b)])
        items = self._normalize(scenarios, active)
        out_bins: List[Optional[np.ndarray]] = [None] * len(items)
        out_rs: List[Optional[np.ndarray]] = [None] * len(items)
        out_migs: List[Optional[np.ndarray]] = [None] * len(items)
        for (tb, nb, use_mask), members in self._group(items).items():
            speeds, act = self._pad_and_stack(members, tb, nb, use_mask,
                                              n_dev)
            bins, rs, migs = self._run_sweep(algorithms, speeds, act,
                                             capacity, tb, nb)
            for slot, (idx, sp, _) in enumerate(members):
                t = sp.shape[0]
                out_bins[idx] = bins[:, slot, :t]
                out_rs[idx] = rs[:, slot, :t]
                out_migs[idx] = migs[:, slot, :t]
        return FleetSweepResult(algorithms=algorithms, bins=out_bins,
                                rscores=out_rs, migrations=out_migs)

    _SIM_FIELDS = _TRAJ_FIELDS

    def _run_sim(self, policies, speeds, act, rcfg, tb: int, nb: int,
                 valid=None):
        speeds, act = self._device_put(speeds, act)
        # `valid is not None` is part of the key: the gated program takes
        # a third operand, so it must never share an executable with the
        # ungated one even at identical shapes
        key = ("simulate", policies, tb, nb, act is not None,
               valid is not None, rcfg, speeds.shape[0])
        bucket = f"{tb}x{nb}"
        if valid is None:
            args = (speeds, act)
            build = lambda: jax.jit(
                lambda tr, ac: _sweep_impl(policies, tr, rcfg, ac))
        else:
            args = (speeds, act, valid)
            build = lambda: jax.jit(
                lambda tr, ac, va: _sweep_impl(policies, tr, rcfg, ac, va))
        fn = self._compiled(key, build, args, bucket)
        res = self._dispatch(key, fn, args, bucket)
        arrays = {f: np.asarray(getattr(res, f)) for f in self._SIM_FIELDS}
        tele = res.telemetry
        if tele is not None:
            tele = TelemetryFrame(
                channels=np.asarray(tele.channels),   # [P, B, T, K]
                steps=np.asarray(tele.steps),         # [P, B, T]
                count=np.asarray(tele.count),         # [P, B]
                names=tele.names)
        to_np = lambda obj: (None if obj is None else
                             jax.tree_util.tree_map(np.asarray, obj))
        return arrays, tele, to_np(res.sketch), to_np(res.incidents)

    @staticmethod
    def _scenario_frame(tele: TelemetryFrame, slot: int,
                        t: int) -> TelemetryFrame:
        """Slice one scenario's frame out of a batch frame, trimming the
        padded timesteps (the recorder ran tb steps; only the scenario's
        true first ``t`` are its history)."""
        return TelemetryFrame(
            channels=tele.channels[:, slot, :t],
            steps=tele.steps[:, slot, :t],
            count=np.minimum(tele.count[:, slot], t),
            names=tele.names)

    @staticmethod
    def _scenario_state(state, slot: int):
        """Slice one scenario's sketch/alert state (leading [P, B] axes)
        out of a batch; unlike frames there is no T axis to trim -- the
        padded steps never touched the state (valid gating)."""
        return jax.tree_util.tree_map(lambda a: a[:, slot], state)

    @staticmethod
    def _obs_on(cfg: LagSimConfig) -> bool:
        """True when the run carries scan-state observability (sketches
        or alerts) that bucket padding must valid-gate."""
        return cfg.telemetry_on and (cfg.telemetry.sketch is not None
                                     or cfg.telemetry.alerts is not None)

    def simulate(self, policies: Sequence[str], scenarios,
                 cfg: LagSimConfig = LagSimConfig(), *,
                 active=None,
                 progress: Optional[Callable[[FleetProgress], None]] = None
                 ) -> FleetLagResult:
        """Closed-loop lag twin over a fleet of scenarios.

        The config is resolved at each scenario's *true* partition count
        (so e.g. the reactive ``max_consumers`` default clamps at the
        real N, not the padded bucket), which keeps padded runs exact.
        ``cfg.control_plane`` (scaler friction emulation) rides inside
        the hashable config, so it participates in bucket/compile-cache
        keys automatically and bucketing stays behavior-preserving.
        ``cfg.fused_steps`` / ``cfg.fused_kernel`` (the multi-step fused
        path, ``repro.lagsim.fused``) ride the same resolved config, so
        fused and unfused runs never share an executable and a padded
        fused run equals the direct one bit-for-bit; an N-padded bucket
        above ``FUSED_MAX_PARTITIONS`` falls back to the per-step scan
        inside the same program, which is equally exact.
        With ``cfg.telemetry`` on, the result carries one recorder frame
        per scenario (``FleetLagResult.telemetry``), sliced to true
        length like every other trajectory.  Streaming sketches/alerts
        ride the same config (``telemetry.sketch`` / ``telemetry.alerts``)
        and come back as per-scenario states; padded bucket steps are
        gated out of their updates, so padding stays exact for them too.

        ``progress`` (optional, host-side) is called after each bucket
        group with a :class:`FleetProgress` snapshot -- merged sketch
        summary and cumulative incident counts so far; this is what
        ``examples/live_dashboard.py`` streams.
        """
        with _span("fleet.simulate", policies=len(policies)):
            return self._simulate(policies, scenarios, cfg, active, progress)

    def _simulate(self, policies, scenarios, cfg: LagSimConfig,
                  active, progress=None) -> FleetLagResult:
        if cfg.telemetry is not None and cfg.telemetry.ring is not None:
            raise ValueError(
                "TelemetryConfig.ring is not supported through FleetRunner: "
                "a ring holds the *last* ring steps, which for a T-padded "
                "scenario are padding, not history; use the full-history "
                "recorder (ring=None) here, or run simulate_lag directly "
                "for ring capture")
        policies = tuple(p.upper() for p in policies)
        alert_cfg = (cfg.telemetry.alerts if cfg.telemetry_on else None)
        n_dev = self._n_dev()
        fast = self._uniform_batch(scenarios, active, n_dev)
        if fast is not None:
            speeds, act = fast
            b, t, n = speeds.shape
            rcfg = cfg.resolve(n)
            arrays, tele, sk, inc = self._run_sim(policies, speeds, act,
                                                  rcfg, t, n)
            sk_cfg = None if rcfg.telemetry is None else rcfg.telemetry.sketch
            result = FleetLagResult(policies=policies, **{
                f: [arrays[f][:, i] for i in range(b)]
                for f in self._SIM_FIELDS},
                telemetry=None if tele is None else [
                    self._scenario_frame(tele, i, t) for i in range(b)],
                sketch=None if sk is None else [
                    self._scenario_state(sk, i) for i in range(b)],
                sketch_configs=None if sk is None else [sk_cfg] * b,
                incidents=None if inc is None else [
                    self._scenario_state(inc, i) for i in range(b)],
                alert_config=alert_cfg, dt=cfg.dt)
            if progress is not None:
                progress(self._progress_snapshot(result, b, b, f"{t}x{n}"))
            return result
        items = self._normalize(scenarios, active)
        obs_on = self._obs_on(cfg)
        outs: Dict[str, List[Optional[np.ndarray]]] = {
            f: [None] * len(items) for f in self._SIM_FIELDS}
        tele_out: List[Optional[TelemetryFrame]] = [None] * len(items)
        sk_out: List[Optional[SketchState]] = [None] * len(items)
        sk_cfg_out: List[Optional[SketchConfig]] = [None] * len(items)
        inc_out: List[Optional[AlertState]] = [None] * len(items)
        any_tele = any_sk = any_inc = False
        done = 0
        result = FleetLagResult(policies=policies, **outs,
                                telemetry=None, alert_config=alert_cfg,
                                dt=cfg.dt)
        groups = self._group(items,
                             extra_key=lambda sp, ac: (cfg.resolve(sp.shape[1]),))
        for (tb, nb, use_mask, rcfg), members in groups.items():
            speeds, act = self._pad_and_stack(members, tb, nb, use_mask,
                                              n_dev)
            valid = None
            if obs_on:
                # bool[B, T]: a scenario's true steps, False on T-padding
                # and on the all-dummy rows added for the shard grid
                rows = [np.arange(tb) < sp.shape[0] for _, sp, _ in members]
                rows += [np.zeros(tb, bool)] * (speeds.shape[0] - len(rows))
                valid = jnp.asarray(np.stack(rows))
            arrays, tele, sk, inc = self._run_sim(policies, speeds, act,
                                                  rcfg, tb, nb, valid)
            sk_cfg = None if rcfg.telemetry is None else rcfg.telemetry.sketch
            for slot, (idx, sp, _) in enumerate(members):
                t = sp.shape[0]
                for f in self._SIM_FIELDS:
                    outs[f][idx] = arrays[f][:, slot, :t]
                if tele is not None:
                    any_tele = True
                    tele_out[idx] = self._scenario_frame(tele, slot, t)
                if sk is not None:
                    any_sk = True
                    sk_out[idx] = self._scenario_state(sk, slot)
                    sk_cfg_out[idx] = sk_cfg
                if inc is not None:
                    any_inc = True
                    inc_out[idx] = self._scenario_state(inc, slot)
            done += len(members)
            if progress is not None:
                result.sketch = sk_out if any_sk else None
                result.sketch_configs = sk_cfg_out if any_sk else None
                result.incidents = inc_out if any_inc else None
                progress(self._progress_snapshot(result, done, len(items),
                                                 f"{tb}x{nb}"))
        result.telemetry = tele_out if any_tele else None
        result.sketch = sk_out if any_sk else None
        result.sketch_configs = sk_cfg_out if any_sk else None
        result.incidents = inc_out if any_inc else None
        return result

    def fitness(self, policies: Sequence[str], scenarios,
                cfg: LagSimConfig = LagSimConfig(), *, active=None,
                incident_weight: float = 0.0) -> FleetFitness:
        """Fitness-batch entrypoint of the adversarial scenario search
        (``repro.scenarios.search``): one scenario batch -> per-(policy,
        scenario) SLO-violation fitness, arrays ``[P, B]``.

        Routes through :meth:`simulate`, so a search that keeps
        ``(B, T, N, cfg)`` constant across generations compiles its
        oracle once and dispatches a warm executable thereafter (the
        bounded LRU cache is the generation loop's flywheel).
        ``incident_weight > 0`` folds per-step incident counts into the
        fitness and requires ``cfg.telemetry.alerts`` to be on.
        """
        if incident_weight and not (cfg.telemetry_on
                                    and cfg.telemetry.alerts is not None):
            raise ValueError(
                "incident_weight > 0 needs alerting in the loop: pass a "
                "LagSimConfig with telemetry=TelemetryConfig(alerts="
                "AlertConfig(rules=default_rules()))")
        with _span("fleet.fitness", policies=len(policies)):
            res = self._simulate(tuple(p.upper() for p in policies),
                                 scenarios, cfg, active)
            stacked = res.stacked()
            summ = res.summarize(cfg, stacked=stacked)
            vf = np.asarray(summ["violation_frac"], np.float32)    # [P, B]
            steps = stacked["lag_total"].shape[-1]
            if res.incidents is not None:
                inc = np.stack([incident_matrix(st)
                                for st in res.incidents], axis=1)  # [P, B]
            else:
                inc = np.zeros_like(vf)
            fit = vf + np.float32(incident_weight) * inc / max(steps, 1)
            return FleetFitness(policies=res.policies, violation_frac=vf,
                                incidents=inc,
                                fitness=fit.astype(np.float32),
                                incident_weight=float(incident_weight))

    @staticmethod
    def _progress_snapshot(result: FleetLagResult, done: int, total: int,
                           bucket: str) -> FleetProgress:
        """Merge whatever has finished into one live snapshot."""
        merged = None
        if result.sketch is not None:
            summaries = []
            for i, st in enumerate(result.sketch):
                if st is not None:
                    summaries.extend(
                        s for _, s in summaries_from_state(
                            st, result.sketch_configs[i]))
            if summaries:
                try:
                    merged = merge_summaries(summaries)
                except ValueError:
                    merged = None       # heterogeneous edges: unmergeable
        counts: Dict[str, int] = {}
        if result.incidents is not None:
            for st in result.incidents:
                if st is not None:
                    for rule, c in incident_counts(st).items():
                        counts[rule] = counts.get(rule, 0) + c
        return FleetProgress(done=done, total=total, bucket=bucket,
                             sketch=merged, incidents=counts)
