"""Minimal message-broker substrate with the Kafka semantics the paper's
system relies on (Sec. V):

* ordered, append-only partitions; messages delivered in production order;
* per-(group, partition) committed offsets with seek/commit;
* at most one consumer of a group reading a partition at a time (enforced);
* ``describe_log_dirs()`` -- byte size per TopicPartition (the AdminClient
  call the monitor uses);
* a simulated clock so the 30 s monitor window and consumer wait times run
  deterministically and fast in tests.

This is an in-process stand-in for the data plane; the control plane built
on top of it (monitor/controller/consumers) is the paper's actual system.
"""
from .clock import Clock, SimClock, WallClock
from .sim import Broker, ConsumerHandle, Partition, Topic, TopicPartition

__all__ = [
    "Clock",
    "SimClock",
    "WallClock",
    "Broker",
    "ConsumerHandle",
    "Partition",
    "Topic",
    "TopicPartition",
]
