"""Clock abstraction: simulated time for tests/benchmarks, wall time for
deployments."""
from __future__ import annotations

import time


class Clock:
    def now(self) -> float:  # seconds
        raise NotImplementedError


class WallClock(Clock):
    def now(self) -> float:
        return time.monotonic()


class SimClock(Clock):
    """Deterministic, manually advanced clock."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0
        self._t += dt
        return self._t
