"""In-process broker with Kafka's ordering/offset/single-reader semantics."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Tuple

from .clock import Clock, SimClock


class TopicPartition(NamedTuple):
    """String-integer pair identifying any partition within a topic (Sec. V-A)."""

    topic: str
    partition: int


@dataclasses.dataclass
class Record:
    offset: int
    timestamp: float
    key: Optional[str]
    value: Any
    nbytes: int


class Partition:
    """Append-only ordered log."""

    def __init__(self):
        self._log: List[Record] = []
        self._bytes = 0

    def append(self, timestamp: float, value: Any, key: Optional[str] = None,
               nbytes: Optional[int] = None) -> int:
        if nbytes is None:
            nbytes = len(value) if isinstance(value, (bytes, str)) else 64
        rec = Record(len(self._log), timestamp, key, value, int(nbytes))
        self._log.append(rec)
        self._bytes += rec.nbytes
        return rec.offset

    def read(self, offset: int, max_records: Optional[int] = None,
             max_bytes: Optional[int] = None) -> List[Record]:
        out: List[Record] = []
        nb = 0
        for rec in self._log[offset:]:
            if max_records is not None and len(out) >= max_records:
                break
            if max_bytes is not None and out and nb + rec.nbytes > max_bytes:
                break
            out.append(rec)
            nb += rec.nbytes
        return out

    @property
    def end_offset(self) -> int:
        return len(self._log)

    @property
    def size_bytes(self) -> int:
        return self._bytes

    def bytes_between(self, lo_offset: int, hi_offset: int) -> int:
        return sum(r.nbytes for r in self._log[lo_offset:hi_offset])


class Topic:
    def __init__(self, name: str, n_partitions: int):
        self.name = name
        self.partitions: List[Partition] = [Partition() for _ in range(n_partitions)]

    def ensure(self, idx: int) -> Partition:
        while idx >= len(self.partitions):
            self.partitions.append(Partition())
        return self.partitions[idx]


class ConsumerHandle:
    """A group member's read handle over its assigned partitions.

    The broker enforces the paper's invariant: at most one member of a group
    reads a partition at any time (two-phase migration relies on this).
    """

    def __init__(self, broker: "Broker", group: str, member: str):
        self.broker = broker
        self.group = group
        self.member = member
        self.assigned: set = set()
        self.closed = False

    def assign(self, tp: TopicPartition) -> None:
        self.broker._acquire(self.group, self.member, tp)
        self.assigned.add(tp)

    def unassign(self, tp: TopicPartition) -> None:
        if tp in self.assigned:
            self.broker._release(self.group, self.member, tp)
            self.assigned.discard(tp)

    def poll(self, max_bytes: int) -> Dict[TopicPartition, List[Record]]:
        """Fetch records round-robin from assigned partitions up to max_bytes."""
        out: Dict[TopicPartition, List[Record]] = {}
        budget = max_bytes
        for tp in sorted(self.assigned):
            if budget <= 0:
                break
            part = self.broker.partition(tp)
            off = self.broker.committed(self.group, tp)
            recs = part.read(off, max_bytes=budget)
            if recs:
                out[tp] = recs
                budget -= sum(r.nbytes for r in recs)
        return out

    def commit(self, tp: TopicPartition, offset: int) -> None:
        self.broker.commit(self.group, tp, offset)

    def close(self) -> None:
        for tp in list(self.assigned):
            self.unassign(tp)
        self.closed = True


class Broker:
    def __init__(self, clock: Optional[Clock] = None):
        self.clock = clock or SimClock()
        self.topics: Dict[str, Topic] = {}
        self._offsets: Dict[Tuple[str, TopicPartition], int] = {}
        self._readers: Dict[Tuple[str, TopicPartition], str] = {}

    # -- admin ---------------------------------------------------------------
    def create_topic(self, name: str, n_partitions: int) -> Topic:
        if name not in self.topics:
            self.topics[name] = Topic(name, n_partitions)
        return self.topics[name]

    def partition(self, tp: TopicPartition) -> Partition:
        return self.topics[tp.topic].ensure(tp.partition)

    def describe_log_dirs(self, topics: Optional[Iterable[str]] = None
                          ) -> Dict[TopicPartition, int]:
        """Bytes per TopicPartition -- AdminClient.describeLogDirs() analogue."""
        out: Dict[TopicPartition, int] = {}
        for name, topic in self.topics.items():
            if topics is not None and name not in topics:
                continue
            for i, p in enumerate(topic.partitions):
                out[TopicPartition(name, i)] = p.size_bytes
        return out

    # -- produce/consume -----------------------------------------------------
    def produce(self, tp: TopicPartition, value: Any, key: Optional[str] = None,
                nbytes: Optional[int] = None) -> int:
        return self.partition(tp).append(self.clock.now(), value, key, nbytes)

    def consumer(self, group: str, member: str) -> ConsumerHandle:
        return ConsumerHandle(self, group, member)

    def committed(self, group: str, tp: TopicPartition) -> int:
        return self._offsets.get((group, tp), 0)

    def commit(self, group: str, tp: TopicPartition, offset: int) -> None:
        self._offsets[(group, tp)] = max(offset, self.committed(group, tp))

    def lag(self, group: str, tp: TopicPartition) -> int:
        part = self.partition(tp)
        return part.bytes_between(self.committed(group, tp), part.end_offset)

    def total_lag(self, group: str, topic: str) -> int:
        t = self.topics[topic]
        return sum(self.lag(group, TopicPartition(topic, i))
                   for i in range(len(t.partitions)))

    # -- single-reader enforcement --------------------------------------------
    def _acquire(self, group: str, member: str, tp: TopicPartition) -> None:
        holder = self._readers.get((group, tp))
        if holder is not None and holder != member:
            raise RuntimeError(
                f"partition {tp} already read by {holder!r} in group {group!r}; "
                f"{member!r} must wait for the stop->ack hand-off")
        self._readers[(group, tp)] = member

    def _release(self, group: str, member: str, tp: TopicPartition) -> None:
        if self._readers.get((group, tp)) == member:
            del self._readers[(group, tp)]

    def reader_of(self, group: str, tp: TopicPartition) -> Optional[str]:
        return self._readers.get((group, tp))

    def expel(self, group: str, member: str) -> None:
        """Group-coordinator eviction of a dead member: frees all the
        partitions it held so survivors can take over (committed offsets are
        retained, so no data is lost -- it is re-read from the last commit)."""
        for (g, tp), holder in list(self._readers.items()):
            if g == group and holder == member:
                del self._readers[(g, tp)]
