"""Exact bin-packing oracle: branch-and-bound with Martello-Toth L2 lower
bounds (pure Python, oracle-grade).

The paper's heuristics are never measured against the true optimum; this
module supplies it for small instances.  ``branch_and_bound`` does a DFS
over the decreasing item list, branching each item into every open bin
with a *distinct* load (symmetry breaking) plus one fresh bin, pruning
with the continuous completion bound; the search is exhaustive, so a run
that finishes within the node limit is provably optimal.  ``brute_force``
enumerates all set partitions (restricted-growth strings) and is the
independent comparator the tests pin the oracle against for N <= 8.

Conventions shared with the heuristics (``binpack.py``):

* oversized items (w > C) each take a dedicated overflow bin that nothing
  else ever joins;
* zero-speed items occupy no capacity but do hold bins open;
* feasibility uses a small relative slack ``EPS_REL * C`` so that float32
  packings produced by the JAX heuristics are never judged infeasible by
  the float64 oracle -- the slack makes every bound a valid *lower* bound
  for the heuristics' arithmetic, keeping reported optimality gaps >= 0.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

EPS_REL = 1e-6


def _eps(capacity: float) -> float:
    return EPS_REL * capacity


def _ceil_slack(x: float) -> int:
    """ceil with a tolerance so 2.0000001 (float noise) stays 2."""
    return max(0, int(math.ceil(x - 1e-9)))


def _split_oversized(weights: Sequence[float], capacity: float
                     ) -> Tuple[List[float], int]:
    eps = _eps(capacity)
    regular = [float(w) for w in weights if w <= capacity + eps]
    return regular, len(weights) - len(regular)


def lower_bound_l1(weights: Sequence[float], capacity: float) -> int:
    """Continuous bound: oversized items count one bin each, the rest
    ceil(sum w / C)."""
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    regular, n_over = _split_oversized(weights, capacity)
    return n_over + _ceil_slack(sum(regular) / capacity - EPS_REL)


def lower_bound_l2(weights: Sequence[float], capacity: float) -> int:
    """Martello-Toth L2: max over alpha in [0, C/2] of

        |J1| + |J2| + max(0, ceil((sum_{J3} w - (|J2| C - sum_{J2} w)) / C))

    with J1 = {w > C - alpha}, J2 = {C - alpha >= w > C/2},
    J3 = {C/2 >= w >= alpha}.  Dominates L1; valid for any packing that
    respects capacity up to the shared EPS slack.
    """
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    regular, n_over = _split_oversized(weights, capacity)
    ws = [w for w in regular if w > 0.0]
    best = lower_bound_l1(weights, capacity)
    half = capacity / 2.0
    # L(alpha) is piecewise constant; its breakpoints are the item sizes
    # <= C/2, their complements C - w for big items, and 0 (which counts
    # every item above C/2 as a dedicated bin)
    alphas = sorted({0.0} | {w for w in ws if w <= half}
                    | {capacity - w for w in ws
                       if 0.0 <= capacity - w <= half})
    for alpha in alphas:
        j1 = j2 = 0
        j2_sum = j3_sum = 0.0
        for w in ws:
            if w > capacity - alpha:
                j1 += 1
            elif w > half:
                j2 += 1
                j2_sum += w
            elif w >= alpha:
                j3_sum += w
        free = j2 * capacity - j2_sum
        extra = _ceil_slack((j3_sum - free) / capacity - EPS_REL)
        best = max(best, n_over + j1 + j2 + extra)
    return best


@dataclasses.dataclass
class BnBResult:
    """Outcome of one oracle run.

    ``optimal`` is True iff the search completed, i.e. ``n_bins`` is the
    exact optimum; otherwise ``n_bins`` is the best feasible packing found
    (an upper bound) and ``lower_bound`` a certified lower bound.
    ``assignment[i]`` is the bin index of item ``i`` in the best packing.
    """

    n_bins: int
    lower_bound: int
    optimal: bool
    assignment: List[int]
    nodes: int


def _ffd_seed(order: List[int], weights: Sequence[float], capacity: float,
              eps: float) -> Tuple[int, List[int]]:
    """First-Fit-Decreasing upper bound (order is already decreasing)."""
    loads: List[float] = []
    assign = [0] * len(weights)
    for i in order:
        w = weights[i]
        for b, load in enumerate(loads):
            if load + w <= capacity + eps:
                loads[b] += w
                assign[i] = b
                break
        else:
            assign[i] = len(loads)
            loads.append(w)
    return len(loads), assign


def branch_and_bound(weights: Sequence[float], capacity: float, *,
                     node_limit: Optional[int] = 2_000_000) -> BnBResult:
    """Exact minimum-bin packing of ``weights`` into bins of size
    ``capacity`` (small N; exponential worst case).

    Returns a :class:`BnBResult`; with the default node limit every
    instance the test-suite and benchmarks feed it (N <= ~16) completes,
    i.e. ``optimal`` is True.  Oversized items are pre-assigned dedicated
    overflow bins, zero-weight items are packed greedily at the end (they
    never change the bin count), and the DFS runs over the remaining items
    in non-increasing order with distinct-load symmetry breaking.
    """
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    n = len(weights)
    eps = _eps(capacity)
    weights = [float(w) for w in weights]
    over = [i for i, w in enumerate(weights) if w > capacity + eps]
    zero = [i for i, w in enumerate(weights) if w <= 0.0]
    rest = [i for i in range(n) if i not in set(over) and weights[i] > 0.0]
    rest.sort(key=lambda i: (-weights[i], i))

    lb_root = lower_bound_l2(weights, capacity)
    ub, seed_assign = _ffd_seed(rest, weights, capacity, eps)
    best_bins = ub
    best_assign = list(seed_assign)
    nodes = 0
    complete = True

    rem_suffix = [0.0] * (len(rest) + 1)
    for d in range(len(rest) - 1, -1, -1):
        rem_suffix[d] = rem_suffix[d + 1] + weights[rest[d]]

    loads: List[float] = []
    assign = [0] * n

    def dfs(d: int) -> None:
        nonlocal best_bins, best_assign, nodes, complete
        if node_limit is not None and nodes > node_limit:
            complete = False
            return
        nodes += 1
        if d == len(rest):
            if len(loads) < best_bins:
                best_bins = len(loads)
                best_assign = list(assign)
            return
        # completion bound: bins already open plus the continuous bound on
        # the overflow of remaining weight past the open free space
        free = len(loads) * capacity - sum(loads)
        need = len(loads) + _ceil_slack(
            (rem_suffix[d] - free) / capacity - EPS_REL)
        if max(need, len(loads)) >= best_bins:
            return
        i = rest[d]
        w = weights[i]
        seen = set()
        for b in range(len(loads)):
            load = loads[b]
            if load + w > capacity + eps:
                continue
            key = round(load, 12)
            if key in seen:
                continue            # symmetric branch: same load, same future
            seen.add(key)
            loads[b] += w
            assign[i] = b
            dfs(d + 1)
            loads[b] -= w
        if len(loads) + 1 < best_bins:
            loads.append(w)
            assign[i] = len(loads) - 1
            dfs(d + 1)
            loads.pop()

    dfs(0)

    # zero-weight items ride along in regular bin 0 (they may not join an
    # overflow bin: its load already exceeds C); open one regular bin for
    # them if the DFS used none.  Oversized items then get dedicated
    # overflow bins after the regular ones.
    k_reg = best_bins
    if zero and k_reg == 0:
        k_reg = 1
    for i in zero:
        best_assign[i] = 0
    k = k_reg
    for i in over:
        best_assign[i] = k
        k += 1
    total = k
    return BnBResult(n_bins=total,
                     lower_bound=total if complete else lb_root,
                     optimal=complete, assignment=best_assign, nodes=nodes)


def brute_force(weights: Sequence[float], capacity: float) -> int:
    """Exact optimum by set-partition enumeration (restricted-growth
    strings); the independent comparator for the oracle tests.  O(Bell(N))
    -- use only for N <= ~10.

    A block is feasible iff its weight sum fits the capacity (with the
    shared EPS slack) or it is a singleton oversized item.
    """
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    n = len(weights)
    if n == 0:
        return 0
    eps = _eps(capacity)
    weights = [float(w) for w in weights]
    best = n

    sums: List[float] = []

    def rec(i: int) -> None:
        nonlocal best
        if len(sums) >= best:
            return
        if i == n:
            best = min(best, len(sums))
            return
        w = weights[i]
        for b in range(len(sums)):
            sums[b] += w
            if sums[b] <= capacity + eps:
                rec(i + 1)
            sums[b] -= w
        sums.append(w)
        rec(i + 1)                  # singleton block: always legal (oversized)
        sums.pop()

    rec(0)
    return best
