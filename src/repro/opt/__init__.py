"""Global packing optimizer: exact oracles and batched stochastic search.

Two layers turn the repo's heuristic race into a scored evaluation:

* ``branch_bound`` -- pure-Python exact branch-and-bound with
  Martello-Toth L2 lower bounds (oracle-grade ground truth for small N);
* ``anneal`` / ``pareto`` -- a massively batched simulated-annealing
  optimizer in JAX (thousands of chains, per-chain lambda) whose
  ``bins + lambda * Rscore`` sweep traces cost-vs-R-score Pareto fronts;
  the hot move-evaluation loop is the Pallas kernel
  ``repro.kernels.move_eval``.

``benchmarks/optimality_gap.py`` combines both into per-algorithm
optimality gaps and frontier hypervolumes (``BENCH_opt.json``);
``lagsim.policies`` exposes the annealer as the closed-loop policies
``ANNEAL`` / ``ANNEAL_STICKY``.
"""
from .anneal import (
    AnnealResult,
    anneal_assign,
    anneal_chains,
    anneal_pack,
    assignment_cost,
    name_universe,
)
from .branch_bound import (
    BnBResult,
    branch_and_bound,
    brute_force,
    lower_bound_l1,
    lower_bound_l2,
)
from .pareto import (
    FrontierResult,
    anneal_frontier,
    dominated,
    heuristic_point,
    hypervolume_2d,
    incumbent_assignment,
    optimality_gap,
    pareto_front,
    reference_point,
)

__all__ = [
    "AnnealResult",
    "BnBResult",
    "FrontierResult",
    "anneal_assign",
    "anneal_chains",
    "anneal_frontier",
    "anneal_pack",
    "assignment_cost",
    "branch_and_bound",
    "brute_force",
    "dominated",
    "heuristic_point",
    "hypervolume_2d",
    "incumbent_assignment",
    "lower_bound_l1",
    "lower_bound_l2",
    "name_universe",
    "optimality_gap",
    "pareto_front",
    "reference_point",
]
