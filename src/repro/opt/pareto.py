"""Pareto frontiers over (consumer cost, rebalance cost) and the metrics
that score heuristics against them.

The 2024 follow-up to the paper ("Multi-Objective Optimization of Consumer
Group Autoscaling in Message Broker Systems") frames the autoscaler's real
object of interest as the *frontier* trading consumer count against
rebalance (R-score) cost.  This module traces that frontier with the
batched annealer -- one chain per (lambda, restart), all in one launch --
and provides the plain-numpy reductions the benchmarks report:

* ``pareto_front``     -- non-dominated subset, both objectives minimized;
* ``hypervolume_2d``   -- dominated area w.r.t. a reference point (the
                          standard multi-objective quality indicator);
* ``anneal_frontier``  -- lambda-sweep -> FrontierResult per instance;
* ``optimality_gap``   -- (heuristic - optimal) / optimal bin counts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .anneal import anneal_pack

Point = Tuple[float, float]


def heuristic_point(name: str, speeds, prev, capacity) -> Point:
    """One heuristic's (bins, rscore) position on an instance: repack
    ``speeds`` with ``prev`` via the registered jax packer
    (``repro.registry.packer_for``) and price the moved set by Eq. 10.
    The shared convention for scoring heuristics against frontiers
    (benchmarks and examples alike)."""
    from repro.registry import packer_for

    speeds = np.asarray(speeds, np.float64)
    prev = np.asarray(prev)
    res = packer_for(name, backend="jax")(jnp.asarray(speeds, jnp.float32),
                                          jnp.asarray(prev, jnp.int32),
                                          capacity)
    bin_of = np.asarray(res.bin_of)
    moved = (prev >= 0) & (bin_of != prev)
    return (float(int(res.n_bins)),
            float(speeds[moved].sum()) / float(capacity))


def incumbent_assignment(trace, capacity, t: int,
                         algorithm: str = "BFD") -> np.ndarray:
    """Sticky assignment after iterations ``[0, t)`` of one stream
    ``[T, N]`` under ``algorithm`` -- the canonical ``prev`` for
    mid-trace frontier instances."""
    from repro.registry import packer_for

    trace = np.asarray(trace)
    packer = packer_for(algorithm, backend="jax")
    prev = jnp.full(trace.shape[1], -1, jnp.int32)
    for s in range(t):
        prev = packer(jnp.asarray(trace[s], jnp.float32), prev,
                      capacity).bin_of
    return np.asarray(prev)


def pareto_front(points: Sequence[Point]) -> List[Point]:
    """Non-dominated subset of ``points`` (minimize both coordinates),
    sorted by the first coordinate.  Duplicate points collapse."""
    pts = sorted(set((float(x), float(y)) for x, y in points))
    front: List[Point] = []
    best_y = np.inf
    for x, y in pts:
        if y < best_y:
            front.append((x, y))
            best_y = y
    return front


def dominated(p: Point, front: Sequence[Point]) -> bool:
    """True iff some frontier point is <= ``p`` in both coordinates and
    strictly better in at least one."""
    px, py = float(p[0]), float(p[1])
    return any(x <= px and y <= py and (x < px or y < py) for x, y in front)


def hypervolume_2d(points: Sequence[Point], ref: Point) -> float:
    """Area dominated by ``points`` inside the box ``[.., ref]`` (both
    objectives minimized; points at or beyond ``ref`` contribute 0)."""
    rx, ry = float(ref[0]), float(ref[1])
    front = pareto_front([(x, y) for x, y in points if x < rx and y < ry])
    hv = 0.0
    prev_y = ry
    for x, y in front:
        hv += (rx - x) * (prev_y - y)
        prev_y = y
    return hv


@dataclasses.dataclass
class FrontierResult:
    """Annealed lambda-sweep frontier for one packing instance."""

    lambdas: List[float]            # the swept lambda grid
    per_lambda: List[Point]         # best (bins, rscore) per lambda
    front: List[Point]              # Pareto front over *all* chains
    ref: Point                      # reference point used for hypervolume
    hypervolume: float              # HV(front, ref)

    def heuristic_metrics(self, point: Point) -> dict:
        """Score one heuristic's (bins, rscore) point against the frontier:
        hypervolume ratio (its single-point HV over the front's) and
        domination status."""
        hv = hypervolume_2d([point], self.ref)
        return {
            "bins": float(point[0]),
            "rscore": float(point[1]),
            "dominated": bool(dominated(point, self.front)),
            "hv_ratio": float(hv / self.hypervolume)
            if self.hypervolume > 0 else 1.0,
        }


def reference_point(speeds, prev, capacity) -> Point:
    """Canonical HV reference for an instance: one bin more than
    partitions, one unit of R more than moving every assigned partition."""
    speeds = np.asarray(speeds, np.float64)
    prev = np.asarray(prev)
    r_all = float(speeds[prev >= 0].sum()) / float(capacity)
    return (float(speeds.shape[0]) + 1.0, r_all + 1.0)


def anneal_frontier(speeds, prev, capacity, key, *,
                    lambdas: Sequence[float] = (0.0, 0.25, 0.5, 1.0, 2.0,
                                                4.0, 8.0),
                    restarts: int = 4, steps: int = 250,
                    use_kernel: bool = False) -> FrontierResult:
    """Trace the cost-vs-R-score frontier of one instance by sweeping
    ``lambdas``, ``restarts`` chains each, in a single batched anneal."""
    lam_vec = jnp.repeat(jnp.asarray(lambdas, jnp.float32), restarts)
    res = anneal_pack(jnp.asarray(speeds, jnp.float32),
                      jnp.asarray(prev, jnp.int32), capacity, lam_vec, key,
                      steps=steps, use_kernel=use_kernel)
    bins = np.asarray(res.bins, np.int64)
    rs = np.asarray(res.rscore, np.float64)
    cost = np.asarray(res.cost, np.float64)
    pts = [(float(b), float(r)) for b, r in zip(bins, rs)]
    per_lambda: List[Point] = []
    for i in range(len(lambdas)):
        sl = slice(i * restarts, (i + 1) * restarts)
        j = i * restarts + int(np.argmin(cost[sl]))
        per_lambda.append((float(bins[j]), float(rs[j])))
    ref = reference_point(speeds, prev, capacity)
    front = pareto_front(pts)
    return FrontierResult(lambdas=[float(l) for l in lambdas],
                          per_lambda=per_lambda, front=front, ref=ref,
                          hypervolume=hypervolume_2d(front, ref))


def optimality_gap(heuristic_bins, optimal_bins) -> np.ndarray:
    """Relative gap ``(heuristic - optimal) / max(optimal, 1)``,
    elementwise over arrays of bin counts."""
    h = np.asarray(heuristic_bins, np.float64)
    o = np.asarray(optimal_bins, np.float64)
    return (h - o) / np.maximum(o, 1.0)
