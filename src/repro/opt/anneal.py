"""Massively batched stochastic packing optimizer: simulated-annealing /
heat-bath chains over partition->bin assignments, vmappable over scenario
batches.

Each chain carries a *feasible* assignment of the N partitions to bin
names in ``[0, 2N+2)`` (the same name universe as ``jaxpack``, so sticky
matches against any heuristic's previous assignment are representable).
Per step the chain

  1. evaluates the cost delta of every single-partition relocation --
     the ``f32[K, N, M]`` plane computed by ``kernels/move_eval.py``
     (jnp oracle by default; the Pallas kernel via ``use_kernel=True``);
  2. samples its next state from the heat-bath (Glauber) distribution
     ``softmax(-delta / T)`` over all allowed moves plus "stay", via
     Gumbel-max, with a geometric temperature schedule ``t0 -> t1``;
  3. tracks the best assignment seen so far.

The objective is ``bins + lam * Rscore`` (the R-score already carries the
1/C normalization of Eq. 10) with a per-chain ``lam``, so one launch
anneals a whole lambda sweep x restarts -- the frontier tracer in
``pareto.py`` rides exactly this.  Moves are masked to
capacity-feasible targets (with the ``binpack.py`` oversized-item
exception) and chains start from the always-feasible identity assignment,
so every state ever visited -- and hence the returned best -- is feasible
by construction.

Everything is pure ``jax.lax`` control flow: the whole optimizer runs
inside jit/vmap/scan, which is how the ``ANNEAL``/``ANNEAL_STICKY``
closed-loop policies (``lagsim/policies.py``) embed it in the simulator's
step scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.move_eval import (
    MOVE_BLOCKED,
    move_delta_batch,
    move_delta_reference,
)

NEG = -1    # masked-out items report this bin name (matches jaxpack.NEG)


def name_universe(n: int) -> int:
    """Bin-name universe size, matching ``jaxpack`` (names < 2n+2)."""
    return 2 * n + 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AnnealResult:
    """Best state per chain after annealing (axis 0 = chain)."""

    assign: jax.Array   # i32[K, N] best assignment (bin names)
    bins: jax.Array     # i32[K]    bins used by the best assignment
    rscore: jax.Array   # f32[K]    Eq. 10 cost of the best assignment vs prev
    cost: jax.Array     # f32[K]    bins + lam * rscore (recomputed exactly)
    lam: jax.Array      # f32[K]    the chain's lambda (echoed for sweeps)


def assignment_cost(assign, speeds, prev, capacity, lam, *, m: int,
                    active=None):
    """Exact objective of assignments ``i32[..., N]`` (names in [0, m)).

    Returns ``(cost, bins, rscore)`` with shapes ``[...]``: open-bin count
    (bins holding at least one partition, zero-speed partitions included),
    Eq. 10 R-score against ``prev`` (-1 entries never count as moved), and
    ``bins + lam * rscore``.  ``active`` (bool[..., N], optional) masks
    partitions that do not exist: they open no bin and price no move
    (``assign`` entries of ``-1`` -- the masked convention -- likewise
    one-hot to nothing).
    """
    onehot = jax.nn.one_hot(assign, m, dtype=jnp.float32)        # (..., N, M)
    moved = (prev >= 0) & (assign != prev)
    if active is not None:
        act = active.astype(bool)
        onehot = onehot * act[..., :, None]
        moved = moved & act
    counts = jnp.sum(onehot, axis=-2)
    bins = jnp.sum((counts > 0).astype(jnp.int32), axis=-1)
    r = jnp.sum(jnp.where(moved, speeds, 0.0), axis=-1) / capacity
    return bins.astype(jnp.float32) + lam * r, bins, r


def _temperature_schedule(steps: int, t0: float, t1: float) -> jax.Array:
    frac = jnp.arange(steps, dtype=jnp.float32) / max(steps - 1, 1)
    return jnp.float32(t0) * (jnp.float32(t1) / jnp.float32(t0)) ** frac


def anneal_chains(speeds: jax.Array, prev: jax.Array, capacity,
                  lam: jax.Array, key: jax.Array, *, steps: int = 200,
                  t0: float = 1.0, t1: float = 0.02,
                  use_kernel: bool = False,
                  active: jax.Array | None = None) -> AnnealResult:
    """Run ``K = lam.shape[0]`` annealing chains over one instance.

    speeds: f32[N]; prev: i32[N] (-1 = unassigned); lam: f32[K] per-chain
    R-score weight; capacity may be a traced scalar; active: optional
    bool[N] partition mask -- an inactive item is frozen out of the
    anneal (no chain may relocate it, it loads no bin and opens no bin)
    and is reported as ``NEG`` in the best assignment.  Scan-safe: pure
    ``lax`` control flow, so callers may jit/vmap freely (``steps``,
    ``t0``, ``t1``, ``use_kernel`` must be static).
    """
    n = speeds.shape[0]
    m = name_universe(n)
    k = lam.shape[0]
    speeds = speeds.astype(jnp.float32)
    prev = prev.astype(jnp.int32)
    lam = lam.astype(jnp.float32)
    cap = jnp.asarray(capacity, jnp.float32)
    if active is not None:
        act = active.astype(bool)
        # an inactive item carries no weight and prices no move; it keeps
        # its identity-bin seat, but the seat reads as empty (count 0)
        speeds = jnp.where(act, speeds, 0.0)
        prev = jnp.where(act, prev, jnp.int32(NEG))
        item_count0 = act.astype(jnp.int32)
        active_k = jnp.broadcast_to(act, (k, n))
    else:
        act = None
        item_count0 = jnp.ones(n, jnp.int32)
        active_k = None

    speeds_k = jnp.broadcast_to(speeds, (k, n))
    prev_k = jnp.broadcast_to(prev, (k, n))
    cap_k = jnp.broadcast_to(cap, (k,))

    # identity start: partition p alone in bin p -- always feasible
    assign0 = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (k, n))
    loads0 = jnp.broadcast_to(
        jnp.concatenate([speeds, jnp.zeros(m - n, jnp.float32)]), (k, m))
    counts0 = jnp.broadcast_to(jnp.concatenate(
        [item_count0, jnp.zeros(m - n, jnp.int32)]), (k, m))
    cost0, _, _ = assignment_cost(assign0, speeds_k, prev_k, cap, lam,
                                  m=m, active=active_k)

    nm = n * m

    def chain_update(assign, loads, counts, cost, best_cost, best_assign,
                     choice, delta_pm):
        do = choice < nm
        idx = jnp.minimum(choice, nm - 1).astype(jnp.int32)
        p = idx // m
        b = idx % m
        d = delta_pm.reshape(-1)[idx]
        do = do & (d < MOVE_BLOCKED / 2)      # belt & braces vs masked moves
        w = speeds[p]
        a = assign[p]
        assign_n = assign.at[p].set(b)
        loads_n = loads.at[a].add(-w).at[b].add(w)
        counts_n = counts.at[a].add(-1).at[b].add(1)
        cost_n = cost + d
        assign = jnp.where(do, assign_n, assign)
        loads = jnp.where(do, loads_n, loads)
        counts = jnp.where(do, counts_n, counts)
        cost = jnp.where(do, cost_n, cost)
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_assign = jnp.where(better, assign, best_assign)
        return assign, loads, counts, cost, best_cost, best_assign

    def body(carry, xs):
        assign, loads, counts, cost, best_cost, best_assign = carry
        temp, key_t = xs
        if use_kernel:
            delta = move_delta_batch(loads, counts, assign, speeds_k,
                                     prev_k, lam, cap_k, active=active_k)
        else:
            delta = move_delta_reference(loads, counts, assign, speeds_k,
                                         prev_k, lam, cap_k, active=active_k)
        logits = jnp.concatenate(
            [-delta.reshape(k, nm) / temp, jnp.zeros((k, 1), jnp.float32)],
            axis=1)
        g = jax.random.gumbel(key_t, (k, nm + 1), jnp.float32)
        choice = jnp.argmax(logits + g, axis=1).astype(jnp.int32)
        carry = jax.vmap(chain_update)(assign, loads, counts, cost,
                                       best_cost, best_assign, choice, delta)
        return carry, None

    init = (assign0, loads0, counts0, cost0, cost0, assign0)
    ts = _temperature_schedule(steps, t0, t1)
    keys = jax.random.split(key, steps)
    carry, _ = lax.scan(body, init, (ts, keys))
    best_assign = carry[5]
    if act is not None:
        # inactive items were frozen in their identity seat; report them
        # as unassigned (one_hot(-1) is all-zeros, so the cost below is
        # unaffected either way)
        best_assign = jnp.where(active_k, best_assign, jnp.int32(NEG))
    # the scan tracks cost incrementally (float drift over many deltas);
    # re-derive the best state's exact cost from scratch
    cost, bins, r = assignment_cost(best_assign, speeds_k, prev_k, cap, lam,
                                    m=m, active=active_k)
    return AnnealResult(assign=best_assign, bins=bins, rscore=r, cost=cost,
                        lam=lam)


def anneal_assign(speeds: jax.Array, prev: jax.Array, capacity,
                  key: jax.Array, *, lam: float = 0.0, chains: int = 8,
                  steps: int = 64, t0: float = 1.0, t1: float = 0.02,
                  use_kernel: bool = False,
                  active: jax.Array | None = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Single-lambda convenience: best chain's ``(assign i32[N], bins i32)``.

    This is the entry point the ``ANNEAL``/``ANNEAL_STICKY`` closed-loop
    policies call once per simulated step.  Inactive items (``active``
    mask 0) come back as ``NEG``.
    """
    lam_vec = jnp.full((chains,), lam, jnp.float32)
    res = anneal_chains(speeds, prev, capacity, lam_vec, key, steps=steps,
                        t0=t0, t1=t1, use_kernel=use_kernel, active=active)
    i = jnp.argmin(res.cost)
    return res.assign[i], res.bins[i]


@functools.partial(jax.jit,
                   static_argnames=("steps", "t0", "t1", "use_kernel"))
def anneal_pack(speeds: jax.Array, prev: jax.Array, capacity,
                lam: jax.Array, key: jax.Array, *, steps: int = 200,
                t0: float = 1.0, t1: float = 0.02,
                use_kernel: bool = False,
                active: jax.Array | None = None) -> AnnealResult:
    """Jitted ``anneal_chains`` for standalone (non-nested) callers."""
    return anneal_chains(speeds, prev, capacity, lam, key, steps=steps,
                         t0=t0, t1=t1, use_kernel=use_kernel, active=active)
