"""Launch layer: production mesh, sharding rule tables, input shapes,
step builders, the multi-pod dry-run, and the train/serve drivers."""
