"""Step-function builders: train_step (fwd+bwd+AdamW), prefill_step
(forward, last-token logits), serve_step (one decode step)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ArchConfig, forward, serve_step as model_serve_step
from repro.models.layers import embed_inputs, logits_fn
from repro.models.transformer import backbone
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = forward(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params,
                                                      opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}
    return train_step


def make_prefill_step(cfg: ArchConfig):
    """Full-sequence forward, returning only the last position's logits (the
    serving prefill: fills state, samples the first generated token)."""
    if cfg.encoder_decoder:
        from repro.models.whisper import _dec_embed, encode
        from repro.models.layers import apply_norm

        def prefill(params, batch):
            loss_free_batch = dict(batch)
            # reuse the teacher-forced path but only keep last-token logits
            from repro.models.whisper import whisper_forward
            enc = encode(params, cfg, batch["inputs"])
            tokens = batch["decoder_tokens"]
            b, s = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            x = _dec_embed(params, cfg, tokens, positions)
            from repro.models.whisper import _cross_attention
            from repro.models.attention import attention_block
            from repro.models.layers import apply_mlp
            from jax import lax

            def body(carry, lp):
                h = apply_norm(lp["ln1"], cfg, carry)
                carry = carry + attention_block(lp["self_attn"], cfg, h, positions)
                h = apply_norm(lp["ln2"], cfg, carry)
                carry = carry + _cross_attention(lp["cross_attn"], cfg, h, enc)
                h = apply_norm(lp["ln3"], cfg, carry)
                carry = carry + apply_mlp(lp["mlp"], cfg, h)
                return carry, None

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = lax.scan(body, x, params["layers"])
            x = apply_norm(params["final_norm"], cfg, x)
            return logits_fn(params, cfg, x[:, -1:, :])[:, 0, :]
        return prefill

    def prefill(params, batch):
        inputs = batch["inputs"]
        b, s = inputs.shape[0], inputs.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = embed_inputs(params["embedding"], cfg, inputs)
        h, _ = backbone(params, cfg, x, positions)
        return logits_fn(params, cfg, h[:, -1:, :])[:, 0, :]
    return prefill


def make_serve_step(cfg: ArchConfig):
    def step(params, state, batch):
        return model_serve_step(params, cfg, state, batch)
    return step
