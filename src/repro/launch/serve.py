"""Serving driver: the autoscaled replica fleet with roofline-derived
capacity.

Wires the full loop the paper + this framework describe: the dry-run's
compiled ``serve_step`` roofline gives the replica capacity C
(`repro.serving.capacity`), the monitor measures per-stream arrival rates,
and the controller packs streams onto the fewest replicas with the selected
algorithm (default MBFP), migrating via the two-phase protocol.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-67b \
      --algorithm MBFP --seconds 300
(falls back to a configured capacity when no dry-run results exist)
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.serving import AutoscaleSimulation


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-67b")
    ap.add_argument("--rules", default="tail256",
                    help="dry-run variant to derive capacity from")
    ap.add_argument("--algorithm", default="MBFP")
    ap.add_argument("--streams", type=int, default=12)
    ap.add_argument("--seconds", type=int, default=300)
    ap.add_argument("--capacity", type=float, default=None,
                    help="override capacity (tokens/s) instead of deriving")
    ap.add_argument("--delta", type=float, default=15.0,
                    help="Eq. 11 workload variability (%% of C per step)")
    args = ap.parse_args(argv)

    cap = args.capacity
    source = "flag"
    if cap is None:
        try:
            from repro.serving.capacity import derived_replica_capacity
            d = derived_replica_capacity(args.arch, "decode_32k",
                                         rules=args.rules)
            cap = d["tokens_per_s"]
            source = (f"dry-run roofline ({d['bottleneck']}-bound, "
                      f"{d['step_seconds'] * 1e3:.0f} ms/step)")
        except Exception as e:  # no dry-run artifacts: fall back
            cap = 500.0
            source = f"default (no dry-run results: {e})"
    print(f"[serve] {args.arch}: replica capacity C = {cap:.0f} tokens/s "
          f"[{source}]")

    sim = AutoscaleSimulation(
        n_partitions=args.streams,
        rate_fn=AutoscaleSimulation.random_walk_rates(
            args.streams, cap, delta=args.delta, seed=0),
        capacity=cap, algorithm=args.algorithm,
        # production headroom: repack when a replica exceeds 90% of C, so
        # workload upswings drain instead of accumulating backlog
        overload_factor=0.9,
        record_bytes=max(64, int(cap // 50)))
    m = sim.run(seconds=args.seconds)

    n = np.asarray(m.n_replicas)
    lag = np.asarray(m.lag_bytes, float)
    migs = sim.controller.migrations
    print(f"[serve] fleet size: min {n.min()} / mean {n.mean():.1f} / "
          f"max {n.max()}")
    print(f"[serve] final lag: {lag[-1] / 1e3:.1f}K (peak {lag.max() / 1e3:.1f}K)")
    print(f"[serve] reassignments: {len(migs)}; mean Rscore "
          f"{np.mean([r.rscore for r in migs]) if migs else 0:.4f}; "
          f"total migrations {sum(len(r.moved) for r in migs)}")
    third = len(lag) // 3
    slope = (lag[-1] - lag[-third]) / max(third, 1)
    # a reactive autoscaler may end mid-upswing; anything under one
    # replica-equivalent of backlog growth is caught by the next scale-up
    verdict = "bounded" if slope < cap else "GROWING beyond one replica"
    print(f"[serve] lag slope last third: {slope:.1f} B/s ({verdict})")


if __name__ == "__main__":
    main()
