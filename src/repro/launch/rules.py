"""Logical-axis -> mesh-axis rule tables.

The models annotate params/activations with logical names; these tables bind
them to the production mesh.  Named rule-set variants are the lever the perf
hillclimb sweeps (EXPERIMENTS.md records which variant each measurement
used).

Baseline (paper-faithful starting point):
* training: batch over (pod,)data; FSDP (p_embed) over data; TP over model
  for heads/ffn/vocab; sequence-parallel residual (seq_sp over model).
* serving: TP-only weights (replicated over data), batch over data, KV-cache
  sequence axis over model (flash-decoding style distributed softmax).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

AxisSpec = Union[None, str, Tuple[str, ...]]


def train_rules(multi_pod: bool = False, variant: str = "baseline"
                ) -> Dict[str, AxisSpec]:
    batch = ("pod", "data") if multi_pod else ("data",)
    base: Dict[str, AxisSpec] = {
        # activations
        "batch": batch,
        "seq_sp": "model",
        "ffn": "model",
        "heads": "model",
        "kv": "model",
        "vocab": "model",
        "exp_cap": "model",
        "cache_seq": "model",
        # params
        "p_embed": "data",          # FSDP within pod (pure DP across pods)
        "p_ffn": "model",
        "p_heads": "model",
        "p_kv": "model",            # auto-replicates when kv % 16 != 0
        "p_vocab": "model",
        "p_experts": None,          # TP-MoE baseline (EP is a variant)
    }
    if variant == "baseline":
        return base
    if variant == "no_sp":          # residual replicated over model
        return {**base, "seq_sp": None}
    if variant == "ep":             # expert parallelism over the model axis
        return {**base, "p_experts": "model", "p_ffn": None,
                "exp_cap": "model", "ffn": None}
    if variant == "moe_local":      # dispatch buffer local to the data shard
        return {**base, "exp_cap": None}
    if variant == "fsdp_model":     # FSDP over both axes (ZeRO-3 everywhere)
        return {**base, "p_embed": ("data", "model") if not multi_pod
                else ("data", "model")}
    raise ValueError(f"unknown train rules variant {variant!r}")


def serve_rules(multi_pod: bool = False, variant: str = "baseline"
                ) -> Dict[str, AxisSpec]:
    batch = ("pod", "data") if multi_pod else ("data",)
    base: Dict[str, AxisSpec] = {
        "batch": batch,
        "seq_sp": "model",
        "ffn": "model",
        "heads": "model",
        "kv": "model",
        "vocab": "model",
        "exp_cap": "model",
        "cache_seq": "model",
        "p_embed": None,            # weights TP-only for low-latency decode
        "p_ffn": "model",
        "p_heads": "model",
        "p_kv": "model",
        "p_vocab": "model",
        "p_experts": None,
    }
    if variant == "baseline":
        return base
    if variant == "cache_batch":    # cache sharded by batch only
        return {**base, "cache_seq": None, "batch": batch}
    if variant == "ep":
        return {**base, "p_experts": "model", "p_ffn": None, "ffn": None}
    if variant == "weights_2d":     # shard weights over data too (prefill)
        return {**base, "p_embed": "data"}
    raise ValueError(f"unknown serve rules variant {variant!r}")
