"""Production mesh + TPU v5e hardware constants.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state -- the dry-run process
must set XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax

# --- TPU v5e constants (roofline denominators) -----------------------------
PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (intra-pod)
DCI_BW = 25e9                   # bytes/s per chip cross-pod (assumed DCI)
HBM_BYTES = 16 * 1024 ** 3      # 16 GiB per chip

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def n_chips(multi_pod: bool) -> int:
    return MULTI_POD_CHIPS if multi_pod else SINGLE_POD_CHIPS
