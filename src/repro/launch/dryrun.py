import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).  Do not move them.

"""Multi-pod dry-run: for every (architecture x input-shape) cell, lower +
compile the step function on the production mesh (16x16 single-pod and
2x16x16 multi-pod) with ShapeDtypeStruct inputs (no allocation), record

  * memory_analysis()  -- proves the program fits per-device HBM,
  * cost_analysis()    -- HLO FLOPs / bytes for the roofline,
  * collective wire bytes parsed from the partitioned HLO,

appending one JSON line per cell to the output file (resumable: cells
already present are skipped).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--rules baseline|...] [--out FILE]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import hlo_walker
from repro.launch.mesh import (DCI_BW, HBM_BW, HBM_BYTES, ICI_BW,
                               PEAK_FLOPS_BF16, make_production_mesh, n_chips)
from repro.launch.rules import serve_rules, train_rules
from repro.launch.shapes import (SHAPES, applicable, batch_logical_specs,
                                 input_specs, model_flops)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import axis_rules, decode_state_specs, init_decode_state, \
    init_params, param_specs
from repro.models.sharding import logical_spec
from repro.optim.adamw import AdamWConfig, adamw_init, opt_state_specs
from jax.sharding import NamedSharding


def _resolve_tree(spec_tree, sds_tree, mesh, rules):
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def one(names, sds):
        return NamedSharding(mesh, logical_spec(names, sds.shape, mesh, rules))
    return jax.tree.map(one, spec_tree, sds_tree, is_leaf=is_spec)


# named experiment variants: (sharding-rules variant, ArchConfig overrides).
# "baseline" is the paper-faithful starting point; the rest are the §Perf
# hillclimb configurations (EXPERIMENTS.md records deltas against baseline).
VARIANTS = {
    "baseline": ("baseline", {}),
    "no_sp": ("no_sp", {}),
    "moe_local": ("moe_local", {}),
    "ep": ("ep", {}),                                  # expert parallelism
    "wkv_kernel": ("baseline", {"wkv_impl": "kernel_stub"}),
    "tail256": ("baseline", {"decode_tail_window": 256}),
    "ep_tail256": ("ep", {"decode_tail_window": 256}),
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_variant: str = "baseline", keep_artifacts: bool = False):
    """Returns a result dict for one cell (raises on failure)."""
    shape = SHAPES[shape_name]
    serve = shape.kind == "decode"
    rules_name, overrides = VARIANTS.get(rules_variant,
                                         (rules_variant, {}))
    cfg = configs.get(arch)
    cfg = type(cfg)(**{**cfg.__dict__, **overrides,
                       "param_dtype": "bfloat16" if serve else "float32"})
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = (serve_rules(multi_pod, rules_name) if serve
             else train_rules(multi_pod, rules_name))

    p_sds = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    p_specs = param_specs(cfg)
    p_shard = _resolve_tree(p_specs, p_sds, mesh, rules)
    batch_sds = input_specs(cfg, shape)
    b_shard = _resolve_tree(batch_logical_specs(cfg, shape), batch_sds, mesh,
                            rules)

    with axis_rules(mesh, rules):
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            o_sds = jax.eval_shape(adamw_init, p_sds)
            o_shard = _resolve_tree(opt_state_specs(p_specs), o_sds, mesh, rules)
            step = make_train_step(cfg, opt_cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_sds, o_sds, batch_sds)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_sds, batch_sds)
        else:
            step = make_serve_step(cfg)
            s_sds = jax.eval_shape(
                lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))
            s_shard = _resolve_tree(decode_state_specs(cfg), s_sds, mesh, rules)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, s_shard, b_shard),
                             out_shardings=(None, s_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_sds, s_sds, batch_sds)

        flush_stats = None
        if shape.kind == "decode" and cfg.decode_tail_window > 0:
            from repro.models.attention import flush_kv_tail
            fl = jax.jit(lambda st: flush_kv_tail(cfg, st),
                         in_shardings=(s_shard,), out_shardings=s_shard,
                         donate_argnums=(0,))
            flush_compiled = fl.lower(s_sds).compile()
            flush_stats = hlo_walker.walk(flush_compiled.as_text())

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware accounting (XLA's cost_analysis counts while bodies
    # once; the walker multiplies through scan trip counts -- see
    # tests/test_hlo_walker.py)
    stats = hlo_walker.walk(hlo)

    chips = n_chips(multi_pod)
    flops_dev = float(stats.flops)
    bytes_dev = float(stats.hbm_bytes)
    mf = model_flops(cfg, shape)

    # roofline terms (seconds)
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    # split intra-pod (ICI) vs cross-pod (pod-axis collectives: group size 2)
    ici_bytes = 0.0
    dci_bytes = 0.0
    for (kind, k), v in stats.collective_by.items():
        if multi_pod and k == 2:
            dci_bytes += v
        else:
            ici_bytes += v
    t_collective = ici_bytes / ICI_BW + dci_bytes / DCI_BW

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "rules": rules_variant, "kind": shape.kind,
        "chips": chips, "compile_s": round(compile_s, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": stats.collective_bytes,
        "collectives": stats.summary(),
        "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                              "bytes": float(cost.get("bytes accessed", 0.0))},
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (flops_dev * chips)
                               if flops_dev > 0 else None),
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_collective,
            "t_collective_ici_s": ici_bytes / ICI_BW,
            "t_collective_dci_s": dci_bytes / DCI_BW,
            "bottleneck": max(
                [("compute", t_compute), ("memory", t_memory),
                 ("collective", t_collective)], key=lambda kv: kv[1])[0],
        },
        "memory": {},
    }
    if flush_stats is not None:
        w = cfg.decode_tail_window
        res["flush_amortized"] = {
            "window": w,
            "t_memory_s": flush_stats.hbm_bytes / HBM_BW / w,
            "t_collective_s": flush_stats.collective_bytes / ICI_BW / w,
        }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                res["memory"][attr] = int(v)
        args_b = res["memory"].get("argument_size_in_bytes", 0)
        temp_b = res["memory"].get("temp_size_in_bytes", 0)
        alias_b = res["memory"].get("alias_size_in_bytes", 0)
        live = args_b + temp_b - alias_b
        res["memory"]["live_bytes_per_device"] = int(live)
        res["memory"]["fits_hbm"] = bool(live <= HBM_BYTES)
    if keep_artifacts:
        res["_hlo"] = hlo
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--no-skip-existing", dest="skip_existing",
                    action="store_false")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, \
        f"dry-run needs 512 placeholder devices, got {len(jax.devices())}"

    archs = [args.arch] if args.arch else configs.list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    done = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r["rules"]))
                except Exception:
                    pass

    failures = []
    with open(args.out, "a") as out:
        for arch in archs:
            cfg = configs.get(arch)
            for shape_name in shapes:
                ok, why = applicable(cfg, shape_name)
                for mp in meshes:
                    mesh_name = "2x16x16" if mp else "16x16"
                    key = (arch, shape_name, mesh_name, args.rules)
                    if key in done:
                        print(f"[skip-done] {key}", flush=True)
                        continue
                    if not ok:
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "rules": args.rules,
                               "skipped": why}
                        out.write(json.dumps(rec) + "\n")
                        out.flush()
                        print(f"[skip] {key}: {why}", flush=True)
                        continue
                    print(f"[cell] {key} ...", flush=True)
                    try:
                        res = lower_cell(arch, shape_name, mp, args.rules)
                        out.write(json.dumps(res) + "\n")
                        out.flush()
                        rl = res["roofline"]
                        print(f"  ok compile={res['compile_s']}s "
                              f"bottleneck={rl['bottleneck']} "
                              f"tc={rl['t_compute_s']:.3e} "
                              f"tm={rl['t_memory_s']:.3e} "
                              f"tcol={rl['t_collective_s']:.3e} "
                              f"live={res['memory'].get('live_bytes_per_device', 0)/2**30:.2f}GiB",
                              flush=True)
                    except Exception as e:
                        tb = traceback.format_exc(limit=20)
                        failures.append((key, str(e)))
                        out.write(json.dumps(
                            {"arch": arch, "shape": shape_name,
                             "mesh": mesh_name, "rules": args.rules,
                             "error": str(e)[:2000]}) + "\n")
                        out.flush()
                        print(f"  FAIL: {e}\n{tb}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:", flush=True)
        for k, e in failures:
            print(f"  {k}: {e[:200]}", flush=True)
        sys.exit(1)
    print("\nall cells ok", flush=True)


if __name__ == "__main__":
    main()
