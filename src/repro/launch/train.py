"""Training driver: data pipeline -> jitted train step -> checkpoints.

Runs on whatever mesh is ambient: single CPU device for the examples/tests,
the production mesh via ``--mesh`` on real hardware (the dry-run proves the
sharded program compiles; this driver executes it).  Restart-safe: state
(params, optimizer, data-pipeline offsets, step) round-trips through the
checkpoint store, and ``--simulate-preemption`` kills the process mid-run to
exercise recovery.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --batch 4 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, adamw_init


def train(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: Optional[str],
          save_every: int = 20, lr: float = 3e-4, log_every: int = 10,
          die_at_step: Optional[int] = None, seed: int = 0):
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 or 1),
                          total_steps=steps)
    pipeline = TokenPipeline(batch, seq, cfg.vocab_size, seed=seed)
    params = init_params(jax.random.key(seed), cfg)
    opt_state = adamw_init(params)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False) if ckpt_dir else None
    if mgr is not None:
        target = {"params": jax.tree.map(
                      lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                  "opt": jax.tree.map(
                      lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state)}
        found = mgr.restore_latest(target)
        if found[0] is not None:
            start_step, tree = found
            params, opt_state = tree["params"], tree["opt"]
            import json
            import os
            meta_path = os.path.join(mgr.directory, f"step_{start_step:08d}",
                                     "MANIFEST.msgpack")
            import msgpack
            with open(meta_path, "rb") as f:
                extra = msgpack.unpackb(f.read()).get("extra", {})
            if "pipeline" in extra:
                pipeline.load_state(extra["pipeline"])
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        b = pipeline.next_batch()
        batch_dev = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        losses.append(float(metrics["loss"]))
        if (step + 1) % log_every == 0:
            dt = (time.time() - t0) / log_every
            print(f"[train] step {step + 1}/{steps} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s/step",
                  flush=True)
            t0 = time.time()
        if mgr is not None and (step + 1) % save_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     extra={"pipeline": pipeline.state()})
        if die_at_step is not None and step + 1 == die_at_step:
            print(f"[train] simulating preemption at step {step + 1}")
            return {"died_at": step + 1, "losses": losses}
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state},
                 extra={"pipeline": pipeline.state()})
        mgr.wait()
    return {"final_step": steps, "losses": losses, "params": params}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--die-at-step", type=int, default=None)
    args = ap.parse_args(argv)
    cfg = configs.get(args.arch, smoke=args.smoke)
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt, lr=args.lr, save_every=args.save_every,
                die_at_step=args.die_at_step)
    print(f"[train] done: {out.get('final_step', out.get('died_at'))} steps, "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
