"""Assigned input shapes x applicability, and ShapeDtypeStruct input specs.

LM transformer shapes are seq_len x global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a seq_len KV cache), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention: it runs for
the ssm/hybrid archs (rwkv6, jamba) and is SKIPPED for pure full-attention
archs (recorded as such in the roofline table; see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "SKIP(full-attn): 500k decode needs sub-quadratic attention"
    return True, ""


def cells(arch_names: List[str], get_cfg) -> List[Tuple[str, str]]:
    out = []
    for a in arch_names:
        for s in SHAPES:
            out.append((a, s))
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for the step function's batch argument."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    batch: Dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.encoder_decoder:
            batch["inputs"] = _sds((b, cfg.encoder_seq_len, d), jnp.bfloat16)
            batch["decoder_tokens"] = _sds((b, s), jnp.int32)
        elif cfg.input_mode == "embeddings":
            batch["inputs"] = _sds((b, s, d), jnp.bfloat16)
        else:
            batch["inputs"] = _sds((b, s), jnp.int32)
        if cfg.mrope_sections:
            batch["positions"] = _sds((3, b, s), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        if cfg.input_mode == "embeddings" and not cfg.encoder_decoder:
            batch["inputs"] = _sds((b, 1, d), jnp.bfloat16)
        else:
            batch["inputs"] = _sds((b,), jnp.int32)
    return batch


def batch_logical_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    """Logical axis names for each batch leaf (for in_shardings)."""
    specs: Dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.encoder_decoder:
            specs["inputs"] = ("batch", "seq_sp", None)
            specs["decoder_tokens"] = ("batch", "seq_sp")
        elif cfg.input_mode == "embeddings":
            specs["inputs"] = ("batch", "seq_sp", None)
        else:
            specs["inputs"] = ("batch", "seq_sp")
        if cfg.mrope_sections:
            specs["positions"] = (None, "batch", "seq_sp")
        if shape.kind == "train":
            specs["labels"] = ("batch", "seq_sp")
    else:
        if cfg.input_mode == "embeddings" and not cfg.encoder_decoder:
            specs["inputs"] = ("batch", None, None)
        else:
            specs["inputs"] = ("batch",)
    return specs


# ---------------------------------------------------------------------------
# analytic model FLOPs (roofline "useful compute" numerator)
# ---------------------------------------------------------------------------

def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D forward-only, + attention terms.

    N = active params (MoE: routed-to experts only).  D = tokens processed.
    Decode processes global_batch tokens per step against a seq_len cache.
    """
    n_active = cfg.n_active_params()
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.head_dim

    def attn_flops(tokens, kv_len, n_attn_layers, causal_factor=1.0):
        # QK^T + AV: 2 * 2 * tokens * kv_len * H * hd, causal halves it
        return (4.0 * tokens * kv_len * cfg.n_heads * hd * causal_factor
                * n_attn_layers)

    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.is_attn_layer(i)) \
        if not cfg.rwkv else 0
    if cfg.encoder_decoder:
        n_attn = cfg.n_layers + cfg.n_encoder_layers  # self; cross counted below

    if shape.kind == "train":
        flops = 6.0 * n_active * (b * s)
        flops += 3.0 * attn_flops(b * s, s, n_attn, 0.5)
        if cfg.encoder_decoder:
            flops += 3.0 * attn_flops(b * s, cfg.encoder_seq_len, cfg.n_layers)
        return flops
    if shape.kind == "prefill":
        flops = 2.0 * n_active * (b * s)
        flops += attn_flops(b * s, s, n_attn, 0.5)
        if cfg.encoder_decoder:
            flops += attn_flops(b * s, cfg.encoder_seq_len, cfg.n_layers)
        return flops
    # decode: one token per sequence
    flops = 2.0 * n_active * b
    flops += attn_flops(b, s, n_attn)
    if cfg.encoder_decoder:
        flops += attn_flops(b, cfg.encoder_seq_len, cfg.n_layers)
    return flops
