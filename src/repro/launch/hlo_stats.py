"""Extract collective-communication statistics from (post-SPMD) HLO text.

``cost_analysis()`` does not report collective bytes, so we parse the
compiled module: every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute contributes its *wire bytes per participating device*,
using standard ring-algorithm accounting:

    all-gather        out_bytes * (k-1)/k        (receives everyone else's shard)
    reduce-scatter    in_bytes  * (k-1)/k
    all-reduce        2 * bytes * (k-1)/k        (RS + AG)
    all-to-all        bytes * (k-1)/k
    collective-permute bytes                     (one hop)

where k is the replica-group size parsed from the op and shapes are the
per-device shapes appearing in the partitioned module.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape like 'bf16[16,128]' or a tuple '(f32[2], f32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota form [G,k]
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    return default


def _source_pairs(line: str) -> int:
    m = re.search(r"source_target_pairs=\{(.*?)\}", line)
    if m:
        return max(1, m.group(1).count("{"))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    # wire bytes per device, by op kind
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    # per (kind, group-size) wire bytes -- lets the roofline split ICI vs DCI
    bytes_by_kind_k: Dict[Tuple[str, int], float]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def summary(self) -> Dict:
        return {"total_bytes": self.total_bytes,
                "by_kind": dict(self.bytes_by_kind),
                "counts": dict(self.count_by_kind),
                "by_kind_groupsize": {f"{k}@{g}": v for (k, g), v in
                                      self.bytes_by_kind_k.items()}}


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    bytes_by: Dict[str, float] = defaultdict(float)
    count_by: Dict[str, int] = defaultdict(int)
    by_kind_k: Dict[Tuple[str, int], float] = defaultdict(float)
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        # match:  [ROOT] %name = <shape> <op>( ... )  (plus -start async forms)
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        nbytes = _shape_bytes(shape_str)
        if kind == "collective-permute":
            wire = float(nbytes)
        else:
            k = _group_size(s, default_group)
            frac = (k - 1) / k if k > 1 else 0.0
            if kind == "all-reduce":
                wire = 2.0 * nbytes * frac
            elif kind == "reduce-scatter":
                # result shape is the post-scatter shard: input = k * nbytes
                wire = float(nbytes) * (k - 1) if k > 1 else 0.0
            else:           # all-gather / all-to-all: result is the full shape
                wire = float(nbytes) * frac
        if wire <= 0:
            continue
        count_by[kind] += 1
        bytes_by[kind] += wire
        k = _group_size(s, default_group) if kind != "collective-permute" else 2
        by_kind_k[(kind, k)] += wire
    return CollectiveStats(dict(bytes_by), dict(count_by), dict(by_kind_k))
