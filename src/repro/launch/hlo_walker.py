"""Trip-count-aware cost accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned program (scan over layers, KV-block scans, recurrent time scans)
under-reports FLOPs/bytes/collectives by the trip count.  This walker parses
the partitioned HLO, builds the computation call graph (while/fusion/call/
conditional), multiplies every op's cost by the product of enclosing trip
counts (``backend_config={"known_trip_count":{"n":...}}``, emitted for all
lax.scan loops), and accumulates:

* flops            -- 2 * |result| * contraction for every ``dot``
* hbm bytes        -- operand + result bytes at fusion/op boundaries
                      (fusion internals excluded; dynamic-update-slice counts
                      the update, not the whole buffer)
* collective bytes -- ring-model wire bytes per device, by (kind, group size)

All values are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
# result type is either a tuple "(...)" (may contain /*index=N*/ comments,
# never nested parens) or a single token
_OP_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:\S+))\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_SINGLE = re.compile(r"(?:body|condition|calls|to_apply|true_computation|"
                            r"false_computation)=%?([\w.\-]+)")
_CALLED_MULTI = re.compile(r"(?:branch_computations|called_computations)=\{([^}]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "after-all", "iota", "partition-id", "replica-id",
    "bitcast-convert",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: Dict[str, Op]
    order: List[str]


def _operand_names(line: str, start: int) -> List[str]:
    """Operand %names inside the op's argument parens, where ``start`` points
    at the opening '(' (so tuple-typed results are not mistaken for args)."""
    i = start
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                region = line[i + 1:j]
                return re.findall(r"%([\w.\-]+)", region)
    return []


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), {}, [])
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, kind = m.group(1), m.group(2), m.group(3)
        cur.ops[name] = Op(name, shape, kind, line,
                           _operand_names(line, m.end() - 1))
        cur.order.append(name)
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    # fallback: largest integer constant in the condition computation
    mc = re.search(r"condition=%?([\w.\-]+)", op.line)
    if mc and mc.group(1) in comps:
        best = 1
        for o in comps[mc.group(1)].ops.values():
            for c in re.findall(r"constant\((\d+)\)", o.line):
                best = max(best, int(c))
        return best
    return 1


def _called_comps(op: Op) -> List[str]:
    out = [m.group(1) for m in _CALLED_SINGLE.finditer(op.line)]
    for m in _CALLED_MULTI.finditer(op.line):
        out.extend(n.strip().lstrip("%") for n in m.group(1).split(",") if n.strip())
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_elems = 0, 0
    out_elems, _ = _shape_elems_bytes(op.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m or not op.operands:
        return 0.0
    lhs = comp.ops.get(op.operands[0])
    if lhs is None:
        return 0.0
    lhs_dims: List[int] = []
    sm = _SHAPE_RE.search(lhs.shape)
    if sm:
        lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    contraction = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contraction *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contraction


def _op_bytes(op: Op, comp: Computation,
              comps: Optional[Dict[str, "Computation"]] = None) -> float:
    if op.kind in _SKIP_BYTES_OPS:
        return 0.0
    _, out_b = _shape_elems_bytes(op.shape)
    if op.kind in ("dynamic-update-slice",):
        upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
        ub = _shape_elems_bytes(upd.shape)[1] if upd else 0
        return float(2 * ub)
    if op.kind in ("dynamic-slice", "gather", "slice"):
        return float(2 * out_b)
    if op.kind == "fusion" and comps is not None:
        for cn in _called_comps(op):
            fused = comps.get(cn)
            if fused and fused.order:
                return _fusion_bytes(op, fused, out_b)
            break
    total = float(out_b)
    for o in op.operands:
        src = comp.ops.get(o)
        if src is None or src.kind in ("constant",):
            # parameters count: they are HBM-resident inputs
            if src is None:
                continue
        total += _shape_elems_bytes(src.shape)[1] if src else 0.0
    return total


_UNARY = ("convert", "bitcast", "copy", "reshape", "transpose")


def _fusion_bytes(op: Op, fused: Computation, out_b: int) -> float:
    """HBM traffic of one fusion call, modeling the TPU lowering:

    * root chain ending in dynamic-update-slice/scatter (possibly wrapped in
      converts/bitcasts): in-place update -- charge 2x the update slice, not
      the whole aliased buffer;
    * a fusion parameter consumed ONLY by dynamic-slice ops inside the fused
      computation: charge the slice result sizes, not the full buffer (the
      loop reads one layer of a stacked carry per iteration);
    * everything else: full operand size + output size.
    """
    # pure dtype-conversion fusion (parameter -> convert/copy/transpose
    # chain): a CPU-backend artifact of upcasting bf16 dot operands to f32.
    # TPU reads the operand natively; charge the input bytes once.
    kinds = {o.kind for o in fused.ops.values()}
    if kinds <= {"parameter", "convert", "copy", "bitcast", "reshape",
                 "transpose", "broadcast"}:
        in_b = sum(_shape_elems_bytes(o.shape)[1]
                   for o in fused.ops.values() if o.kind == "parameter")
        return float(in_b)

    # --- output side: walk back through unary wrappers to find a DUS root
    write_b = float(out_b)
    cur = fused.ops.get(fused.order[-1])
    seen = 0
    while cur is not None and cur.kind in _UNARY and cur.operands and seen < 6:
        cur = fused.ops.get(cur.operands[0])
        seen += 1
    if cur is not None:
        upd_idx = {"dynamic-update-slice": 1, "scatter": 2}.get(cur.kind)
        if upd_idx is not None and len(cur.operands) > upd_idx:
            upd = fused.ops.get(cur.operands[upd_idx])
            if upd is not None:
                ub = _shape_elems_bytes(upd.shape)[1]
                write_b = float(2 * ub)   # read-modify-write of the region

    # --- input side: per-parameter consumption analysis
    params: Dict[int, Op] = {}
    for o in fused.ops.values():
        if o.kind == "parameter":
            m = re.search(r"parameter\((\d+)\)", o.line)
            if m:
                params[int(m.group(1))] = o
    read_b = 0.0
    for idx, name in enumerate(op.operands):
        pop = params.get(idx)
        if pop is None:
            continue
        consumers = [o for o in fused.ops.values()
                     if pop.name in o.operands and o.kind != "parameter"]
        if consumers and all(c.kind == "dynamic-slice" for c in consumers):
            read_b += sum(_shape_elems_bytes(c.shape)[1] for c in consumers)
        else:
            # if this param is the aliased DUS destination, its read is
            # already covered by write_b
            if cur is not None and cur.kind in ("dynamic-update-slice", "scatter") \
                    and cur.operands and fused.ops.get(cur.operands[0]) is not None:
                chain = fused.ops[cur.operands[0]]
                hops = 0
                while chain is not None and chain.kind in _UNARY and \
                        chain.operands and hops < 6:
                    chain = fused.ops.get(chain.operands[0])
                    hops += 1
                if chain is pop:
                    continue
            read_b += _shape_elems_bytes(pop.shape)[1]
    return write_b + read_b


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _collective_wire_bytes(op: Op) -> Tuple[str, int, float]:
    kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
    _, nbytes = _shape_elems_bytes(op.shape)
    if kind == "collective-permute":
        return kind, 2, float(nbytes)
    k = _group_size(op.line)
    if k <= 1:
        return kind, k, 0.0
    frac = (k - 1) / k
    if kind == "all-reduce":
        # -start result may be a (in, out) tuple: halve to get payload
        if op.kind.endswith("-start"):
            nbytes = nbytes / 2
        return kind, k, 2.0 * nbytes * frac
    if kind == "reduce-scatter":
        return kind, k, float(nbytes) * (k - 1)
    return kind, k, float(nbytes) * frac   # all-gather / all-to-all


@dataclasses.dataclass
class WalkStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by: Dict[Tuple[str, int], float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    dot_flops_by_shape: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    bytes_by_opkind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    top_byte_ops: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    top_collective_ops: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)

    def summary(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind_k": {f"{k}@{g}": v for (k, g), v in
                                     sorted(self.collective_by.items())},
            "collective_counts": dict(self.collective_counts),
        }


def walk(text: str) -> WalkStats:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    stats = WalkStats()

    def visit(comp: Computation, mult: float, in_fusion: bool):
        for name in comp.order:
            op = comp.ops[name]
            if op.kind == "dot":
                f = _dot_flops(op, comp) * mult
                stats.flops += f
                stats.dot_flops_by_shape[op.shape] += f
            if not in_fusion:
                base = op.kind.replace("-start", "")
                if base in _COLLECTIVES:
                    if op.kind.endswith("-done"):
                        continue
                    kind, k, wire = _collective_wire_bytes(op)
                    stats.collective_bytes += wire * mult
                    stats.collective_by[(kind, k)] += wire * mult
                    stats.collective_counts[kind] += int(mult)
                    if wire * mult > 0:
                        mm = re.search(r'op_name="([^"]*)"', op.line)
                        desc = (f"{kind}@{k} {op.shape[:48]} x{mult:g} "
                                f"[{(mm.group(1) if mm else '?')[:90]}]")
                        if len(stats.top_collective_ops) < 200:
                            stats.top_collective_ops.append((wire * mult, desc))
                        else:
                            mn = min(range(len(stats.top_collective_ops)),
                                     key=lambda i: stats.top_collective_ops[i][0])
                            if stats.top_collective_ops[mn][0] < wire * mult:
                                stats.top_collective_ops[mn] = (wire * mult, desc)
                    continue
                b = _op_bytes(op, comp, comps) * mult
                stats.hbm_bytes += b
                if b > 0:
                    stats.bytes_by_opkind[op.kind] += b
                    if len(stats.top_byte_ops) < 400:
                        stats.top_byte_ops.append((b, f"{op.kind} {op.shape[:60]} x{mult:g}"))
                    else:
                        mn = min(range(len(stats.top_byte_ops)),
                                 key=lambda i: stats.top_byte_ops[i][0])
                        if stats.top_byte_ops[mn][0] < b:
                            stats.top_byte_ops[mn] = (b, f"{op.kind} {op.shape[:60]} x{mult:g}")
            # descend
            if op.kind == "while":
                trips = _trip_count(op, comps)
                for cn in _called_comps(op):
                    if cn in comps:
                        visit(comps[cn], mult * trips, in_fusion)
            elif op.kind == "fusion":
                for cn in _called_comps(op):
                    if cn in comps:
                        visit(comps[cn], mult, True)
            elif op.kind in ("call", "conditional", "custom-call"):
                for cn in _called_comps(op):
                    if cn in comps:
                        visit(comps[cn], mult, in_fusion)
            # reduce/sort/scatter/map apply tiny scalar computations: skip

    visit(entry, 1.0, False)
    return stats
