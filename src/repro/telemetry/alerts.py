"""Declarative in-loop SLO alerting: burn rates, invariant violations,
storms and thrash, evaluated inside the scan in O(rules) per step.

Post-hoc trace forensics (PR 7's ``decode_events``) needs the full
frame history; an autoscaler run at the ROADMAP's horizons can't afford
that, and a production scaler is judged on *alerts*, not traces (KEDA's
lag trigger, the Cloud-Run scheduled-scaling work in PAPERS.md).  This
module evaluates a declarative :class:`AlertRule` set over streaming
sketch state (per-rule debiased EWMA windows) inside the ``lax.scan``:

* ``slo_burn``          -- multi-window burn rate on the lag-SLO
  violation fraction (Google SRE-style: a fast and a slow EWMA window
  must *both* burn error budget faster than ``burn_threshold``x);
* ``lag_growth``        -- the paper's Eq. 1 invariant made an alert:
  consumption is not keeping up (EWMA of the per-step lag delta stays
  positive) for ``sustain_steps`` consecutive steps;
* ``rebalance_storm``   -- partitions continuously unreadable (migration
  downtime / control-plane storm) for ``storm_steps`` or longer;
* ``consumer_thrash``   -- scale-event flapping: the EWMA rate of
  consumer-count changes exceeds ``thrash_rate``.

State is a fixed-shape :class:`AlertState` (per-rule windows + a bounded
incident table of ``max_incidents`` rows), so alerting adds O(R * M)
memory no matter how long the run is; a padded fleet step is gated out
by ``valid`` exactly like the sketches.  Host-side,
:func:`decode_incidents` turns the table into typed :class:`Incident`
records with open/close steps, duration, peak measurement and severity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

ALERT_KINDS: Tuple[str, ...] = ("slo_burn", "lag_growth", "rebalance_storm",
                                "consumer_thrash")
SEVERITIES: Tuple[str, ...] = ("page", "ticket", "info")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative rule (hashable; rides the engine's jit key).

    ``kind`` selects which fields matter -- use the classmethod
    constructors (:meth:`slo_burn`, :meth:`lag_growth`,
    :meth:`rebalance_storm`, :meth:`consumer_thrash`) rather than
    spelling every knob.  Windows/half-lives are in simulation steps.
    """

    name: str
    kind: str
    severity: str = "page"
    # slo_burn: both EWMA windows of the violation indicator must burn
    # budget (1 - slo_target) at >= burn_threshold x the sustainable rate
    slo_target: float = 0.99
    burn_threshold: float = 2.0
    fast_halflife: float = 8.0
    slow_halflife: float = 64.0
    # lag_growth: EWMA(lag delta) > min_growth for sustain_steps steps
    growth_halflife: float = 16.0
    sustain_steps: int = 8
    min_growth: float = 0.0
    # rebalance_storm: any partition blocked for >= storm_steps steps
    storm_steps: int = 4
    # consumer_thrash: EWMA(consumer-count-changed) > thrash_rate
    thrash_halflife: float = 16.0
    thrash_rate: float = 0.25

    def __post_init__(self) -> None:
        if self.kind not in ALERT_KINDS:
            raise ValueError(
                f"unknown alert kind {self.kind!r}; have {ALERT_KINDS}")
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"unknown severity {self.severity!r}; have {SEVERITIES}")
        if not self.name:
            raise ValueError("alert rules need a non-empty name")
        for fld in ("fast_halflife", "slow_halflife", "growth_halflife",
                    "thrash_halflife"):
            if not float(getattr(self, fld)) > 0.0:
                raise ValueError(
                    f"{self.name}: {fld} must be > 0 steps, got "
                    f"{getattr(self, fld)!r}")
        if not 0.0 < float(self.slo_target) < 1.0:
            raise ValueError(
                f"{self.name}: slo_target must be in (0, 1) -- the error "
                f"budget is 1 - slo_target -- got {self.slo_target!r}")
        if int(self.sustain_steps) < 1 or int(self.storm_steps) < 1:
            raise ValueError(
                f"{self.name}: sustain_steps/storm_steps must be >= 1")
        if not float(self.burn_threshold) > 0.0:
            raise ValueError(
                f"{self.name}: burn_threshold must be > 0, got "
                f"{self.burn_threshold!r}")
        if not 0.0 < float(self.thrash_rate) < 1.0:
            raise ValueError(
                f"{self.name}: thrash_rate is a change *fraction* in (0, 1), "
                f"got {self.thrash_rate!r}")

    # -- constructors ---------------------------------------------------

    @classmethod
    def slo_burn(cls, name: str = "slo_burn", *, slo_target: float = 0.99,
                 burn_threshold: float = 2.0, fast_halflife: float = 8.0,
                 slow_halflife: float = 64.0,
                 severity: str = "page") -> "AlertRule":
        return cls(name=name, kind="slo_burn", severity=severity,
                   slo_target=slo_target, burn_threshold=burn_threshold,
                   fast_halflife=fast_halflife, slow_halflife=slow_halflife)

    @classmethod
    def lag_growth(cls, name: str = "lag_growth", *,
                   growth_halflife: float = 16.0, sustain_steps: int = 8,
                   min_growth: float = 0.0,
                   severity: str = "page") -> "AlertRule":
        return cls(name=name, kind="lag_growth", severity=severity,
                   growth_halflife=growth_halflife,
                   sustain_steps=sustain_steps, min_growth=min_growth)

    @classmethod
    def rebalance_storm(cls, name: str = "rebalance_storm", *,
                        storm_steps: int = 4,
                        severity: str = "ticket") -> "AlertRule":
        return cls(name=name, kind="rebalance_storm", severity=severity,
                   storm_steps=storm_steps)

    @classmethod
    def consumer_thrash(cls, name: str = "consumer_thrash", *,
                        thrash_halflife: float = 16.0,
                        thrash_rate: float = 0.25,
                        severity: str = "ticket") -> "AlertRule":
        return cls(name=name, kind="consumer_thrash", severity=severity,
                   thrash_halflife=thrash_halflife, thrash_rate=thrash_rate)


def default_rules(*, slo_target: float = 0.99) -> Tuple[AlertRule, ...]:
    """The canonical four-rule set: one rule per failure mode the paper
    prices (SLO burn, Eq. 1 invariant, rebalance downtime, flapping)."""
    return (AlertRule.slo_burn(slo_target=slo_target),
            AlertRule.lag_growth(),
            AlertRule.rebalance_storm(),
            AlertRule.consumer_thrash())


@dataclasses.dataclass(frozen=True)
class AlertConfig:
    """A rule set plus the incident-table bound (hashable).

    ``max_incidents`` bounds the per-rule open/close table carried
    through the scan; incidents past the bound still *count* (see
    ``AlertState.count``) but lose their open/close steps.
    """

    rules: Tuple[AlertRule, ...] = ()
    max_incidents: int = 32

    def __post_init__(self) -> None:
        if not self.rules:
            raise ValueError(
                "AlertConfig needs at least one AlertRule (see "
                "repro.telemetry.alerts.default_rules)")
        names = [r.name for r in self.rules]
        if len(set(names)) != len(names):
            raise ValueError(
                f"alert rule names must be unique, got {names}")
        if int(self.max_incidents) < 1:
            raise ValueError(
                f"max_incidents={self.max_incidents!r} must be >= 1")

    @property
    def rule_names(self) -> Tuple[str, ...]:
        return tuple(r.name for r in self.rules)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AlertState:
    """Fixed-shape alert carry: ``R`` rules x ``M = max_incidents`` table
    rows.  ``count`` is the total incidents ever opened per rule (it may
    exceed ``M``; overflowed incidents keep counting but drop their
    table row)."""

    tick: jax.Array         # i32[]    valid steps seen (absolute step)
    fast: jax.Array         # f32[R]   fast EWMA accumulator (per kind)
    fast_w: jax.Array       # f32[R]   its debias weight
    slow: jax.Array         # f32[R]   slow EWMA accumulator
    slow_w: jax.Array       # f32[R]
    consec: jax.Array       # i32[R]   consecutive-condition counter
    prev_lag: jax.Array     # f32[]    last step's total lag
    prev_cons: jax.Array    # f32[]    last step's consumer count
    measure: jax.Array      # f32[R]   current measured value per rule
    active: jax.Array       # bool[R]  rule currently firing
    cur_start: jax.Array    # i32[R]   open step of the firing incident
    cur_peak: jax.Array     # f32[R]   peak measure of the firing incident
    open_step: jax.Array    # i32[R, M]  -1 = row unused
    close_step: jax.Array   # i32[R, M]  -1 = still open / unused
    peak: jax.Array         # f32[R, M]
    count: jax.Array        # i32[R]   incidents ever opened
    rule_names: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))


def alert_init(cfg: AlertConfig) -> AlertState:
    r, m = len(cfg.rules), int(cfg.max_incidents)
    zf = jnp.zeros(r, jnp.float32)
    zi = jnp.zeros(r, jnp.int32)
    return AlertState(
        tick=jnp.int32(0), fast=zf, fast_w=zf, slow=zf, slow_w=zf,
        consec=zi, prev_lag=jnp.float32(0.0), prev_cons=jnp.float32(0.0),
        measure=zf, active=jnp.zeros(r, bool), cur_start=zi - 1,
        cur_peak=zf, open_step=jnp.full((r, m), -1, jnp.int32),
        close_step=jnp.full((r, m), -1, jnp.int32),
        peak=jnp.zeros((r, m), jnp.float32), count=zi,
        rule_names=cfg.rule_names)


def _alpha(halflife: float) -> float:
    return 1.0 - 2.0 ** (-1.0 / float(halflife))


def alert_step(cfg: AlertConfig, state: AlertState, *, lag_total, consumers,
               unreadable, storm_parts, slo_lag,
               valid: Optional[jax.Array] = None) -> AlertState:
    """Evaluate every rule on this step's already-computed scalars.

    Pure ``jnp`` reads -- alerting never changes the trajectories.
    ``valid`` gates padded fleet steps out, like ``sketch_update``.
    """
    lag_total = jnp.asarray(lag_total, jnp.float32)
    consumers = jnp.asarray(consumers, jnp.float32)
    unreadable = jnp.asarray(unreadable, jnp.float32)
    storm_parts = jnp.asarray(storm_parts, jnp.float32)
    tick = state.tick

    fasts, fast_ws, slows, slow_ws = [], [], [], []
    consecs, measures, firings = [], [], []
    dlag = jnp.where(tick > 0, lag_total - state.prev_lag, 0.0)
    changed = jnp.where(tick > 0,
                        (consumers != state.prev_cons).astype(jnp.float32),
                        0.0)
    for i, rule in enumerate(cfg.rules):
        fast, fw = state.fast[i], state.fast_w[i]
        slow, sw = state.slow[i], state.slow_w[i]
        consec = state.consec[i]
        if rule.kind == "slo_burn":
            v = (lag_total > jnp.float32(slo_lag)).astype(jnp.float32)
            af = jnp.float32(_alpha(rule.fast_halflife))
            as_ = jnp.float32(_alpha(rule.slow_halflife))
            fast = (1 - af) * fast + af * v
            fw = (1 - af) * fw + af
            slow = (1 - as_) * slow + as_ * v
            sw = (1 - as_) * sw + as_
            budget = jnp.float32(1.0 - rule.slo_target)
            burn_fast = fast / jnp.maximum(fw, 1e-12) / budget
            burn_slow = slow / jnp.maximum(sw, 1e-12) / budget
            measure = jnp.minimum(burn_fast, burn_slow)
            firing = measure > jnp.float32(rule.burn_threshold)
        elif rule.kind == "lag_growth":
            ag = jnp.float32(_alpha(rule.growth_halflife))
            fast = (1 - ag) * fast + ag * dlag
            fw = (1 - ag) * fw + ag
            measure = fast / jnp.maximum(fw, 1e-12)
            grow = measure > jnp.float32(rule.min_growth)
            consec = jnp.where(grow, consec + 1, 0)
            firing = consec >= rule.sustain_steps
        elif rule.kind == "rebalance_storm":
            blocked = (unreadable > 0) | (storm_parts > 0)
            consec = jnp.where(blocked, consec + 1, 0)
            measure = consec.astype(jnp.float32)
            firing = consec >= rule.storm_steps
        else:                                   # consumer_thrash
            at = jnp.float32(_alpha(rule.thrash_halflife))
            fast = (1 - at) * fast + at * changed
            fw = (1 - at) * fw + at
            measure = fast / jnp.maximum(fw, 1e-12)
            firing = measure > jnp.float32(rule.thrash_rate)
        fasts.append(fast)
        fast_ws.append(fw)
        slows.append(slow)
        slow_ws.append(sw)
        consecs.append(consec)
        measures.append(measure)
        firings.append(firing)

    measure = jnp.stack(measures)
    firing = jnp.stack(firings)
    r, m = state.open_step.shape
    rows = jnp.arange(r)
    opening = firing & ~state.active
    closing = ~firing & state.active
    # the firing incident's running peak (seeded by the opening measure)
    cur_peak = jnp.where(opening, measure,
                         jnp.where(state.active & firing,
                                   jnp.maximum(state.cur_peak, measure),
                                   state.cur_peak))
    cur_start = jnp.where(opening, tick, state.cur_start)
    # open: write row `count` (if it still fits the bounded table)
    oslot = jnp.clip(state.count, 0, m - 1)
    o_ok = opening & (state.count < m)
    open_step = state.open_step.at[rows, oslot].set(
        jnp.where(o_ok, tick, state.open_step[rows, oslot]))
    # close: the open incident lives at row `count - 1`
    cslot = jnp.clip(state.count - 1, 0, m - 1)
    c_ok = closing & (state.count >= 1) & (state.count <= m)
    close_step = state.close_step.at[rows, cslot].set(
        jnp.where(c_ok, tick - 1, state.close_step[rows, cslot]))
    peak = state.peak.at[rows, cslot].set(
        jnp.where(c_ok, cur_peak, state.peak[rows, cslot]))
    new = AlertState(
        tick=tick + 1,
        fast=jnp.stack(fasts), fast_w=jnp.stack(fast_ws),
        slow=jnp.stack(slows), slow_w=jnp.stack(slow_ws),
        consec=jnp.stack(consecs),
        prev_lag=lag_total, prev_cons=consumers,
        measure=measure, active=firing,
        cur_start=cur_start, cur_peak=cur_peak,
        open_step=open_step, close_step=close_step, peak=peak,
        count=state.count + opening.astype(jnp.int32),
        rule_names=state.rule_names)
    if valid is None:
        return new
    keep = jnp.asarray(valid, bool)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(keep, a, b), new, state)


# ---------------------------------------------------------------------------
# host-side decoding
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Incident:
    """One decoded incident.  ``open_step``/``close_step`` are inclusive
    simulation steps; a still-open incident closes at the last step with
    ``still_open=True``.  ``index`` locates the stream in a batched
    state (e.g. ``(policy,)`` through ``api.simulate``)."""

    rule: str
    kind: str
    severity: str
    open_step: int
    close_step: int
    duration_s: float
    peak: float
    still_open: bool = False
    index: Tuple[int, ...] = ()

    def as_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "kind": self.kind,
                "severity": self.severity, "open_step": self.open_step,
                "close_step": self.close_step,
                "duration_s": round(float(self.duration_s), 6),
                "peak": round(float(self.peak), 6),
                "still_open": self.still_open, "index": list(self.index)}


def decode_incidents(state: AlertState, cfg: AlertConfig,
                     dt: float = 1.0) -> List[Incident]:
    """Typed incidents from a (possibly batched) final ``AlertState``,
    ordered by ``(index, open_step, rule)``.  Incidents past the bounded
    table are counted but carry no rows; compare ``incident_counts``
    against ``len(decode_incidents(...))`` to detect the overflow."""
    rule_of = {r.name: r for r in cfg.rules}
    counts = np.asarray(state.count)
    lead = counts.shape[:-1]
    opens = np.asarray(state.open_step)
    closes = np.asarray(state.close_step)
    peaks = np.asarray(state.peak)
    cur_peak = np.asarray(state.cur_peak)
    active = np.asarray(state.active)
    ticks = np.asarray(state.tick)
    out: List[Incident] = []
    for index in (np.ndindex(*lead) if lead else [()]):
        t_end = int(ticks[index]) - 1
        for ri, name in enumerate(state.rule_names):
            rule = rule_of[name]
            n_rows = min(int(counts[index + (ri,)]), opens.shape[-1])
            for row in range(n_rows):
                o = int(opens[index + (ri, row)])
                if o < 0:
                    continue
                c = int(closes[index + (ri, row)])
                if c >= 0:
                    out.append(Incident(
                        rule=name, kind=rule.kind, severity=rule.severity,
                        open_step=o, close_step=c,
                        duration_s=(c - o + 1) * dt,
                        peak=float(peaks[index + (ri, row)]), index=index))
                elif bool(active[index + (ri,)]) and t_end >= o:
                    out.append(Incident(
                        rule=name, kind=rule.kind, severity=rule.severity,
                        open_step=o, close_step=t_end,
                        duration_s=(t_end - o + 1) * dt,
                        peak=float(cur_peak[index + (ri,)]),
                        still_open=True, index=index))
    out.sort(key=lambda e: (e.index, e.open_step, e.rule))
    return out


def incident_counts(state: AlertState) -> Dict[str, int]:
    """Total incidents per rule (overflowed ones included), summed over
    any leading batch axes."""
    counts = np.asarray(state.count)
    flat = counts.reshape(-1, counts.shape[-1]).sum(axis=0)
    return {name: int(flat[i]) for i, name in enumerate(state.rule_names)}


def incident_matrix(state: AlertState) -> np.ndarray:
    """Per-stream total incident counts with batch axes *preserved*:
    ``state.count`` summed over its trailing rule axis only
    (``f32[...]``, e.g. ``[P]`` for one fleet scenario).  This is the
    adversarial search's fitness component -- unlike
    :func:`incident_counts` it keeps every scenario/policy stream
    separate, so a fitness oracle can credit incidents to the genome
    that caused them."""
    counts = np.asarray(state.count)
    return counts.sum(axis=-1).astype(np.float32)


def incident_summary(state: AlertState, cfg: AlertConfig,
                     dt: float = 1.0) -> Dict[str, Dict[str, float]]:
    """Per-rule roll-up for BENCH blocks / exporters: incident count,
    total alert duration, peak measurement, and how many are still
    open."""
    incidents = decode_incidents(state, cfg, dt=dt)
    counts = incident_counts(state)
    out: Dict[str, Dict[str, float]] = {
        name: {"count": float(counts.get(name, 0)),
               "total_duration_s": 0.0, "peak": 0.0, "open": 0.0}
        for name in state.rule_names
    }
    for inc in incidents:
        row = out[inc.rule]
        row["total_duration_s"] += inc.duration_s
        row["peak"] = max(row["peak"], inc.peak)
        row["open"] += 1.0 if inc.still_open else 0.0
    return out


__all__ = [
    "ALERT_KINDS",
    "AlertConfig",
    "AlertRule",
    "AlertState",
    "Incident",
    "alert_init",
    "alert_step",
    "decode_incidents",
    "default_rules",
    "incident_counts",
    "incident_matrix",
    "incident_summary",
]
