"""Host-side span profiling: where wall time goes, outside the jaxprs.

The in-loop recorder (``telemetry.record``) answers *what the simulated
system did*; this module answers *where the host spent its time* --
tracing, XLA compilation, dispatch, result transfer -- the compile-vs-
dispatch split the ROADMAP's megakernel item needs a baseline for.

``span(name, **args)`` is a context manager that appends one timed
``SpanRecord`` to the process-wide default :class:`Tracer`;
``@traced()`` wraps a function in one.  Every record carries its
``call_index`` (the nth occurrence of that span name), so first-call
(trace + compile) and steady-state costs separate cleanly:
``Tracer.summary()`` reports ``first_us`` vs ``steady_us`` per name, and
``Tracer.to_chrome_trace()`` exports the whole run as Chrome
``trace_event`` JSON -- load it at https://ui.perfetto.dev (or
``chrome://tracing``) to see a ``fleet_bench`` run as a timeline.

Everything here is stdlib-only (no jax import), so ``repro.api`` can
instrument its verbs without losing its jax-free import.  The tracer is
a bounded flight recorder: past ``max_spans`` records new spans are
dropped (and counted in ``dropped``), never grown without bound.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional


@dataclasses.dataclass
class SpanRecord:
    """One completed span (times in microseconds since tracer epoch)."""

    name: str
    start_us: float
    dur_us: float
    call_index: int            # nth occurrence of this name (0 = first call)
    tid: int                   # host thread id
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Tracer:
    """Bounded, process-wide span collector with Chrome-trace export."""

    def __init__(self, max_spans: int = 100_000, enabled: bool = True):
        if max_spans < 1:
            raise ValueError(f"max_spans must be >= 1, got {max_spans}")
        self.max_spans = max_spans
        self.enabled = enabled
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self.spans: List[SpanRecord] = []
        self.dropped = 0
        self._counts: Dict[str, int] = {}

    # -- recording ----------------------------------------------------------

    def reset(self) -> None:
        """Drop every record and restart the epoch and call indices."""
        with self._lock:
            self.spans.clear()
            self._counts.clear()
            self.dropped = 0
            self._epoch = time.perf_counter()

    @contextlib.contextmanager
    def span(self, name: str, **args: Any) -> Iterator[Optional[Dict]]:
        """Time the enclosed block as one span.

        Yields the (mutable) args dict so the block can attach results
        discovered mid-span (e.g. a cache-hit flag); yields ``None`` when
        the tracer is disabled.
        """
        if not self.enabled:
            yield None
            return
        t0 = time.perf_counter()
        try:
            yield args
        finally:
            t1 = time.perf_counter()
            with self._lock:
                idx = self._counts.get(name, 0)
                self._counts[name] = idx + 1
                if len(self.spans) >= self.max_spans:
                    self.dropped += 1
                else:
                    self.spans.append(SpanRecord(
                        name=name,
                        start_us=(t0 - self._epoch) * 1e6,
                        dur_us=(t1 - t0) * 1e6,
                        call_index=idx,
                        tid=threading.get_ident(),
                        args=dict(args)))

    def instant(self, name: str, **args: Any) -> None:
        """Record a zero-duration event (cache hits/misses, evictions)."""
        with self.span(name, **args):
            pass

    def traced(self, name: Optional[str] = None) -> Callable:
        """Decorator: run the function inside ``span(name or qualname)``."""

        def deco(fn: Callable) -> Callable:
            label = name if name is not None else fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(label):
                    return fn(*a, **kw)

            return wrapper

        return deco

    # -- reductions ---------------------------------------------------------

    def records(self, name: Optional[str] = None,
                **arg_filter: Any) -> List[SpanRecord]:
        """Snapshot of records, optionally filtered by name and arg values."""
        with self._lock:
            out = list(self.spans)
        if name is not None:
            out = [r for r in out if r.name == name]
        for k, v in arg_filter.items():
            out = [r for r in out if r.args.get(k) == v]
        return out

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name durations, first call split from steady state.

        ``first_us`` is the ``call_index == 0`` span (trace + compile for
        a jitted callee); ``steady_us`` the mean over the rest (pure
        dispatch + execution); ``count`` and ``total_us`` cover both.
        """
        per: Dict[str, List[SpanRecord]] = {}
        for r in self.records():
            per.setdefault(r.name, []).append(r)
        out: Dict[str, Dict[str, float]] = {}
        for nm, rs in sorted(per.items()):
            first = [r.dur_us for r in rs if r.call_index == 0]
            rest = [r.dur_us for r in rs if r.call_index > 0]
            out[nm] = {
                "count": float(len(rs)),
                "total_us": float(sum(r.dur_us for r in rs)),
                "first_us": float(first[0]) if first else 0.0,
                "steady_us": float(sum(rest) / len(rest)) if rest else 0.0,
            }
        return out

    # -- export -------------------------------------------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON (the format Perfetto ingests).

        Complete ``ph: "X"`` duration events on one process track, one
        thread row per host thread; span args ride along for the
        Perfetto details pane.
        """
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": "repro"},
        }]
        for r in self.records():
            events.append({
                "name": r.name,
                "cat": r.name.split(".", 1)[0],
                "ph": "X",
                "ts": r.start_us,
                "dur": r.dur_us,
                "pid": 0,
                "tid": r.tid,
                "args": {**r.args, "call_index": r.call_index},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> Dict[str, Any]:
        """Write the Chrome/Perfetto trace JSON to ``path``."""
        out = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        return out


def validate_chrome_trace(trace: Dict[str, Any]) -> None:
    """Assert ``trace`` is structurally valid Chrome ``trace_event`` JSON
    (the checks Perfetto's importer performs on load); raises ``ValueError``
    naming the first offending event otherwise."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"traceEvents[{i}] has no phase ('ph') field")
        if ev["ph"] == "X":
            for k in ("name", "ts", "dur", "pid", "tid"):
                if k not in ev:
                    raise ValueError(
                        f"traceEvents[{i}] (ph=X, "
                        f"name={ev.get('name')!r}) is missing {k!r}")
            if ev["dur"] < 0:
                raise ValueError(
                    f"traceEvents[{i}] ({ev['name']!r}) has negative dur")


_DEFAULT = Tracer()


def default_tracer() -> Tracer:
    """The process-wide tracer every module-level ``span`` records into."""
    return _DEFAULT


def span(name: str, **args: Any):
    """``with span("api.simulate", policies=3): ...`` on the default tracer."""
    return _DEFAULT.span(name, **args)


def traced(name: Optional[str] = None) -> Callable:
    """Decorator form of :func:`span` on the default tracer."""
    return _DEFAULT.traced(name)


def instant(name: str, **args: Any) -> None:
    """Zero-duration event on the default tracer (cache hits, evictions)."""
    _DEFAULT.instant(name, **args)


__all__ = [
    "SpanRecord",
    "Tracer",
    "default_tracer",
    "instant",
    "span",
    "traced",
    "validate_chrome_trace",
]
