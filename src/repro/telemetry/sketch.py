"""Scan-safe streaming metric sketches: whole-run summaries in O(1)/step.

The flight recorder (``telemetry.record``) answers *what happened at
step t* -- but materializing T frames is exactly the O(T) the ROADMAP's
planet-scale item (10^4-10^6 partitions, week-long horizons) rules out.
This module carries constant-size **online aggregators** through the
lagsim ``lax.scan``, one slot per telemetry channel:

* Welford mean / variance (numerically stable single-pass moments);
* running min / max;
* debiased EWMA windows at configurable half-lives (the "last ~H steps"
  view an SLO dashboard plots);
* a fixed-bin histogram sketch over selected channels, giving whole-run
  quantiles (e.g. the p99 of total lag) within one bin of resolution --
  without ever holding the per-step history.

Everything is pure ``jnp`` on values the engine's step already computes:
sketches on never changes the simulated trajectories, and sketches off
emits the pre-existing program bit-for-bit.

The update takes an optional ``valid`` scalar so the fleet layer's
bucket padding stays exact: a padded timestep leaves the sketch state
untouched (``where(valid, new, old)``), so a padded run's sketch equals
the direct run's bit-for-bit.  Host-side, :class:`SketchSummary`
finalizes a state (debiasing EWMAs, deriving stddev and quantiles) and
**merges across buckets/scenarios** with Chan's parallel-variance
update, so a fleet of thousands of scenarios reduces to one summary
without restacking trajectories.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Static sketch knobs (hashable: rides ``TelemetryConfig`` inside
    the engine's jit key).

    ``ewma_halflives`` are in *steps*: a window's weight on a sample
    halves every ``h`` steps (``alpha = 1 - 2**(-1/h)``).
    ``hist_channels`` selects which channels get a fixed-bin histogram
    over ``[0, hist_max]`` (values clamp into the edge bins, so choose
    ``hist_max`` to cover the workload's lag range; ``None`` lets
    ``LagSimConfig.resolve`` default it to ``8 * capacity * dt * n`` --
    eight consumer-steps of drain per partition).  Quantile estimates
    are exact to one bin width ``hist_max / hist_bins``.
    """

    ewma_halflives: Tuple[float, ...] = (8.0, 64.0)
    hist_bins: int = 64
    hist_channels: Tuple[str, ...] = ("lag_total",)
    hist_max: Optional[float] = None

    def __post_init__(self) -> None:
        for h in self.ewma_halflives:
            if not float(h) > 0.0:
                raise ValueError(
                    f"ewma_halflives entries must be > 0 steps, got {h!r}")
        if int(self.hist_bins) < 2:
            raise ValueError(
                f"hist_bins={self.hist_bins!r} must be >= 2 (one bin cannot "
                f"locate a quantile)")
        if self.hist_max is not None and not float(self.hist_max) > 0.0:
            raise ValueError(
                f"hist_max={self.hist_max!r} must be > 0 (or None to derive "
                f"a default from the lagsim config)")

    @property
    def alphas(self) -> Tuple[float, ...]:
        """Per-step EWMA decay rates derived from the half-lives."""
        return tuple(1.0 - 2.0 ** (-1.0 / float(h))
                     for h in self.ewma_halflives)

    @property
    def bin_width(self) -> float:
        """Histogram bin width -- the quantile resolution bound."""
        if self.hist_max is None:
            raise ValueError(
                "hist_max is unresolved (None); run through LagSimConfig."
                "resolve or set it explicitly")
        return float(self.hist_max) / int(self.hist_bins)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SketchState:
    """The carried aggregator bundle (``K`` channels, ``H`` half-lives,
    ``C`` histogrammed channels x ``B`` bins).  All leaves are fixed
    shape, so the state scans, jits, vmaps, and stacks."""

    count: jax.Array      # f32[]     valid steps aggregated
    mean: jax.Array       # f32[K]    Welford running mean
    m2: jax.Array         # f32[K]    Welford sum of squared deviations
    vmin: jax.Array       # f32[K]
    vmax: jax.Array       # f32[K]
    ewma: jax.Array       # f32[H, K] biased EWMA (debias via ewma_w)
    ewma_w: jax.Array     # f32[H]    accumulated EWMA weight (debiasing)
    hist: jax.Array       # f32[C, B] per-channel fixed-bin counts
    names: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    hist_names: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))


def _hist_indices(cfg: SketchConfig, names: Tuple[str, ...]) -> Tuple[int, ...]:
    idx = []
    for ch in cfg.hist_channels:
        if ch not in names:
            raise ValueError(
                f"SketchConfig.hist_channels names unknown channel {ch!r}; "
                f"this run records {names}")
        idx.append(names.index(ch))
    return tuple(idx)


def sketch_init(cfg: SketchConfig, names: Tuple[str, ...]) -> SketchState:
    """Zero state for ``names`` (the run's full channel tuple, custom
    counters included).  Raises (named) if a ``hist_channels`` entry is
    not a recorded channel."""
    _hist_indices(cfg, names)           # fail fast on unknown channels
    k = len(names)
    h = len(cfg.ewma_halflives)
    c = len(cfg.hist_channels)
    return SketchState(
        count=jnp.float32(0.0),
        mean=jnp.zeros(k, jnp.float32),
        m2=jnp.zeros(k, jnp.float32),
        vmin=jnp.full(k, jnp.inf, jnp.float32),
        vmax=jnp.full(k, -jnp.inf, jnp.float32),
        ewma=jnp.zeros((h, k), jnp.float32),
        ewma_w=jnp.zeros(h, jnp.float32),
        hist=jnp.zeros((c, int(cfg.hist_bins)), jnp.float32),
        names=tuple(names),
        hist_names=tuple(cfg.hist_channels))


def sketch_update(cfg: SketchConfig, state: SketchState, vec: jax.Array,
                  valid: Optional[jax.Array] = None) -> SketchState:
    """One O(K) update with the step's channel vector ``f32[K]``.

    ``valid`` (scalar bool, optional) gates the update: a ``False`` step
    (fleet bucket padding) leaves every aggregate untouched, keeping
    padded runs bit-identical to direct runs.
    """
    c1 = state.count + 1.0
    d = vec - state.mean
    mean = state.mean + d / c1
    m2 = state.m2 + d * (vec - mean)
    vmin = jnp.minimum(state.vmin, vec)
    vmax = jnp.maximum(state.vmax, vec)
    al = jnp.asarray(cfg.alphas, jnp.float32)[:, None]        # [H, 1]
    ewma = (1.0 - al) * state.ewma + al * vec[None, :]
    ewma_w = (1.0 - al[:, 0]) * state.ewma_w + al[:, 0]
    hist = state.hist
    if state.hist_names:
        width = jnp.float32(cfg.bin_width)
        rows = jnp.arange(len(state.hist_names))
        x = vec[jnp.asarray(_hist_indices(cfg, state.names))]
        slot = jnp.clip((x / width).astype(jnp.int32), 0,
                        int(cfg.hist_bins) - 1)
        hist = hist.at[rows, slot].add(1.0)
    new = SketchState(count=c1, mean=mean, m2=m2, vmin=vmin, vmax=vmax,
                      ewma=ewma, ewma_w=ewma_w, hist=hist,
                      names=state.names, hist_names=state.hist_names)
    if valid is None:
        return new
    keep = jnp.asarray(valid, bool)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(keep, a, b), new, state)


# ---------------------------------------------------------------------------
# host-side finalization + cross-bucket merging
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SketchSummary:
    """A finalized sketch: plain numpy, one row per channel.

    ``ewma`` maps half-life -> debiased window value per channel;
    ``hist`` / ``edges`` back :meth:`quantile`.  ``m2`` is kept (not just
    the stddev) so :func:`merge_summaries` can combine summaries with
    Chan's parallel-variance update.
    """

    names: Tuple[str, ...]
    count: float
    mean: np.ndarray                    # f64[K]
    m2: np.ndarray                      # f64[K]
    vmin: np.ndarray                    # f64[K]
    vmax: np.ndarray                    # f64[K]
    ewma: Dict[float, np.ndarray]       # halflife -> f64[K] (debiased)
    hist: np.ndarray                    # f64[C, B]
    hist_names: Tuple[str, ...]
    edges: np.ndarray                   # f64[B + 1] shared bin edges

    @classmethod
    def from_state(cls, state: SketchState,
                   cfg: SketchConfig) -> "SketchSummary":
        """Finalize one stream's state (no leading batch axes -- index
        or ``tree_map`` a batched state down to one stream first)."""
        count = np.asarray(state.count, np.float64)
        if count.ndim != 0:
            raise ValueError(
                f"from_state finalizes ONE stream; this state has leading "
                f"batch shape {count.shape} -- slice it (see "
                f"summaries_from_state) or merge_summaries the slices")
        w = np.asarray(state.ewma_w, np.float64)
        raw = np.asarray(state.ewma, np.float64)
        ewma = {
            float(h): (raw[i] / w[i] if w[i] > 0 else np.zeros(raw.shape[1]))
            for i, h in enumerate(cfg.ewma_halflives)
        }
        bins = int(cfg.hist_bins)
        return cls(
            names=state.names,
            count=float(count),
            mean=np.asarray(state.mean, np.float64),
            m2=np.asarray(state.m2, np.float64),
            vmin=np.asarray(state.vmin, np.float64),
            vmax=np.asarray(state.vmax, np.float64),
            ewma=ewma,
            hist=np.asarray(state.hist, np.float64),
            hist_names=state.hist_names,
            edges=np.linspace(0.0, float(cfg.hist_max), bins + 1))

    # -- derived views ------------------------------------------------------

    def channel_index(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise ValueError(
                f"unknown channel {name!r}; this sketch covers {self.names}")

    def variance(self) -> np.ndarray:
        """Population variance per channel (0 where count < 2)."""
        if self.count < 2:
            return np.zeros_like(self.mean)
        return self.m2 / self.count

    def stddev(self) -> np.ndarray:
        return np.sqrt(np.maximum(self.variance(), 0.0))

    def quantile(self, q: float, channel: Optional[str] = None) -> float:
        """Histogram quantile estimate (bin-center of the bin holding the
        q-th observation; exact to one bin width).  ``channel`` defaults
        to the single histogrammed channel."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q!r}")
        if channel is None:
            if len(self.hist_names) != 1:
                raise ValueError(
                    f"pass channel= explicitly; this sketch histograms "
                    f"{self.hist_names}")
            channel = self.hist_names[0]
        if channel not in self.hist_names:
            raise ValueError(
                f"channel {channel!r} has no histogram; sketched: "
                f"{self.hist_names} (add it to SketchConfig.hist_channels)")
        counts = self.hist[self.hist_names.index(channel)]
        total = counts.sum()
        if total <= 0:
            return 0.0
        cum = np.cumsum(counts)
        k = int(np.searchsorted(cum, q * total, side="left"))
        k = min(k, len(counts) - 1)
        return float(0.5 * (self.edges[k] + self.edges[k + 1]))

    def as_dict(self, quantiles: Sequence[float] = (0.5, 0.9, 0.99)
                ) -> Dict[str, Any]:
        """JSON-ready nested dict (the shape the BENCH ``telemetry``
        blocks embed)."""
        std = self.stddev()
        out: Dict[str, Any] = {"count": self.count, "channels": {}}
        for i, nm in enumerate(self.names):
            row = {
                "mean": float(self.mean[i]),
                "std": float(std[i]),
                "min": float(self.vmin[i]) if self.count else 0.0,
                "max": float(self.vmax[i]) if self.count else 0.0,
            }
            for h, v in sorted(self.ewma.items()):
                row[f"ewma_h{h:g}"] = float(v[i])
            out["channels"][nm] = row
        for ch in self.hist_names:
            out["channels"][ch].update({
                f"p{int(round(q * 100)):02d}": self.quantile(q, ch)
                for q in quantiles
            })
        return out


def summaries_from_state(state: SketchState, cfg: SketchConfig
                         ) -> List[Tuple[Tuple[int, ...], SketchSummary]]:
    """Finalize every stream of a batched state (any leading shape on
    ``count``) -> ``[(index, summary), ...]`` in ``np.ndindex`` order."""
    lead = np.asarray(state.count).shape
    out = []
    for index in (np.ndindex(*lead) if lead else [()]):
        one = jax.tree_util.tree_map(lambda a: np.asarray(a)[index], state)
        out.append((index, SketchSummary.from_state(one, cfg)))
    return out


def merge_summaries(summaries: Sequence[SketchSummary]) -> SketchSummary:
    """Combine per-bucket/per-scenario summaries into one, as if a single
    sketch had seen every (valid) step.

    Exact for count / mean / variance (Chan's parallel update), min /
    max, and the histogram (bin-wise sum, so merged quantiles keep the
    one-bin resolution bound).  EWMA windows are *stream-local* recency
    views with no exact cross-stream merge; the merged value is the
    count-weighted mean, flagged as such in the docs.
    """
    ss = list(summaries)
    if not ss:
        raise ValueError("merge_summaries needs at least one summary")
    first = ss[0]
    for s in ss[1:]:
        if s.names != first.names or s.hist_names != first.hist_names:
            raise ValueError(
                f"cannot merge sketches over different channel sets: "
                f"{s.names} vs {first.names}")
        if s.edges.shape != first.edges.shape or not np.allclose(
                s.edges, first.edges):
            raise ValueError(
                "cannot merge sketches with different histogram edges "
                "(hist_max/hist_bins must match across the fleet)")
    count = 0.0
    mean = np.zeros_like(first.mean)
    m2 = np.zeros_like(first.m2)
    vmin = np.full_like(first.vmin, np.inf)
    vmax = np.full_like(first.vmax, -np.inf)
    hist = np.zeros_like(first.hist)
    ew_num = {h: np.zeros_like(v) for h, v in first.ewma.items()}
    for s in ss:
        if s.count > 0:
            delta = s.mean - mean
            tot = count + s.count
            m2 = m2 + s.m2 + delta * delta * (count * s.count / tot)
            mean = mean + delta * (s.count / tot)
            count = tot
            vmin = np.minimum(vmin, s.vmin)
            vmax = np.maximum(vmax, s.vmax)
        hist = hist + s.hist
        for h, v in s.ewma.items():
            ew_num[h] = ew_num[h] + v * s.count
    ewma = {h: (num / count if count > 0 else num)
            for h, num in ew_num.items()}
    return SketchSummary(names=first.names, count=count, mean=mean, m2=m2,
                         vmin=vmin, vmax=vmax, ewma=ewma, hist=hist,
                         hist_names=first.hist_names, edges=first.edges)


__all__ = [
    "SketchConfig",
    "SketchState",
    "SketchSummary",
    "merge_summaries",
    "sketch_init",
    "sketch_update",
    "summaries_from_state",
]
