"""Industry-standard metric export: Prometheus text exposition and
OpenTelemetry-style JSON, stdlib-only (no jax import).

Real autoscalers in this space are judged by their monitoring surface
(KEDA publishes its lag trigger as Prometheus metrics; the paper's
R-score is a downtime SLI); this module gives every run the same
surface.  :func:`prometheus_exposition` renders a
:class:`~repro.telemetry.sketch.SketchSummary`, a decoded incident
list, and a :class:`~repro.telemetry.spans.Tracer` summary as
`text/plain; version=0.0.4` exposition -- the format a Prometheus
scrape endpoint serves -- with the sketch histogram emitted as a native
Prometheus histogram (cumulative ``_bucket{le=...}`` + ``_sum`` +
``_count``).  :func:`otlp_metrics_json` / :func:`otlp_spans_json` emit
the OpenTelemetry protocol's JSON encoding (``resourceMetrics`` /
``resourceSpans``) for OTLP-ingesting backends.

:func:`validate_exposition` is a pure-python linter for the exposition
format (metric/label name grammar, ``TYPE``-before-samples, histogram
bucket monotonicity, ``+Inf`` == ``_count``) so CI can gate on the
output actually being scrapeable.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

_METRIC_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)(?:\s+\d+)?$")
_LABEL_PAIR_RE = re.compile(
    r'^(?P<key>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<val>(?:[^"\\]|\\.)*)"$')


def _fmt(v: float) -> str:
    """Prometheus sample values: shortest lossless float, Inf/NaN named."""
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _labels(pairs: Mapping[str, str]) -> str:
    if not pairs:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(pairs.items()))
    return "{" + body + "}"


def _sanitize(name: str) -> str:
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not out or not _METRIC_RE.match(out):
        out = "_" + out
    return out


class _Writer:
    def __init__(self) -> None:
        self.lines: List[str] = []
        self._typed: set = set()

    def header(self, name: str, mtype: str, help_text: str) -> None:
        if name in self._typed:
            return
        self._typed.add(name)
        self.lines.append(f"# HELP {name} {help_text}")
        self.lines.append(f"# TYPE {name} {mtype}")

    def sample(self, name: str, labels: Mapping[str, str],
               value: float) -> None:
        self.lines.append(f"{name}{_labels(labels)} {_fmt(value)}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def prometheus_exposition(sketch: Optional[Any] = None,
                          incidents: Optional[Sequence[Any]] = None,
                          spans: Optional[Mapping[str, Mapping[str, float]]] = None,
                          labels: Optional[Mapping[str, str]] = None,
                          prefix: str = "repro") -> str:
    """Render a scrape body from any subset of the observability surface.

    ``sketch`` is a :class:`SketchSummary` (means/extrema/EWMAs as
    gauges, histogrammed channels as native histograms); ``incidents``
    a list of decoded :class:`Incident` records (counts and durations by
    rule/severity); ``spans`` a ``Tracer.summary()`` mapping.  ``labels``
    ride every sample (e.g. ``{"scenario": "burst"}``).
    """
    base = dict(labels or {})
    for k in base:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid Prometheus label name {k!r}")
    w = _Writer()

    if sketch is not None:
        p = f"{prefix}_sketch"
        w.header(f"{p}_steps", "gauge",
                 "Valid simulation steps aggregated by the sketch.")
        w.sample(f"{p}_steps", base, sketch.count)
        for stat, vec in (("mean", sketch.mean), ("std", sketch.stddev()),
                          ("min", sketch.vmin), ("max", sketch.vmax)):
            name = f"{p}_{stat}"
            w.header(name, "gauge",
                     f"Per-channel whole-run {stat} from the online sketch.")
            for i, ch in enumerate(sketch.names):
                v = float(vec[i])
                if sketch.count == 0 and stat in ("min", "max"):
                    v = 0.0
                w.sample(name, {**base, "channel": ch}, v)
        name = f"{p}_ewma"
        w.header(name, "gauge",
                 "Debiased EWMA window per channel (halflife in steps).")
        for h, vec in sorted(sketch.ewma.items()):
            for i, ch in enumerate(sketch.names):
                w.sample(name, {**base, "channel": ch, "halflife": f"{h:g}"},
                         float(vec[i]))
        for ci, ch in enumerate(sketch.hist_names):
            name = f"{p}_{_sanitize(ch)}"
            w.header(name, "histogram",
                     f"Fixed-bin whole-run distribution of {ch}.")
            counts = sketch.hist[ci]
            cum = 0.0
            for bi in range(len(counts)):
                cum += float(counts[bi])
                w.sample(f"{name}_bucket",
                         {**base, "le": _fmt(float(sketch.edges[bi + 1]))},
                         cum)
            w.sample(f"{name}_bucket", {**base, "le": "+Inf"}, cum)
            # bin-center mass approximation; exact _sum is not tracked
            centers = [0.5 * (float(sketch.edges[i]) + float(sketch.edges[i + 1]))
                       for i in range(len(counts))]
            w.sample(f"{name}_sum", base,
                     sum(c * float(n) for c, n in zip(centers, counts)))
            w.sample(f"{name}_count", base, cum)

    if incidents is not None:
        p = f"{prefix}_incidents"
        by_rule: Dict[Tuple[str, str], List[Any]] = {}
        for inc in incidents:
            by_rule.setdefault((inc.rule, inc.severity), []).append(inc)
        w.header(f"{p}_total", "counter",
                 "Incidents opened per alert rule over the run.")
        w.header(f"{p}_duration_seconds_total", "counter",
                 "Summed alert-firing duration per rule.")
        w.header(f"{p}_active", "gauge",
                 "Incidents still open at the end of the run.")
        for (rule, severity), incs in sorted(by_rule.items()):
            lbl = {**base, "rule": rule, "severity": severity}
            w.sample(f"{p}_total", lbl, float(len(incs)))
            w.sample(f"{p}_duration_seconds_total", lbl,
                     sum(i.duration_s for i in incs))
            w.sample(f"{p}_active", lbl,
                     float(sum(1 for i in incs if i.still_open)))

    if spans is not None:
        p = f"{prefix}_span"
        w.header(f"{p}_calls_total", "counter",
                 "Host-side span occurrences (Tracer records).")
        w.header(f"{p}_time_microseconds_total", "counter",
                 "Total wall time inside each span name.")
        w.header(f"{p}_steady_microseconds", "gauge",
                 "Mean steady-state (post-first-call) span duration.")
        for nm, row in sorted(spans.items()):
            lbl = {**base, "span": _sanitize(nm)}
            w.sample(f"{p}_calls_total", lbl, row.get("count", 0.0))
            w.sample(f"{p}_time_microseconds_total", lbl,
                     row.get("total_us", 0.0))
            w.sample(f"{p}_steady_microseconds", lbl,
                     row.get("steady_us", 0.0))

    return w.text()


def validate_exposition(text: str) -> None:
    """Lint Prometheus text exposition; raises ``ValueError`` naming the
    first offending line.

    Checks the grammar a scraper enforces: metric/label name charsets,
    ``# TYPE`` declared before its samples, parseable sample values, and
    histogram coherence (``le`` buckets cumulative and non-decreasing,
    ``+Inf`` bucket present and equal to ``_count``).
    """
    types: Dict[str, str] = {}
    hist: Dict[Tuple[str, str], Dict[str, float]] = {}

    def family(name: str) -> str:
        for suf in ("_bucket", "_sum", "_count", "_total"):
            if name.endswith(suf) and name[:-len(suf)] in types:
                return name[:-len(suf)]
        return name

    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(
                    f"line {ln}: malformed comment {line!r} (only '# HELP' "
                    f"and '# TYPE' comments are meaningful)")
            if not _METRIC_RE.match(parts[2]):
                raise ValueError(
                    f"line {ln}: invalid metric name {parts[2]!r}")
            if parts[1] == "TYPE":
                if len(parts) < 4 or parts[3] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped"):
                    raise ValueError(
                        f"line {ln}: invalid TYPE line {line!r}")
                if parts[2] in types:
                    raise ValueError(
                        f"line {ln}: duplicate TYPE for {parts[2]!r}")
                types[parts[2]] = parts[3]
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: unparseable sample {line!r}")
        name = m.group("name")
        fam = family(name)
        if fam in types and types[fam] == "histogram":
            pass
        elif name not in types and fam == name:
            raise ValueError(
                f"line {ln}: sample {name!r} has no preceding # TYPE line")
        labels: Dict[str, str] = {}
        raw = m.group("labels")
        if raw:
            for pair in _split_label_pairs(raw, ln):
                pm = _LABEL_PAIR_RE.match(pair)
                if not pm:
                    raise ValueError(
                        f"line {ln}: malformed label pair {pair!r}")
                labels[pm.group("key")] = pm.group("val")
        val = m.group("value")
        try:
            fval = float(val.replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            raise ValueError(f"line {ln}: non-numeric value {val!r}")
        if fam in types and types[fam] == "histogram":
            key = (fam, json.dumps(
                {k: v for k, v in labels.items() if k != "le"},
                sort_keys=True))
            h = hist.setdefault(key, {"prev": -math.inf, "inf": math.nan,
                                      "cnt": math.nan})
            if name == f"{fam}_bucket":
                if "le" not in labels:
                    raise ValueError(
                        f"line {ln}: histogram bucket without 'le' label")
                if fval < h["prev"] - 1e-9:
                    raise ValueError(
                        f"line {ln}: histogram {fam!r} buckets not "
                        f"cumulative (value decreased)")
                h["prev"] = fval
                if labels["le"] == "+Inf":
                    h["inf"] = fval
            elif name == f"{fam}_count":
                h["cnt"] = fval
    for (fam, lbl), h in hist.items():
        if math.isnan(h["inf"]):
            raise ValueError(
                f"histogram {fam!r} ({lbl}) has no '+Inf' bucket")
        if not math.isnan(h["cnt"]) and abs(h["inf"] - h["cnt"]) > 1e-9:
            raise ValueError(
                f"histogram {fam!r} ({lbl}): +Inf bucket {h['inf']} != "
                f"_count {h['cnt']}")


def _split_label_pairs(raw: str, ln: int) -> List[str]:
    out, buf, quoted, escape = [], [], False, False
    for ch in raw:
        if escape:
            buf.append(ch)
            escape = False
        elif ch == "\\":
            buf.append(ch)
            escape = True
        elif ch == '"':
            buf.append(ch)
            quoted = not quoted
        elif ch == "," and not quoted:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if quoted:
        raise ValueError(f"line {ln}: unterminated label quote")
    if buf:
        out.append("".join(buf))
    return out


# ---------------------------------------------------------------------------
# OpenTelemetry-style JSON (OTLP/JSON encoding, deterministic timestamps)
# ---------------------------------------------------------------------------

def _otlp_attrs(pairs: Mapping[str, Any]) -> List[Dict[str, Any]]:
    out = []
    for k, v in sorted(pairs.items()):
        if isinstance(v, bool):
            val: Dict[str, Any] = {"boolValue": v}
        elif isinstance(v, (int,)):
            val = {"intValue": str(v)}
        elif isinstance(v, float):
            val = {"doubleValue": v}
        else:
            val = {"stringValue": str(v)}
        out.append({"key": k, "value": val})
    return out


def _gauge(name: str, desc: str, unit: str,
           points: List[Dict[str, Any]]) -> Dict[str, Any]:
    return {"name": name, "description": desc, "unit": unit,
            "gauge": {"dataPoints": points}}


def otlp_metrics_json(sketch: Optional[Any] = None,
                      incidents: Optional[Sequence[Any]] = None,
                      resource: Optional[Mapping[str, Any]] = None,
                      time_unix_nano: int = 0) -> Dict[str, Any]:
    """OTLP/JSON ``resourceMetrics`` for a sketch summary and incident
    list.  ``time_unix_nano`` defaults to 0 so output is deterministic;
    stamp real wall-clock time at the call site if a backend needs it.
    """
    ts = str(int(time_unix_nano))
    metrics: List[Dict[str, Any]] = []
    if sketch is not None:
        for stat, vec in (("mean", sketch.mean), ("std", sketch.stddev()),
                          ("min", sketch.vmin), ("max", sketch.vmax)):
            pts = []
            for i, ch in enumerate(sketch.names):
                v = float(vec[i])
                if sketch.count == 0 and stat in ("min", "max"):
                    v = 0.0
                pts.append({"timeUnixNano": ts, "asDouble": v,
                            "attributes": _otlp_attrs({"channel": ch})})
            metrics.append(_gauge(
                f"repro.sketch.{stat}",
                f"Whole-run per-channel {stat} from the online sketch.",
                "1", pts))
        for ci, ch in enumerate(sketch.hist_names):
            counts = sketch.hist[ci]
            total = float(sum(float(c) for c in counts))
            centers = [0.5 * (float(sketch.edges[i]) + float(sketch.edges[i + 1]))
                       for i in range(len(counts))]
            metrics.append({
                "name": f"repro.sketch.hist.{ch}",
                "description": f"Fixed-bin whole-run distribution of {ch}.",
                "unit": "1",
                "histogram": {
                    "aggregationTemporality": 2,   # CUMULATIVE
                    "dataPoints": [{
                        "timeUnixNano": ts,
                        "count": str(int(total)),
                        "sum": sum(c * float(n)
                                   for c, n in zip(centers, counts)),
                        "bucketCounts": [str(int(float(c))) for c in counts],
                        "explicitBounds": [float(e)
                                           for e in sketch.edges[1:-1]],
                        "attributes": _otlp_attrs({"channel": ch}),
                    }],
                },
            })
    if incidents is not None:
        by_rule: Dict[Tuple[str, str], List[Any]] = {}
        for inc in incidents:
            by_rule.setdefault((inc.rule, inc.severity), []).append(inc)
        pts, dur_pts = [], []
        for (rule, severity), incs in sorted(by_rule.items()):
            attrs = _otlp_attrs({"rule": rule, "severity": severity})
            pts.append({"timeUnixNano": ts, "asDouble": float(len(incs)),
                        "attributes": attrs})
            dur_pts.append({"timeUnixNano": ts,
                            "asDouble": sum(i.duration_s for i in incs),
                            "attributes": attrs})
        metrics.append({
            "name": "repro.incidents.count",
            "description": "Incidents opened per alert rule over the run.",
            "unit": "1",
            "sum": {"aggregationTemporality": 2, "isMonotonic": True,
                    "dataPoints": pts},
        })
        metrics.append({
            "name": "repro.incidents.duration",
            "description": "Summed alert-firing duration per rule.",
            "unit": "s",
            "sum": {"aggregationTemporality": 2, "isMonotonic": True,
                    "dataPoints": dur_pts},
        })
    return {"resourceMetrics": [{
        "resource": {"attributes": _otlp_attrs(
            {"service.name": "repro", **(resource or {})})},
        "scopeMetrics": [{
            "scope": {"name": "repro.telemetry", "version": "1"},
            "metrics": metrics,
        }],
    }]}


def otlp_spans_json(records: Sequence[Any],
                    resource: Optional[Mapping[str, Any]] = None,
                    epoch_unix_nano: int = 0) -> Dict[str, Any]:
    """OTLP/JSON ``resourceSpans`` from ``Tracer.records()`` --
    span times are tracer-epoch-relative microseconds, offset by
    ``epoch_unix_nano`` (default 0: deterministic output)."""
    spans = []
    for i, r in enumerate(records):
        start = int(epoch_unix_nano) + int(r.start_us * 1_000)
        spans.append({
            "traceId": "0" * 31 + "1",
            "spanId": f"{i + 1:016x}",
            "name": r.name,
            "kind": 1,                                 # SPAN_KIND_INTERNAL
            "startTimeUnixNano": str(start),
            "endTimeUnixNano": str(start + int(r.dur_us * 1_000)),
            "attributes": _otlp_attrs(
                {**r.args, "call_index": r.call_index, "tid": r.tid}),
        })
    return {"resourceSpans": [{
        "resource": {"attributes": _otlp_attrs(
            {"service.name": "repro", **(resource or {})})},
        "scopeSpans": [{
            "scope": {"name": "repro.telemetry.spans", "version": "1"},
            "spans": spans,
        }],
    }]}


__all__ = [
    "otlp_metrics_json",
    "otlp_spans_json",
    "prometheus_exposition",
    "validate_exposition",
]
