"""In-loop flight recorder: scan-safe structured event capture.

The lag twin's ``lax.scan`` is opaque once compiled -- end-of-run
aggregates cannot say *which* repack decision blew the SLO, or whether a
violation window overlapped a rebalance storm.  This module captures the
answer inside the scan, as pure data flow:

* :class:`TelemetryConfig` -- static, hashable knobs; rides inside
  ``LagSimConfig`` so it participates in jit / fleet compile-cache keys
  automatically.  ``None`` (or ``enabled=False``) is the recorder-free
  path: the engine emits the exact same jaxpr as before this module
  existed, so the goldens stay bit-identical.
* a fixed vector of per-step **channels** (migrations, the per-iteration
  Eq. 10 R-score, unreadable/storm partition counts, replica count,
  active-partition count, total lag and configurable lag quantiles),
  threaded as an extra scan output -- or, with ``ring`` set, written
  into a fixed-shape ring buffer carried through the scan so memory
  stays O(ring) on arbitrarily long simulations;
* :class:`TelemetryFrame` -- the recorded array bundle (a registered
  pytree; channel names are static aux data so they survive jit, vmap
  and stacking);
* :class:`CounterState` -- the custom-counter contract: a policy whose
  scan state is ``CounterState(counters, names, inner)`` gets its
  ``counters`` appended to every recorded step (the registry's policy
  protocol documents this);
* :func:`decode_events` / :class:`EventStream` -- host-side decoding of
  a frame into typed event records (scale decisions, migration bursts,
  rebalance-storm windows, partition births/deaths), with
  ``to_dataframe()`` / ``to_json()`` exporters.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static recorder knobs (hashable: part of the engine's jit key).

    ``lag_quantiles`` adds one ``lag_q{..}`` channel per entry (quantile
    of per-partition backlog over the *active* partitions).  ``ring``
    bounds recorder memory: ``None`` records every step (``T`` rows);
    an integer keeps only the last ``ring`` steps in a carried ring
    buffer (the flight-recorder mode for very long scans).

    The streaming-observability knobs ride here too: ``sketch`` carries
    online aggregators (``repro.telemetry.sketch``) through the scan,
    ``alerts`` evaluates a declarative rule set in-loop
    (``repro.telemetry.alerts``), and ``record_frames=False`` drops the
    per-step frame entirely -- sketches/alerts in O(1) memory with no
    O(T) history, the planet-scale monitoring mode.
    """

    enabled: bool = True
    lag_quantiles: Tuple[float, ...] = (0.5, 0.9, 0.99)
    ring: Optional[int] = None
    record_frames: bool = True
    sketch: Optional["Any"] = None       # telemetry.sketch.SketchConfig
    alerts: Optional["Any"] = None       # telemetry.alerts.AlertConfig

    def __post_init__(self) -> None:
        for q in self.lag_quantiles:
            if not 0.0 <= float(q) <= 1.0:
                raise ValueError(
                    f"lag_quantiles entries must be in [0, 1], got {q!r}")
        if self.ring is not None and int(self.ring) < 1:
            raise ValueError(
                f"ring={self.ring!r} must be a positive number of steps "
                f"(or None to record every step)")
        if self.ring is not None and not self.record_frames:
            raise ValueError(
                "ring is a frame-recorder mode; record_frames=False with "
                "ring set is contradictory (drop ring, or keep frames)")
        if self.sketch is not None:
            from . import sketch as _sketch
            if not isinstance(self.sketch, _sketch.SketchConfig):
                raise TypeError(
                    f"TelemetryConfig.sketch must be a SketchConfig, got "
                    f"{type(self.sketch).__name__}")
        if self.alerts is not None:
            from . import alerts as _alerts
            if not isinstance(self.alerts, _alerts.AlertConfig):
                raise TypeError(
                    f"TelemetryConfig.alerts must be an AlertConfig, got "
                    f"{type(self.alerts).__name__}")

    @property
    def base_channels(self) -> Tuple[str, ...]:
        """Channel names this config records, before custom counters."""
        return BASE_CHANNELS + tuple(
            f"lag_q{int(round(float(q) * 100)):02d}"
            for q in self.lag_quantiles)


#: the always-recorded channels (see ``record_step`` for definitions)
BASE_CHANNELS: Tuple[str, ...] = (
    "consumers",        # replicas billed this step
    "migrations",       # partitions whose owner changed (NEG never counts)
    "rscore",           # Eq. 10 of this step's reassignment: moved speed / C
    "unreadable",       # partitions blocked (migration downtime or storm)
    "storm_parts",      # partitions blocked by a control-plane warm-up storm
    "active_parts",     # partitions that exist this step (mask contract)
    "lag_total",        # total backlog after draining
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CounterState:
    """Custom-counter contract for policies.

    A policy builder that wants its own per-step counters in the
    recorded stream wraps its scan state as
    ``CounterState(counters=f32[K], names=(...), inner=state)`` and
    updates ``counters`` in ``step``.  The engine probes the state type
    after each step and appends ``counters`` to the channel vector;
    ``names`` (static) join the frame's channel names.
    """

    counters: jax.Array                        # f32[K]
    inner: Any                                 # the policy's own state
    names: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TelemetryFrame:
    """Recorded channels of one (or a batch of) simulated stream(s).

    ``channels`` is ``f32[..., R, K]`` where ``R`` is the number of
    recorded rows (``T``, or ``ring`` in ring mode) and ``K ==
    len(names)``; ``steps`` (``i32[..., R]``) is the absolute simulation
    step of each row (``-1``: slot never written, ring mode only);
    ``count`` (``i32[...]``) the total number of steps the recorder saw.
    """

    channels: jax.Array
    steps: jax.Array
    count: jax.Array
    names: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    def channel(self, name: str) -> np.ndarray:
        """One channel as ``[..., R]`` numpy, by name."""
        return np.asarray(self.channels)[..., self.names.index(name)]


# ---------------------------------------------------------------------------
# in-scan recording (called from lagsim.engine inside the scan body)
# ---------------------------------------------------------------------------

def record_step(tele: TelemetryConfig, *, speeds, new_lag, moved, blocked,
                storm, n_consumers, act_t, capacity, pstate
                ) -> Tuple[jax.Array, Tuple[str, ...]]:
    """The per-step channel vector ``f32[K]`` and its (static) names.

    Pure ``jnp`` on values the engine already computes -- adding the
    recorder never changes the simulated trajectories, only the scan's
    outputs.  ``storm`` may be ``None`` (no control plane).
    """
    n = speeds.shape[0]
    moved_speed = jnp.sum(jnp.where(moved, speeds, 0.0))
    if act_t is None:
        active_parts = jnp.float32(n)
        lag_for_q = new_lag
    else:
        active_parts = jnp.sum(act_t.astype(jnp.float32))
        # quantiles over existing partitions only: a dead partition's
        # forced-zero lag must not drag the distribution down
        lag_for_q = jnp.where(act_t, new_lag, jnp.nan)
    vals = [
        n_consumers.astype(jnp.float32),
        jnp.sum(moved.astype(jnp.float32)),
        moved_speed / jnp.float32(capacity),
        jnp.sum(blocked.astype(jnp.float32)),
        (jnp.float32(0.0) if storm is None
         else jnp.sum(storm.astype(jnp.float32))),
        active_parts,
        jnp.sum(new_lag),
    ]
    names = tele.base_channels
    if tele.lag_quantiles:
        qs = jnp.nanquantile(
            lag_for_q, jnp.asarray(tele.lag_quantiles, jnp.float32))
        # an all-dead step has no distribution; record 0, not NaN
        qs = jnp.where(jnp.isnan(qs), 0.0, qs)
        vals.extend(qs[i] for i in range(len(tele.lag_quantiles)))
    if isinstance(pstate, CounterState):
        vals.extend(pstate.counters[i].astype(jnp.float32)
                    for i in range(len(pstate.names)))
        names = names + tuple(pstate.names)
    return jnp.stack(vals), names


def ring_init(tele: TelemetryConfig, k: int):
    """Initial ring-buffer carry ``(buf f32[ring, K], steps i32[ring])``."""
    r = int(tele.ring)
    return (jnp.zeros((r, k), jnp.float32), jnp.full((r,), -1, jnp.int32))


def ring_write(carry, tick, vec):
    """Write ``vec`` at slot ``tick % ring``; returns the new carry."""
    buf, steps = carry
    slot = tick % jnp.int32(buf.shape[0])
    return (buf.at[slot].set(vec), steps.at[slot].set(tick))


def frame_from_outputs(tele: TelemetryConfig, names: Tuple[str, ...],
                       channels: jax.Array, t_total: int) -> TelemetryFrame:
    """Frame for per-step (non-ring) recording: one row per scan step."""
    steps = jnp.broadcast_to(
        jnp.arange(t_total, dtype=jnp.int32), channels.shape[:-1])
    return TelemetryFrame(channels=channels, steps=steps,
                          count=jnp.int32(t_total), names=names)


def frame_from_ring(tele: TelemetryConfig, names: Tuple[str, ...],
                    carry, t_total: int) -> TelemetryFrame:
    """Frame for ring mode: the final buffer plus absolute step indices."""
    buf, steps = carry
    return TelemetryFrame(channels=buf, steps=steps,
                          count=jnp.int32(t_total), names=names)


# ---------------------------------------------------------------------------
# host-side decoding
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TelemetryEvent:
    """One decoded event.  ``kind`` is one of:

    * ``scale``       -- the consumer count changed (``from``/``to``);
    * ``migration``   -- >= 1 partition changed owner this step
      (``count``, ``rscore`` -- the paper's Eq. 10 price of the move);
    * ``storm``       -- a control-plane rebalance-storm window
      (``start``/``end`` steps, ``peak_parts`` concurrently blocked);
    * ``downtime``    -- a window with any partition unreadable
      (migration downtime and/or storm; ``start``/``end``,
      ``peak_parts``);
    * ``lifecycle``   -- the active-partition count changed: topic
      births/deaths under the variable-N mask (``delta``, ``active``).

    ``index`` locates the stream in a batched frame (e.g. ``(policy,
    stream)`` for a sweep; ``()`` for a single trace).
    """

    kind: str
    step: int
    index: Tuple[int, ...] = ()
    data: Dict[str, float] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "step": self.step,
                "index": list(self.index),
                "data": {k: (round(float(v), 6) if isinstance(v, float)
                             else v) for k, v in self.data.items()}}


def _windows(mask: np.ndarray, steps: np.ndarray, vals: np.ndarray
             ) -> List[Tuple[int, int, float]]:
    """Contiguous True runs -> [(start_step, end_step_inclusive, peak)]."""
    out = []
    start = None
    peak = 0.0
    for i, on in enumerate(mask):
        if on and start is None:
            start, peak = int(steps[i]), float(vals[i])
        elif on:
            peak = max(peak, float(vals[i]))
        elif start is not None:
            out.append((start, int(steps[i - 1]), peak))
            start = None
    if start is not None:
        out.append((start, int(steps[-1]), peak))
    return out


def decode_events(frame: TelemetryFrame) -> List[TelemetryEvent]:
    """Decode a frame (any leading batch shape) into typed event records,
    ordered by ``(index, step)``.  Ring-mode frames decode the surviving
    window; rows never written (``step == -1``) are skipped."""
    ch = np.asarray(frame.channels, np.float64)
    steps = np.asarray(frame.steps, np.int64)
    names = frame.names
    col = {nm: i for i, nm in enumerate(names)}
    events: List[TelemetryEvent] = []
    lead = ch.shape[:-2]
    for index in np.ndindex(*lead) if lead else [()]:
        c = ch[index]                       # [R, K]
        s = steps[index]                    # [R]
        order = np.argsort(s, kind="stable")  # ring mode: restore time order
        valid = s[order] >= 0
        c, s = c[order][valid], s[order][valid]
        if c.shape[0] == 0:
            continue
        cons = c[:, col["consumers"]]
        migs = c[:, col["migrations"]]
        rsc = c[:, col["rscore"]]
        act = c[:, col["active_parts"]]
        for t in np.flatnonzero(np.diff(cons) != 0):
            events.append(TelemetryEvent(
                "scale", int(s[t + 1]), index,
                {"from": float(cons[t]), "to": float(cons[t + 1])}))
        for t in np.flatnonzero(migs > 0):
            events.append(TelemetryEvent(
                "migration", int(s[t]), index,
                {"count": float(migs[t]), "rscore": float(rsc[t])}))
        for start, end, peak in _windows(c[:, col["storm_parts"]] > 0, s,
                                         c[:, col["storm_parts"]]):
            events.append(TelemetryEvent(
                "storm", start, index, {"end": float(end),
                                        "peak_parts": peak}))
        for start, end, peak in _windows(c[:, col["unreadable"]] > 0, s,
                                         c[:, col["unreadable"]]):
            events.append(TelemetryEvent(
                "downtime", start, index, {"end": float(end),
                                           "peak_parts": peak}))
        for t in np.flatnonzero(np.diff(act) != 0):
            events.append(TelemetryEvent(
                "lifecycle", int(s[t + 1]), index,
                {"delta": float(act[t + 1] - act[t]),
                 "active": float(act[t + 1])}))
    events.sort(key=lambda e: (e.index, e.step, e.kind))
    return events


def _require_pandas(caller: str):
    """Late pandas import with a degrade-gracefully error: pandas is an
    optional dependency (not in requirements.txt), and the exporters are
    conveniences, not core paths."""
    try:
        import pandas as pd
    except ImportError as exc:
        raise ImportError(
            f"{caller} needs pandas, which is an optional dependency and "
            f"is not installed in this environment.  Install pandas, or "
            f"use to_json()/decode_events() (stdlib + numpy only) instead."
        ) from exc
    return pd


@dataclasses.dataclass
class EventStream:
    """A decoded frame: typed events plus the raw per-step samples."""

    events: List[TelemetryEvent]
    frame: TelemetryFrame

    @classmethod
    def from_frame(cls, frame: TelemetryFrame) -> "EventStream":
        return cls(events=decode_events(frame), frame=frame)

    def counts(self) -> Dict[str, int]:
        """Events per kind -- the summary the BENCH ``telemetry`` blocks
        embed."""
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def to_json(self) -> str:
        """Canonical JSON: channel names, event records, recorded-step
        count.  Floats round to 6 decimals so fixed-seed streams diff
        cleanly across runs."""
        return json.dumps({
            "channels": list(self.frame.names),
            "recorded_steps": int(np.max(np.asarray(self.frame.count))),
            "counts": self.counts(),
            "events": [e.as_dict() for e in self.events],
        }, indent=1, sort_keys=True)

    def to_dataframe(self):
        """The per-step samples as a tidy ``pandas.DataFrame`` (one row
        per recorded (index, step), one column per channel)."""
        pd = _require_pandas("EventStream.to_dataframe")
        ch = np.asarray(self.frame.channels, np.float64)
        steps = np.asarray(self.frame.steps, np.int64)
        lead = ch.shape[:-2]
        rows = []
        for index in np.ndindex(*lead) if lead else [()]:
            c, s = ch[index], steps[index]
            for r in range(c.shape[0]):
                if s[r] < 0:
                    continue
                row = {"step": int(s[r])}
                row.update({f"i{d}": int(v) for d, v in enumerate(index)})
                row.update({nm: float(c[r, k])
                            for k, nm in enumerate(self.frame.names)})
                rows.append(row)
        return pd.DataFrame(rows).sort_values(
            [c for c in rows[0] if c.startswith("i")] + ["step"]
        ).reset_index(drop=True) if rows else pd.DataFrame()

    def events_dataframe(self):
        """The decoded events as a ``pandas.DataFrame``."""
        pd = _require_pandas("EventStream.events_dataframe")
        return pd.DataFrame([
            {"kind": e.kind, "step": e.step, "index": e.index, **e.data}
            for e in self.events])


__all__ = [
    "BASE_CHANNELS",
    "CounterState",
    "EventStream",
    "TelemetryConfig",
    "TelemetryEvent",
    "TelemetryFrame",
    "decode_events",
]
