"""Flight-recorder telemetry: in-loop capture, streaming sketches,
SLO alerting, host-side spans, and standard metric export.

Five submodules (see their docstrings for the design):

* ``telemetry.record`` -- the scan-safe in-loop recorder.  Enable it by
  putting a :class:`TelemetryConfig` on ``LagSimConfig.telemetry``; the
  engine then threads a fixed-shape channel vector through the scan and
  returns a :class:`TelemetryFrame` on every trace, decodable into typed
  events (:func:`decode_events` / :class:`EventStream`).  Off (the
  default) is bit-identical to the recorder-free engine.
* ``telemetry.sketch`` -- constant-memory online aggregators (Welford
  moments, min/max, EWMA windows, histogram quantiles) carried through
  the scan; enable via ``TelemetryConfig(sketch=SketchConfig(...))``.
* ``telemetry.alerts`` -- declarative in-loop alerting (multi-window
  SLO burn rate, lag-growth invariant, rebalance storms, thrash) with
  fixed-shape incident tables; ``TelemetryConfig(alerts=AlertConfig(
  rules=default_rules()))``.
* ``telemetry.spans`` -- host-side span profiling (:func:`span`,
  :func:`traced`, :class:`Tracer`) with first-call vs steady-state
  separation and Chrome/Perfetto ``trace_event`` export.
* ``telemetry.export`` -- stdlib-only Prometheus text exposition and
  OTLP-style JSON for sketches, incidents, and spans, plus a
  pure-python exposition linter.

``spans`` and ``export`` are jax-free; ``spans`` imports eagerly,
everything jax-backed resolves lazily, so ``import repro.telemetry``
stays cheap.
"""
from .spans import (SpanRecord, Tracer, default_tracer, instant, span,
                    traced, validate_chrome_trace)

_RECORD_EXPORTS = (
    "BASE_CHANNELS",
    "CounterState",
    "EventStream",
    "TelemetryConfig",
    "TelemetryEvent",
    "TelemetryFrame",
    "decode_events",
)

_SKETCH_EXPORTS = (
    "SketchConfig",
    "SketchState",
    "SketchSummary",
    "merge_summaries",
    "sketch_init",
    "sketch_update",
    "summaries_from_state",
)

_ALERT_EXPORTS = (
    "AlertConfig",
    "AlertRule",
    "AlertState",
    "Incident",
    "alert_init",
    "alert_step",
    "decode_incidents",
    "default_rules",
    "incident_counts",
    "incident_summary",
)

_EXPORT_EXPORTS = (
    "otlp_metrics_json",
    "otlp_spans_json",
    "prometheus_exposition",
    "validate_exposition",
)


def __getattr__(name: str):
    if name in _RECORD_EXPORTS:
        from . import record as _record

        return getattr(_record, name)
    if name in _SKETCH_EXPORTS:
        from . import sketch as _sketch

        return getattr(_sketch, name)
    if name in _ALERT_EXPORTS:
        from . import alerts as _alerts

        return getattr(_alerts, name)
    if name in _EXPORT_EXPORTS:
        from . import export as _export

        return getattr(_export, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = sorted(
    _RECORD_EXPORTS + _SKETCH_EXPORTS + _ALERT_EXPORTS + _EXPORT_EXPORTS + (
        "SpanRecord",
        "Tracer",
        "default_tracer",
        "instant",
        "span",
        "traced",
        "validate_chrome_trace",
    ))
