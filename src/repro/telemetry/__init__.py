"""Flight-recorder telemetry: in-loop trace capture + host-side spans.

Two halves (see the submodule docstrings for the design):

* ``telemetry.record`` -- the scan-safe in-loop recorder.  Enable it by
  putting a :class:`TelemetryConfig` on ``LagSimConfig.telemetry``; the
  engine then threads a fixed-shape channel vector through the scan and
  returns a :class:`TelemetryFrame` on every trace, decodable into typed
  events (:func:`decode_events` / :class:`EventStream`).  Off (the
  default) is bit-identical to the recorder-free engine.
* ``telemetry.spans`` -- host-side span profiling (:func:`span`,
  :func:`traced`, :class:`Tracer`) with first-call vs steady-state
  separation and Chrome/Perfetto ``trace_event`` export.

``spans`` is stdlib-only and imported eagerly; ``record`` needs jax and
resolves lazily, so ``import repro.telemetry`` stays cheap.
"""
from .spans import (SpanRecord, Tracer, default_tracer, instant, span,
                    traced, validate_chrome_trace)

_RECORD_EXPORTS = (
    "BASE_CHANNELS",
    "CounterState",
    "EventStream",
    "TelemetryConfig",
    "TelemetryEvent",
    "TelemetryFrame",
    "decode_events",
)


def __getattr__(name: str):
    if name in _RECORD_EXPORTS:
        from . import record as _record

        return getattr(_record, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = sorted(_RECORD_EXPORTS + (
    "SpanRecord",
    "Tracer",
    "default_tracer",
    "instant",
    "span",
    "traced",
    "validate_chrome_trace",
))
