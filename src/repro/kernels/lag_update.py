"""Fused closed-loop lag update as a Pallas TPU kernel, batched over streams.

One simulated step of the lag digital twin (``repro.lagsim``) is:

  1. production:  avail_i = lag_i + produced_i
  2. segment-sum: L_c = sum of avail_i over *readable* partitions of bin c
  3. drain:       every readable partition of bin c sheds the fraction
                  min(1, cap_c / L_c) of its backlog, so each consumer
                  drains exactly min(L_c, cap_c) bytes in aggregate
                  (proportional water-filling of a shared budget)

Steps 2-3 are a one-hot segment reduction plus a gather -- the hot inner
loop when the twin sweeps hundreds of scenarios -- so the kernel fuses all
three into a single VMEM pass per stream: ``grid = (B,)``, each program
instance owns one stream's ``(N,)`` state and reduces over the ``(N, M)``
one-hot plane in registers.  Partitions that are unreadable (mid-migration
downtime, ``readable == 0``) or unassigned (``assign < 0``) keep their
backlog untouched.

Masking (variable-N fleets): pass ``active`` and partitions with
``active == 0`` -- topics that do not currently exist -- produce no
backlog, join no per-bin sum (they drain no budget), and end the step
with exactly zero lag ("unreadable and empty").  ``active=None`` keeps
the exact unmasked program, so all-active runs stay bit-identical.

Semantics are pinned to the pure-jnp oracle ``lag_update_reference`` below
(tests/test_lagsim.py); on hosts without a TPU the wrapper falls back to
Pallas interpreter mode automatically, like ``binpack_select``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.telemetry.spans import span as _span

from ._compat import CompilerParams as _CompilerParams
from ._compat import default_interpret as _default_interpret

_TINY = 1e-30   # python literal so it is not captured as a traced const


def lag_update_reference(lag, produced, assign, readable, cap, *, m: int,
                         active=None):
    """Pure-jnp oracle over ``(..., N)`` state arrays.

    lag, produced: f32[..., N] backlog and this step's production (bytes);
    assign: i32[..., N] bin name per partition (< ``m``; -1 = unassigned);
    readable: bool/i32[..., N] -- 0 while a partition is in migration
    downtime; cap: per-consumer drain budget for the step, a scalar or any
    shape broadcastable to the per-bin sums f32[..., M]; active: optional
    bool/i32[..., N] -- 0 marks a partition that does not exist this step
    (no production, no drain, post-step lag exactly 0).  Returns the
    post-drain backlog f32[..., N].
    """
    if active is not None:
        act = active.astype(bool)
        produced = jnp.where(act, produced, 0.0)
        readable = readable.astype(bool) & act
    avail = lag + produced
    names = jnp.arange(m, dtype=jnp.int32)
    live = (readable.astype(bool)) & (assign >= 0)
    onehot = (assign[..., :, None] == names) & live[..., :, None]   # (..., N, M)
    per_bin = jnp.sum(jnp.where(onehot, avail[..., :, None], 0.0), axis=-2)
    ratio = jnp.minimum(1.0, cap / jnp.maximum(per_bin, _TINY))
    frac = jnp.sum(jnp.where(onehot, ratio[..., None, :], 0.0), axis=-1)
    out = jnp.maximum(avail * (1.0 - frac), 0.0)
    if active is not None:
        out = jnp.where(act, out, 0.0)
    return out


def _drain_math(avail, assign, live, cap, *, n: int, m: int):
    """The fused segment-sum + proportional drain on one stream's (N,)
    values -- shared by the batched and the rank-1 kernel entries."""
    live = live & (assign >= 0)
    names = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    onehot = (assign[:, None] == names) & live[:, None]    # (N, M)
    per_bin = jnp.sum(jnp.where(onehot, avail[:, None], 0.0), axis=0)  # (M,)
    ratio = jnp.minimum(1.0, cap / jnp.maximum(per_bin, _TINY))
    frac = jnp.sum(jnp.where(onehot, ratio[None, :], 0.0), axis=1)     # (N,)
    return jnp.maximum(avail * (1.0 - frac), 0.0)


def _lag_update_kernel(lag_ref, prod_ref, assign_ref, readable_ref, cap_ref,
                       *rest, n: int, m: int, masked: bool):
    """One stream: fused produce + one-hot segment drain over (N, M)."""
    if masked:
        active_ref, out_ref = rest
        act = active_ref[0] > 0
        avail = lag_ref[0] + jnp.where(act, prod_ref[0], 0.0)   # (N,)
        live = (readable_ref[0] > 0) & act
    else:
        (out_ref,) = rest
        avail = lag_ref[0] + prod_ref[0]                       # (N,)
        live = readable_ref[0] > 0
    out = _drain_math(avail, assign_ref[0], live, cap_ref[0], n=n, m=m)
    if masked:
        out = jnp.where(act, out, 0.0)
    out_ref[0] = out


def _lag_update_kernel_1d(lag_ref, prod_ref, assign_ref, readable_ref,
                          cap_ref, *rest, n: int, m: int, masked: bool):
    """Rank-1 twin of ``_lag_update_kernel``: refs are the (N,)/(M,)
    arrays themselves, no leading stream axis to index away."""
    if masked:
        active_ref, out_ref = rest
        act = active_ref[...] > 0
        avail = lag_ref[...] + jnp.where(act, prod_ref[...], 0.0)
        live = (readable_ref[...] > 0) & act
    else:
        (out_ref,) = rest
        avail = lag_ref[...] + prod_ref[...]
        live = readable_ref[...] > 0
    out = _drain_math(avail, assign_ref[...], live, cap_ref[...], n=n, m=m)
    if masked:
        out = jnp.where(act, out, 0.0)
    out_ref[...] = out


def lag_update_batch(lag, produced, assign, readable, cap, *, active=None,
                     interpret: bool | None = None):
    """Fused lag update over a batch of streams in one kernel launch.

    lag, produced: f32[B, N]; assign: i32[B, N] (-1 = unassigned);
    readable: i32[B, N] (0 = migration downtime); cap: f32[B, M] per-bin
    drain budget for the step; active: optional i32/bool[B, N] partition
    mask (0 = the partition does not exist: no production, no drain, lag
    forced to 0).  Returns f32[B, N] post-drain backlog.
    ``grid = (B,)``; each instance holds one stream's (N,) state plus the
    (N, M) one-hot plane in VMEM.
    """
    if interpret is None:
        interpret = _default_interpret()
    masked = active is not None
    b, n = lag.shape
    m = cap.shape[1]
    kernel = functools.partial(_lag_update_kernel, n=n, m=m, masked=masked)
    n_spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    in_specs = [n_spec, n_spec, n_spec, n_spec,
                pl.BlockSpec((1, m), lambda i: (i, 0))]
    args = [lag.astype(jnp.float32), produced.astype(jnp.float32),
            assign.astype(jnp.int32), readable.astype(jnp.int32),
            cap.astype(jnp.float32)]
    if masked:
        in_specs.append(n_spec)
        args.append(active.astype(jnp.int32))
    call = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    if isinstance(lag, jax.core.Tracer):
        # inside a jit trace: launch cost belongs to the enclosing
        # fleet.compile / fleet.dispatch spans, not a per-step host span
        return call(*args)
    with _span("kernel.lag_update", batch=b, n=n, m=m,
               interpret=bool(interpret)):
        return call(*args)


def lag_update_single(lag, produced, assign, readable, cap, *, active=None,
                      interpret: bool | None = None):
    """Rank-1 fused lag update: one stream, no batch axis.

    lag, produced: f32[N]; assign: i32[N]; readable: i32[N]; cap: f32[M];
    active: optional i32/bool[N].  Returns f32[N].  Same semantics as one
    row of ``lag_update_batch`` (both are pinned to
    ``lag_update_reference``), but callers with rank-1 state -- the lag
    engine's per-step ``drain`` inside ``lax.scan`` -- skip the
    ``lag[None]`` expand + ``[0]`` squeeze round-trip per step.
    """
    if interpret is None:
        interpret = _default_interpret()
    masked = active is not None
    n = lag.shape[0]
    m = cap.shape[0]
    kernel = functools.partial(_lag_update_kernel_1d, n=n, m=m, masked=masked)
    args = [lag.astype(jnp.float32), produced.astype(jnp.float32),
            assign.astype(jnp.int32), readable.astype(jnp.int32),
            cap.astype(jnp.float32)]
    if masked:
        args.append(active.astype(jnp.int32))
    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )
    if isinstance(lag, jax.core.Tracer):
        return call(*args)
    with _span("kernel.lag_update", batch=1, n=n, m=m,
               interpret=bool(interpret)):
        return call(*args)
