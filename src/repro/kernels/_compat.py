"""Version shims and backend rules shared by the Pallas kernels."""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names it TPUCompilerParams; keep one alias for both
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def default_interpret() -> bool:
    """Only a real TPU runs the compiled Mosaic kernels; every other backend
    (cpu, gpu) gets Pallas interpreter mode."""
    return jax.default_backend() != "tpu"
