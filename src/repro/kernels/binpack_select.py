"""Fit-strategy bin selection as a Pallas TPU kernel.

The packer's inner operation -- "given bin loads and an item, pick the
first/best/worst bin it fits in" -- is a masked argmin/argmax reduction.
Evaluating algorithm sweeps (12 algorithms x 6 deltas x 500 iterations x
batches of streams) on device makes this the hot loop; the kernel evaluates
a whole batch of (loads, item) instances per launch with the loads row
resident in VMEM.

Semantics match ``repro.core.jaxpack._select_slot``: ties break to the
lowest slot, an item "fits" iff load + w <= capacity and slot < k.
Returns slot = M (out of range) when nothing fits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 3.4e38  # python literal: jnp scalars would be captured as consts


def _select_kernel(loads_ref, w_ref, k_ref, cap_ref, slot_ref, *,
                   strategy: str, m: int):
    loads = loads_ref[0]                              # (M,)
    w = w_ref[0]
    k = k_ref[0]
    cap = cap_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)
    fits = (idx < k) & (loads + w <= cap)
    if strategy == "first":
        score = jnp.where(fits, idx.astype(jnp.float32), _BIG)
        best = jnp.argmin(score)
    elif strategy == "best":      # tightest fit = max load; first on tie
        score = jnp.where(fits, loads, -_BIG)
        best = jnp.argmax(score)
    elif strategy == "worst":     # most slack = min load; first on tie
        score = jnp.where(fits, loads, _BIG)
        best = jnp.argmin(score)
    else:
        raise ValueError(strategy)
    found = jnp.any(fits)
    slot_ref[0] = jnp.where(found, best.astype(jnp.int32), jnp.int32(m))


def select_slot_batch(loads, w, k, capacity, *, strategy: str = "best",
                      interpret: bool = False):
    """loads: (N, M) f32; w, capacity: (N,) f32; k: (N,) i32 (bins created).

    Returns (N,) i32 chosen slot per instance (M = nothing fits).
    """
    n, m = loads.shape
    kernel = functools.partial(_select_kernel, strategy=strategy, m=m)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(loads.astype(jnp.float32), w.astype(jnp.float32),
      k.astype(jnp.int32), capacity.astype(jnp.float32))
