"""Fit-strategy bin selection as a Pallas TPU kernel, batched over streams.

The packer's inner operation -- "given bin loads and an item, pick the
first/best/worst bin it fits in" -- is a masked argmin/argmax reduction.
Evaluating algorithm sweeps (12 algorithms x 6 deltas x 500 iterations x
batches of streams) on device makes this the hot loop, so the kernel grid
carries an explicit *batch* dimension: each program instance reduces a
whole ``(rows, M)`` tile of (loads, item) instances for one stream of the
batch, with the loads tile resident in VMEM.  ``grid = (B, ceil(N/rows))``
and both dimensions are parallel, so one launch covers the entire
``f32[B, N, M]`` sweep.

Semantics match ``repro.core.jaxpack._select_slot``: ties break to the
lowest slot, an item "fits" iff load + w <= capacity and slot < k.
Returns slot = M (out of range) when nothing fits.

Masking (variable-N fleets): pass ``active`` (i32/bool per instance) and
inactive instances -- partitions that do not currently exist -- return
slot = ``NEG`` (-1): they select no bin at all, distinct from "exists but
nothing fits" (= M).  ``active=None`` keeps the exact unmasked program.

On hosts without a TPU the wrappers fall back to Pallas interpreter mode
automatically, so the same call sites work in CI and on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.telemetry.spans import span as _span

from ._compat import CompilerParams as _CompilerParams
from ._compat import default_interpret as _default_interpret

_BIG = 3.4e38  # python literal: jnp scalars would be captured as consts

DEFAULT_ROW_TILE = 256
NEG = -1       # "inactive instance": the item does not exist, no slot at all


def _select_tile_kernel(loads_ref, w_ref, k_ref, cap_ref, *rest, strategy: str,
                        m: int, rows: int, masked: bool):
    """One (rows, M) tile: row-wise masked argmin/argmax along the M axis."""
    if masked:
        active_ref, slot_ref = rest
    else:
        (slot_ref,) = rest
    loads = loads_ref[0]                              # (rows, M)
    w = w_ref[0][:, None]                             # (rows, 1)
    k = k_ref[0][:, None]                             # (rows, 1)
    cap = cap_ref[0][:, None]
    idx = jax.lax.broadcasted_iota(jnp.int32, (rows, m), 1)
    fits = (idx < k) & (loads + w <= cap)
    if strategy == "first":
        score = jnp.where(fits, idx.astype(jnp.float32), _BIG)
        best = jnp.argmin(score, axis=1)
    elif strategy == "best":      # tightest fit = max load; first on tie
        score = jnp.where(fits, loads, -_BIG)
        best = jnp.argmax(score, axis=1)
    elif strategy == "worst":     # most slack = min load; first on tie
        score = jnp.where(fits, loads, _BIG)
        best = jnp.argmin(score, axis=1)
    else:
        raise ValueError(strategy)
    found = jnp.any(fits, axis=1)
    slot = jnp.where(found, best.astype(jnp.int32), jnp.int32(m))
    if masked:
        slot = jnp.where(active_ref[0] > 0, slot, jnp.int32(NEG))
    slot_ref[0] = slot


def select_slot_grid(loads, w, k, capacity, *, active=None,
                     strategy: str = "best",
                     row_tile: int = DEFAULT_ROW_TILE,
                     interpret: bool | None = None):
    """Batched fit-selection over a grid of streams.

    loads: (B, N, M) f32 bin loads; w, capacity: (B, N) f32; k: (B, N) i32
    (bins created); active: optional (B, N) i32/bool -- 0 marks an
    instance whose item does not exist.  Returns (B, N) i32 chosen slot
    per instance (M when nothing fits, ``NEG`` when inactive).  One kernel
    launch; ``grid = (B, ceil(N / row_tile))``.
    """
    if interpret is None:
        interpret = _default_interpret()
    masked = active is not None
    b, n, m = loads.shape
    rows = min(row_tile, n)
    pad = (-n) % rows
    if pad:
        # padded rows see k=0 -> nothing fits; their output is sliced off
        loads = jnp.pad(loads, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
        k = jnp.pad(k, ((0, 0), (0, pad)))
        capacity = jnp.pad(capacity, ((0, 0), (0, pad)))
        if masked:
            active = jnp.pad(active.astype(jnp.int32), ((0, 0), (0, pad)))
    n_pad = n + pad
    kernel = functools.partial(_select_tile_kernel, strategy=strategy, m=m,
                               rows=rows, masked=masked)
    row_spec = pl.BlockSpec((1, rows), lambda i, j: (i, j))
    in_specs = [
        pl.BlockSpec((1, rows, m), lambda i, j: (i, j, 0)),
        row_spec, row_spec, row_spec,
    ]
    args = [loads.astype(jnp.float32), w.astype(jnp.float32),
            k.astype(jnp.int32), capacity.astype(jnp.float32)]
    if masked:
        in_specs.append(row_spec)
        args.append(active.astype(jnp.int32))
    call = pl.pallas_call(
        kernel,
        grid=(b, n_pad // rows),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )
    if isinstance(loads, jax.core.Tracer):
        # under a jit trace the launch is timed by the caller's spans
        return call(*args)[:, :n]
    with _span("kernel.select_slot", batch=b, n=n, m=m, strategy=strategy,
               interpret=bool(interpret)):
        return call(*args)[:, :n]


def select_slot_batch(loads, w, k, capacity, *, active=None,
                      strategy: str = "best",
                      interpret: bool | None = None):
    """loads: (N, M) f32; w, capacity: (N,) f32; k: (N,) i32 (bins created);
    active: optional (N,) i32/bool instance mask.

    Returns (N,) i32 chosen slot per instance (M = nothing fits, ``NEG`` =
    inactive).  Thin wrapper over ``select_slot_grid`` with a singleton
    batch dimension.
    """
    return select_slot_grid(loads[None], w[None], k[None], capacity[None],
                            active=None if active is None else active[None],
                            strategy=strategy, interpret=interpret)[0]
