"""Flash attention (prefill/training fwd) as a Pallas TPU kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
innermost ("arbitrary") axis, accumulating an online softmax in VMEM
scratch.  GQA is handled in the K/V BlockSpec index maps (q head h reads kv
head h // group), so grouped K/V are never materialized H-wide in HBM --
unlike the jnp reference path, which must jnp.repeat them.

VMEM working set per grid step (bf16 in, f32 accumulate):
    q tile (block_q, hd) + k/v tiles (block_k, hd) + acc (block_q, hd)
    + scores (block_q, block_k)
With the default block_q = block_k = 512, hd = 128: ~2.6 MB -- comfortably
inside the ~16 MB v5e VMEM, and all matmul dims are multiples of 128 so the
MXU is fully tiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, block_q: int, block_k: int, n_kv: int,
                  sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    # causal: skip kv blocks strictly above the diagonal
    @pl.when((not causal) or (ki * block_k <= qi * block_q + block_q - 1))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]                                  # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd) with H % KV == 0.

    Returns (B, H, Sq, hd) in q.dtype.
    """
    b, h, sq, hd = q.shape
    _, kvh, skv, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    nq, nk = sq // block_q, skv // block_k
    sm_scale = hd ** -0.5

    kernel = functools.partial(_flash_kernel, causal=causal, block_q=block_q,
                               block_k=block_k, n_kv=nk, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
