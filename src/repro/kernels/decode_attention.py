"""GQA decode attention (flash-decoding) as a Pallas TPU kernel.

One new token per sequence attends to a length-``cache_len`` KV cache.
Grid: (batch, kv_heads, cache_blocks); the cache axis is innermost and
accumulates online-softmax state in VMEM scratch.  The q heads of one kv
group (G = H/KV rows) are processed together, so the MXU sees a
(G x hd) @ (hd x block_s) matmul per step; ``cache_len`` arrives in SMEM and
masks the tail block.

VMEM per step: k/v tiles (block_s, hd) + acc (G, hd) + scores (G, block_s);
with block_s=512, hd=128, G<=8: ~0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30
DEFAULT_BLOCK_S = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, block_s: int, n_s: int, sm_scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    s_pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)

    @pl.when(si * block_s <= cache_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale        # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(s_pos <= cache_len, s, NEG_INF)         # (G, bs)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q, k_cache, v_cache, cache_len, *,
                         block_s: int = DEFAULT_BLOCK_S,
                         interpret: bool = False):
    """q: (B, KV, G, hd); k/v_cache: (B, KV, S, hd); cache_len: () int32 --
    attends to positions [0, cache_len] (inclusive: the new token's K/V must
    already be written at ``cache_len``).  Returns (B, KV, G, hd).
    """
    b, kvh, g, hd = q.shape
    _, _, s, _ = k_cache.shape
    block_s = min(block_s, s)
    assert s % block_s == 0
    n_s = s // block_s
    kernel = functools.partial(_decode_kernel, block_s=block_s, n_s=n_s,
                               sm_scale=hd ** -0.5)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, s_, len_ref: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b_, h_, s_, len_ref: (b_, h_, s_, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b_, h_, s_, len_ref: (b_, h_, s_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h_, s_, len_ref: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, q, k_cache, v_cache)
