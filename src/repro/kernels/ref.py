"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode sweeps in tests/test_kernels.py compare against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd).  Full-softmax reference."""
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """q: (B, KV, G, hd); caches: (B, KV, S, hd); attends [0, cache_len]."""
    b, kvh, g, hd = q.shape
    s_len = k_cache.shape[2]
    s = jnp.einsum("bngd,bnsd->bngs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(s_len)[None, None, None, :] <= cache_len
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bnsd->bngd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)


def rwkv6_wkv_ref(r, k, v, w, u, s0):
    """Sequential-scan reference of the WKV recurrence (all f32)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, out

    rt = jnp.moveaxis(r, 1, 0)
    kt = jnp.moveaxis(k, 1, 0)
    vt = jnp.moveaxis(v, 1, 0)
    wt = jnp.moveaxis(w, 1, 0)
    s_last, out = jax.lax.scan(step, s0, (rt, kt, vt, wt))
    return jnp.moveaxis(out, 0, 1), s_last


def select_slot_ref(loads, w, k, capacity, *, strategy: str = "best"):
    """Batched reference of the packer's fit-strategy selection."""
    n, m = loads.shape
    idx = jnp.arange(m)
    fits = (idx[None, :] < k[:, None]) & (loads + w[:, None] <= capacity[:, None])
    if strategy == "first":
        score = jnp.where(fits, idx[None, :].astype(jnp.float32), jnp.inf)
        best = jnp.argmin(score, axis=1)
    elif strategy == "best":
        score = jnp.where(fits, loads, -jnp.inf)
        best = jnp.argmax(score, axis=1)
    elif strategy == "worst":
        score = jnp.where(fits, loads, jnp.inf)
        best = jnp.argmin(score, axis=1)
    else:
        raise ValueError(strategy)
    return jnp.where(fits.any(axis=1), best, m).astype(jnp.int32)
