"""Multi-step closed-loop lag megakernel: K simulated steps per launch.

``kernels/lag_update.py`` fuses ONE step's produce + drain; the scan
around it still pays a dispatch per simulated step.  This kernel hoists
the whole loop: ``grid = (B, ceil(T / K))`` with the time dimension
marked ``"arbitrary"`` (sequential), and each program instance advances
``K = fused_steps`` steps of one stream while the entire carry -- the
per-partition backlog, the previous assignment, and the migration
downtime counters -- stays resident in VMEM scratch across grid steps.
The ``[1, K, N]`` rate (and active-mask) slabs are streamed per grid
step through Pallas' pipelined block fetches, so the next block's DMA
overlaps the current block's compute (double buffering); K tunes slab
size against pipeline depth.

Each in-kernel step replays the heuristic policy families exactly:

  1. traversal order: identity, or ``pack_jax``'s stable non-increasing
     sort for Decreasing variants (pairwise rank, no sort primitive);
  2. slot selection per item with the same select logic as
     ``binpack_select`` (next/first/best/worst as a masked double-min);
  3. the Sec. IV-C sticky renaming of creation slots to bin names,
     with the name universe packed into int32 bitmasks;
  4. migration-downtime masking (a moved partition is unreadable for
     ``migration_steps`` steps);
  5. the produce + proportional-drain update of ``lag_update``.

The bit-exact oracle is the XLA fused engine ``repro.lagsim.fused``
(itself pinned bit-for-bit to the unfused per-step scan), asserted in
tests/test_fused_loop.py and the CI fused smoke.  Like the other three
kernels, hosts without a TPU run Pallas interpreter mode automatically.

The int32 name bitmask bounds the kernel to ``n <= 14`` partitions
(``2n + 1 < 31`` bits) -- the engine falls back to the unfused scan
above that (``repro.lagsim.fused.FUSED_MAX_PARTITIONS``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.telemetry.spans import span as _span

from ._compat import CompilerParams as _CompilerParams
from ._compat import default_interpret as _default_interpret

NEG = -1
_TINY = 1e-30   # python literal so it is not captured as a traced const
_STRATEGIES = ("next", "first", "best", "worst")


def _one_step(speeds, act, lag, prev, down, *, strategy: str,
              decreasing: bool, capacity: float, dt: float, mig: int,
              n: int):
    """One simulated step on one stream's ``(N,)`` state (pure jnp on
    kernel-loaded values; see the module docstring for the phases)."""
    m = n + 1
    inf = jnp.float32(jnp.inf)
    one = jnp.int32(1)
    iota_n = lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    iota_m = lax.broadcasted_iota(jnp.int32, (1, m), 1)[0]
    cap = jnp.float32(capacity)
    cap_step = jnp.float32(capacity * dt)

    produced = speeds * jnp.float32(dt)
    if act is not None:
        produced = jnp.where(act, produced, 0.0)

    # phase 1: traversal order (stable non-increasing sort as a pairwise
    # rank: strictly-greater plus equal-with-lower-index counts)
    if decreasing:
        col = lax.broadcasted_iota(jnp.int32, (n, n), 1)
        row = lax.broadcasted_iota(jnp.int32, (n, n), 0)
        gt = speeds[:, None] < speeds[None, :]
        eq_lo = (speeds[:, None] == speeds[None, :]) & (col < row)
        rank = jnp.sum((gt | eq_lo).astype(jnp.int32), axis=1)      # (n,)
        oh = rank[:, None] == col
        order = jnp.sum(jnp.where(oh, row, 0), axis=0)
        sp_ord = jnp.sum(jnp.where(oh, speeds[:, None], 0.0), axis=0)
        act_ord = (None if act is None else
                   jnp.sum(jnp.where(oh, act[:, None].astype(jnp.int32), 0),
                           axis=0) > 0)
    else:
        order = iota_n
        sp_ord = speeds
        act_ord = act

    # phase 2: slot selection (binpack_select logic, double-min tie-break)
    loads = jnp.full((m,), inf, jnp.float32)
    creator = jnp.full((m,), NEG, jnp.int32)
    slot_of = jnp.full((n,), NEG, jnp.int32)
    k = jnp.int32(0)
    lastload = jnp.float32(0.0)
    for i in range(n):
        w = sp_ord[i]
        j = order[i]
        d = loads + w
        fits = d <= cap
        if strategy == "next":
            found = (k > 0) & (lastload + w <= cap)
            slot = jnp.where(found, k - 1, k)
        else:
            if strategy == "first":
                score = jnp.where(fits, iota_m.astype(jnp.float32), inf)
            elif strategy == "best":
                score = jnp.where(fits, -loads, inf)
            else:
                score = jnp.where(fits, loads, inf)
            mn = jnp.min(score)
            s_sel = jnp.min(jnp.where(score == mn, iota_m, jnp.int32(127)))
            found = mn < inf
            slot = jnp.where(found, s_sel, k)
        coh = iota_m == slot
        if act_ord is None:
            a = None
            upd = coh
        else:
            a = act_ord[i]
            upd = coh & a
        loads = jnp.where(upd, jnp.where(found, d, w), loads)
        creator = jnp.where(upd & ~found, j, creator)
        new_last = jnp.where(found & (slot == k - 1), lastload + w,
                             jnp.where(~found, w, lastload))
        if a is None:
            lastload = new_last
            k = k + (~found).astype(jnp.int32)
            slot_of = jnp.where(iota_n == j, slot, slot_of)
        else:
            lastload = jnp.where(a, new_last, lastload)
            k = k + (a & ~found).astype(jnp.int32)
            slot_of = jnp.where((iota_n == j) & a, slot, slot_of)

    # phase 3: sticky naming over creation slots (int32 name bitmasks)
    ohc = creator[:n, None] == iota_n[None, :]
    pv = jnp.sum(jnp.where(ohc, prev[None, :], 0), axis=1)
    p_all = jnp.where(creator[:n] >= 0, pv, NEG)
    claimed = jnp.int32(0)
    seen = jnp.int32(0)
    q = jnp.int32(0)
    new_assign = jnp.full((n,), NEG, jnp.int32)
    for s in range(n):
        v = p_all[s]
        vbit = one << jnp.maximum(v, 0)
        live = jnp.int32(s) < k
        cand = (v >= 0) & ((seen & vbit) == 0)
        seen = jnp.where(v >= 0, seen | vbit, seen)
        win = cand & (v >= q) & live
        fall = live & ~win
        nm = jnp.where(win, v, q)
        new_assign = jnp.where((slot_of == s) & live, nm, new_assign)
        claimed = jnp.where(win, claimed | vbit, claimed)
        adv = fall | (win & (v == q))
        mask = claimed | ((one << (q + 1)) - 1)
        low = (~mask) & (mask + 1)
        q = jnp.where(adv, lax.population_count(low - 1), q)

    # phases 4-5: downtime masking + produce/drain (lag_update, in slot
    # space: slot <-> name is a bijection per step so per-bin sums match)
    moved = (prev >= 0) & (new_assign >= 0) & (new_assign != prev)
    new_down = jnp.where(moved, jnp.int32(mig), jnp.maximum(down - 1, 0))
    readable = (new_down == 0) & (new_assign >= 0)
    avail = lag + produced
    live_p = readable & (slot_of >= 0)
    onehot = (slot_of[:, None] == iota_m[None, :]) & live_p[:, None]
    per_bin = jnp.sum(jnp.where(onehot, avail[:, None], 0.0), axis=0)
    ratio = jnp.minimum(1.0, cap_step / jnp.maximum(per_bin, _TINY))
    frac = jnp.sum(jnp.where(onehot, ratio[None, :], 0.0), axis=1)
    new_lag = jnp.maximum(avail * (1.0 - frac), 0.0)
    if act is not None:
        new_lag = jnp.where(act, new_lag, 0.0)
        unread = (new_down > 0) & act
    else:
        unread = new_down > 0
    return new_lag, new_assign, new_down, k, moved, unread


def _loop_fused_kernel(*refs, k_blk: int, n: int, masked: bool,
                       strategy: str, decreasing: bool, capacity: float,
                       dt: float, mig: int):
    """Advance ``k_blk`` steps of one stream; carry lives in VMEM scratch
    across the sequential (``"arbitrary"``) time-block grid dimension."""
    if masked:
        (rates_ref, active_ref, lag0_ref, tot_ref, mx_ref, cons_ref,
         migs_ref, unread_ref, asg_ref, lag_s, prev_s, down_s) = refs
    else:
        (rates_ref, lag0_ref, tot_ref, mx_ref, cons_ref, migs_ref,
         unread_ref, asg_ref, lag_s, prev_s, down_s) = refs
        active_ref = None

    @pl.when(pl.program_id(1) == 0)
    def _init():
        lag_s[...] = lag0_ref[0]
        prev_s[...] = jnp.full((n,), NEG, jnp.int32)
        down_s[...] = jnp.zeros((n,), jnp.int32)

    lag = lag_s[...]
    prev = prev_s[...]
    down = down_s[...]
    for kk in range(k_blk):
        speeds = rates_ref[0, kk]
        act = None if active_ref is None else active_ref[0, kk] > 0
        lag, prev, down, k, moved, unread = _one_step(
            speeds, act, lag, prev, down, strategy=strategy,
            decreasing=decreasing, capacity=capacity, dt=dt, mig=mig, n=n)
        tot_ref[0, kk] = jnp.sum(lag)
        mx_ref[0, kk] = jnp.max(lag)
        cons_ref[0, kk] = k
        migs_ref[0, kk] = jnp.sum(moved.astype(jnp.int32))
        unread_ref[0, kk] = jnp.sum(unread.astype(jnp.int32))
        asg_ref[0, kk] = prev
    lag_s[...] = lag
    prev_s[...] = prev
    down_s[...] = down


def loop_fused_batch(rates, *, strategy: str, decreasing: bool,
                     capacity: float = 1.0, dt: float = 1.0,
                     migration_steps: int = 2, fused_steps: int = 8,
                     active=None, initial_lag=None,
                     interpret: bool | None = None):
    """Run a heuristic policy's whole closed loop in one kernel launch.

    rates: f32[B, T, N] per-partition production rates; active: optional
    bool/i32[B, T, N] partition-existence mask; initial_lag: optional
    f32[B, N] backlog seed (zeros by default).  ``strategy`` in
    ``("next", "first", "best", "worst")`` with ``decreasing`` selects
    the heuristic family member (NF..WFD).  Returns
    ``(lag_total f32[B, T], lag_max f32[B, T], consumers i32[B, T],
    migrations i32[B, T], unreadable i32[B, T], assigns i32[B, T, N])``.

    ``fused_steps`` (K) is the block size: steps advanced per grid step
    while the carry stays in VMEM.  T is padded up to a multiple of K
    internally (padded steps never feed back into real ones: time is
    causal) and outputs are sliced back to T.
    """
    if strategy not in _STRATEGIES:
        raise ValueError(
            f"strategy must be one of {_STRATEGIES}, got {strategy!r}")
    b, t, n = rates.shape
    if n > 14:
        raise ValueError(
            f"loop_fused_batch packs bin names into int32 bitmasks and "
            f"supports n <= 14 partitions; got n = {n} (the lag engine "
            f"falls back to the unfused scan above the limit)")
    k_blk = int(fused_steps)
    if k_blk <= 0:
        raise ValueError(f"fused_steps must be >= 1, got {fused_steps}")
    if interpret is None:
        interpret = _default_interpret()
    masked = active is not None
    t_blocks = -(-t // k_blk)
    t_pad = t_blocks * k_blk
    rates = jnp.asarray(rates, jnp.float32)
    if t_pad != t:
        rates = jnp.pad(rates, ((0, 0), (0, t_pad - t), (0, 0)))
    if initial_lag is None:
        initial_lag = jnp.zeros((b, n), jnp.float32)
    else:
        initial_lag = jnp.asarray(initial_lag, jnp.float32)

    kernel = functools.partial(
        _loop_fused_kernel, k_blk=k_blk, n=n, masked=masked,
        strategy=strategy, decreasing=bool(decreasing),
        capacity=float(capacity), dt=float(dt), mig=int(migration_steps))
    slab = pl.BlockSpec((1, k_blk, n), lambda i, j: (i, j, 0))
    in_specs = [slab]
    args = [rates]
    if masked:
        act = jnp.asarray(active).astype(jnp.int32)
        if t_pad != t:
            act = jnp.pad(act, ((0, 0), (0, t_pad - t), (0, 0)))
        in_specs.append(slab)
        args.append(act)
    in_specs.append(pl.BlockSpec((1, n), lambda i, j: (i, 0)))
    args.append(initial_lag)
    step_spec = pl.BlockSpec((1, k_blk), lambda i, j: (i, j))
    call = pl.pallas_call(
        kernel,
        grid=(b, t_blocks),
        in_specs=in_specs,
        out_specs=[step_spec, step_spec, step_spec, step_spec, step_spec,
                   slab],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, t_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, t_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, t_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, t_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, t_pad, n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n,), jnp.float32),   # lag carry
            pltpu.VMEM((n,), jnp.int32),     # previous assignment
            pltpu.VMEM((n,), jnp.int32),     # migration downtime
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )

    def run(*a):
        outs = call(*a)
        if t_pad != t:
            outs = [o[:, :t] for o in outs]
        return tuple(outs)

    if isinstance(rates, jax.core.Tracer):
        return run(*args)
    with _span("kernel.loop_fused", batch=b, t=t, n=n, k=k_blk,
               interpret=bool(interpret)):
        return run(*args)
