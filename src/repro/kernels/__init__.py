"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel has an explicit BlockSpec VMEM tiling, a jit'd wrapper in
``ops.py``, and a pure-jnp oracle in ``ref.py``; correctness is enforced by
interpret-mode shape/dtype sweeps in tests/test_kernels.py.

The paper itself is a control-plane contribution (no kernel); these kernels
serve the data plane it orchestrates -- plus ``binpack_select``, which puts
the packer's own inner reduction on device for batched algorithm sweeps,
``lag_update``, the fused produce+drain step of the closed-loop lag twin
(``repro.lagsim``), and ``move_eval``, the all-moves delta-cost plane of
the batched annealer (``repro.opt``; for these two the oracle lives next
to the kernel in its module).
"""
from . import lag_update, move_eval, ops, ref

__all__ = ["lag_update", "move_eval", "ops", "ref"]
