"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the wrappers run the kernels in interpret mode when
``interpret`` is unset, so the same call sites work everywhere; on TPU the
kernels compile to Mosaic.  ``flash_attention`` exposes a custom_vjp whose
backward uses the jnp online-softmax path (recompute), so training with
``cfg.use_pallas`` stays differentiable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .binpack_select import (DEFAULT_ROW_TILE, select_slot_batch,
                             select_slot_grid)
from .decode_attention import decode_attention_fwd
from .flash_attention import flash_attention_fwd
from ._compat import default_interpret as _default_interpret
from .rwkv6_scan import rwkv6_wkv_fwd


# ---------------------------------------------------------------------------
# flash attention (B, Sq, H, hd) interface matching models/attention.py
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    """q/k/v: (B, S, H, hd) (kv heads already expanded).  Returns same layout."""
    return _flash_fwd_impl(q, k, v, causal)


def _flash_fwd_impl(q, k, v, causal):
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_fwd(qt, kt, vt, causal=causal,
                              interpret=_default_interpret())
    return out.transpose(0, 2, 1, 3)


def _flash_fwd_rule(causal, q, k, v):
    return _flash_fwd_impl(q, k, v, causal), (q, k, v)


def _flash_bwd_rule(causal, res, g):
    q, k, v = res

    def ref_fn(q_, k_, v_):
        from repro.models.attention import online_softmax_attention
        from repro.models.base import ArchConfig
        cfg = ArchConfig(name="_", family="dense", n_layers=1, d_model=1,
                         n_heads=1, n_kv_heads=1, d_ff=1, vocab_size=1,
                         attn_chunk=1024)
        return online_softmax_attention(q_, k_, v_, cfg, causal=causal)

    _, vjp = jax.vjp(ref_fn, q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@jax.jit
def decode_attention(q, k_cache, v_cache, cache_len):
    """q: (B, KV, G, hd); caches: (B, S, KV, hd) model layout.  Transposes to
    the kernel's (B, KV, S, hd) and back are fused by XLA."""
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    return decode_attention_fwd(q, kt, vt, cache_len,
                                interpret=_default_interpret())


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, w, u, s0, chunk: Optional[int] = None):
    """r,k,v,w: (B, T, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).

    Chunks T through the kernel when it exceeds the VMEM budget, carrying
    the state between launches.
    """
    b, t, h, hd = r.shape
    budget = 4096
    if chunk is None:
        chunk = min(t, budget)
    if t <= chunk:
        return rwkv6_wkv_fwd(r, k, v, w, u, s0,
                             interpret=_default_interpret())
    assert t % chunk == 0
    nc = t // chunk

    def body(s, xs):
        rc, kc, vc, wc = xs
        out, s2 = rwkv6_wkv_fwd(rc, kc, vc, wc, u, s,
                                interpret=_default_interpret())
        return s2, out

    resh = lambda x: x.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    s_last, outs = jax.lax.scan(body, s0, (resh(r), resh(k), resh(v), resh(w)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, t, h, hd)
    return out, s_last


# ---------------------------------------------------------------------------
# packer fit selection
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("strategy",))
def select_slot(loads, w, k, capacity, strategy: str = "best"):
    # interpret defaults inside the kernel wrapper (same backend rule)
    return select_slot_batch(loads, w, k, capacity, strategy=strategy)


@functools.partial(jax.jit, static_argnames=("strategy", "row_tile"))
def select_slot_batched(loads, w, k, capacity, strategy: str = "best",
                        row_tile: int = DEFAULT_ROW_TILE):
    """Batched-grid variant: loads (B, N, M); w/k/capacity (B, N).  One
    kernel launch covers the whole sweep batch."""
    return select_slot_grid(loads, w, k, capacity, strategy=strategy,
                            row_tile=row_tile)
