"""RWKV-6 WKV recurrence as a Pallas TPU kernel.

The jnp reference scans T sequential steps with a (B, H, hd, hd) state --
4096 tiny HLO loop iterations on TPU, each launching VPU work with poor
occupancy.  The kernel instead runs grid (B, H) with the whole per-head
(T, hd) streams resident in VMEM and a fori_loop over T that keeps the
(hd, hd) state in VMEM scratch: one kernel launch, zero HBM traffic for the
state, T*(hd x hd) outer-product updates on the VPU back to back.

VMEM per grid step: 4 streams (T, hd) f32 + state (hd, hd) + out (T, hd):
T=4096, hd=64 -> ~5.3 MB.  For longer T the ops.py wrapper chunks T and
carries the state between calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, s_out_ref,
                state_ref, *, t_steps: int, hd: int):
    state_ref[...] = s0_ref[0, 0]

    def step(t, _):
        r_t = r_ref[0, t, 0, :]                      # (hd,)
        k_t = k_ref[0, t, 0, :]
        v_t = v_ref[0, t, 0, :]
        w_t = w_ref[0, t, 0, :]
        u = u_ref[0]                                 # (hd,)
        kv = k_t[:, None] * v_t[None, :]             # (hd, hd) outer product
        s = state_ref[...]
        o_ref[0, t, 0, :] = jnp.sum(
            r_t[:, None] * (s + u[:, None] * kv), axis=0)
        state_ref[...] = w_t[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, t_steps, step, ())
    s_out_ref[0, 0] = state_ref[...]


def rwkv6_wkv_fwd(r, k, v, w, u, s0, *, interpret: bool = False):
    """r,k,v,w: (B, T, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).

    Returns (out (B, T, H, hd), s_last (B, H, hd, hd)).
    w is the per-step decay in (0, 1) (already exp(-exp(.)) transformed).
    """
    b, t, h, hd = r.shape
    kernel = functools.partial(_wkv_kernel, t_steps=t, hd=hd)
    stream = pl.BlockSpec((1, t, 1, hd), lambda b_, h_: (b_, 0, h_, 0))
    state = pl.BlockSpec((1, 1, hd, hd), lambda b_, h_: (b_, h_, 0, 0))
    out, s_last = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[stream, stream, stream, stream,
                  pl.BlockSpec((1, hd), lambda b_, h_: (h_, 0)),
                  state],
        out_specs=[stream, state],
        out_shape=[jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, s_last
