"""Delta-cost evaluation of all (partition, target-bin) moves as a Pallas
TPU kernel, batched over annealing chains.

The stochastic packing optimizer (``repro.opt.anneal``) runs thousands of
simulated-annealing chains in parallel; each step every chain must know the
cost change of *every* single-item relocation -- move partition ``p`` from
its current bin to bin ``b`` -- under the objective

    cost = bins_used + (lam / C) * sum_{moved p} speed(p)

(the paper's consumer count plus the Eq. 10 R-score weighted by ``lam``).
That is an ``f32[K, N, M]`` plane per step and the optimizer's hot inner
loop, so the kernel fuses the whole evaluation into one VMEM pass per
chain: ``grid = (K,)``, each program instance holds one chain's bin state
(loads/counts over ``M`` name slots) plus the shared item data and emits
the full ``(N, M)`` delta tile.  Moves that would violate capacity are
masked to ``MOVE_BLOCKED`` (a large finite sentinel); a move is allowed iff

    b != assign[p]  and  (loads[b] + w <= C   or
                          counts[b] == 0 and w > C)

-- the same oversized-item exception as ``binpack.py`` (an item wider than
a bin may sit alone in a dedicated overflow bin, nothing ever joins it).

Masking (variable-N fleets): pass ``active`` and every move of an
inactive item is additionally masked to ``MOVE_BLOCKED`` -- a partition
that does not exist can never be relocated.  Callers are responsible for
excluding inactive items from ``counts`` (the annealer does), so bins
holding only inactive items already read as empty here.  ``active=None``
keeps the exact unmasked program.

Semantics are pinned to the pure-jnp oracle ``move_delta_reference`` below
(tests/test_kernels.py); on hosts without a TPU the wrapper falls back to
Pallas interpreter mode automatically, like ``binpack_select`` and
``lag_update``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.telemetry.spans import span as _span

from ._compat import CompilerParams as _CompilerParams
from ._compat import default_interpret as _default_interpret

# Large finite sentinel for masked (infeasible) moves.  Finite so that
# downstream softmax/Gumbel selection arithmetic (-MOVE_BLOCKED / T) stays
# inside the float32 range for any sane temperature.
MOVE_BLOCKED = 1e30


def move_delta_reference(loads, counts, assign, speeds, prev, lam, capacity,
                         *, active=None):
    """Pure-jnp oracle over ``(..., M)`` bin state and ``(..., N)`` items.

    loads:  f32[..., M] current load per bin name slot;
    counts: i32[..., M] items per bin name slot (bins with only zero-speed
            items still count as open);
    assign: i32[..., N] current bin name per item (always >= 0);
    speeds: f32[..., N] item sizes;
    prev:   i32[..., N] previous bin name per item, -1 = unassigned
            (the R-score only prices moves of previously-assigned items);
    lam:    f32[...] R-score weight, broadcast over the (N, M) plane;
    capacity: f32[...] bin size C, broadcast likewise;
    active: optional bool/i32[..., N] item mask -- every move of an item
            with ``active == 0`` is masked to ``MOVE_BLOCKED``.

    Returns f32[..., N, M]: ``delta[..., p, b]`` is the cost change of
    relocating item ``p`` to bin ``b``, or ``MOVE_BLOCKED`` when the move
    is a no-op (``b == assign[p]``) or infeasible.
    """
    loads = loads.astype(jnp.float32)
    counts = counts.astype(jnp.int32)
    assign = assign.astype(jnp.int32)
    speeds = speeds.astype(jnp.float32)
    prev = prev.astype(jnp.int32)
    m = loads.shape[-1]
    lam = jnp.asarray(lam, jnp.float32)[..., None, None]
    cap = jnp.asarray(capacity, jnp.float32)[..., None, None]

    count_a = jnp.take_along_axis(counts, assign, axis=-1)       # (..., N)
    names = jnp.arange(m, dtype=jnp.int32)                       # (M,)
    w = speeds[..., :, None]                                     # (..., N, 1)
    d_bins = ((counts[..., None, :] == 0).astype(jnp.float32)
              - (count_a[..., :, None] == 1).astype(jnp.float32))
    sticky = prev >= 0
    was_moved = ((assign != prev) & sticky).astype(jnp.float32)  # (..., N)
    now_moved = ((names != prev[..., :, None])
                 & sticky[..., :, None]).astype(jnp.float32)     # (..., N, M)
    d_r = (now_moved - was_moved[..., :, None]) * w * (lam / cap)
    allowed = ((assign[..., :, None] != names)
               & ((loads[..., None, :] + w <= cap)
                  | ((counts[..., None, :] == 0) & (w > cap))))
    if active is not None:
        allowed = allowed & active.astype(bool)[..., :, None]
    return jnp.where(allowed, d_bins + d_r, MOVE_BLOCKED)


def _move_eval_kernel(loads_ref, counts_ref, assign_ref, speeds_ref,
                      prev_ref, lam_ref, cap_ref, *rest, n: int, m: int,
                      masked: bool):
    """One chain: the full (N, M) delta plane in a single VMEM pass."""
    if masked:
        active_ref, out_ref = rest
    else:
        (out_ref,) = rest
    loads = loads_ref[0]                                  # (M,)
    counts = counts_ref[0]                                # (M,)
    assign = assign_ref[0]                                # (N,)
    speeds = speeds_ref[0]                                # (N,)
    prev = prev_ref[0]                                    # (N,)
    lam = lam_ref[0, 0]
    cap = cap_ref[0, 0]
    names = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    cur = assign[:, None] == names                        # (N, M) one-hot
    count_a = jnp.sum(jnp.where(cur, counts[None, :], 0), axis=1)   # (N,)
    w = speeds[:, None]
    d_bins = ((counts[None, :] == 0).astype(jnp.float32)
              - (count_a[:, None] == 1).astype(jnp.float32))
    sticky = prev >= 0
    was_moved = ((assign != prev) & sticky).astype(jnp.float32)
    now_moved = ((names != prev[:, None]) & sticky[:, None]).astype(jnp.float32)
    d_r = (now_moved - was_moved[:, None]) * w * (lam / cap)
    allowed = (~cur) & ((loads[None, :] + w <= cap)
                        | ((counts[None, :] == 0) & (w > cap)))
    if masked:
        allowed = allowed & (active_ref[0] > 0)[:, None]
    out_ref[0] = jnp.where(allowed, d_bins + d_r, MOVE_BLOCKED)


def move_delta_batch(loads, counts, assign, speeds, prev, lam, cap, *,
                     active=None, interpret: bool | None = None):
    """Fused move evaluation over a batch of chains in one kernel launch.

    loads: f32[K, M]; counts: i32[K, M]; assign: i32[K, N];
    speeds: f32[K, N]; prev: i32[K, N]; lam, cap: f32[K]; active:
    optional i32/bool[K, N] item mask (0 = item does not exist, all of
    its moves are blocked).
    Returns f32[K, N, M] move deltas (``MOVE_BLOCKED`` where masked).
    ``grid = (K,)``; each program instance owns one chain's bin state and
    its (N, M) delta tile.
    """
    if interpret is None:
        interpret = _default_interpret()
    masked = active is not None
    k, m = loads.shape
    n = assign.shape[1]
    kernel = functools.partial(_move_eval_kernel, n=n, m=m, masked=masked)
    m_spec = pl.BlockSpec((1, m), lambda i: (i, 0))
    n_spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    s_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    in_specs = [m_spec, m_spec, n_spec, n_spec, n_spec, s_spec, s_spec]
    args = [loads.astype(jnp.float32), counts.astype(jnp.int32),
            assign.astype(jnp.int32), speeds.astype(jnp.float32),
            prev.astype(jnp.int32), lam.astype(jnp.float32).reshape(k, 1),
            cap.astype(jnp.float32).reshape(k, 1)]
    if masked:
        in_specs.append(n_spec)
        args.append(active.astype(jnp.int32))
    call = pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n, m), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )
    if isinstance(loads, jax.core.Tracer):
        # under a jit trace the launch is timed by the caller's spans
        return call(*args)
    with _span("kernel.move_eval", chains=k, n=n, m=m,
               interpret=bool(interpret)):
        return call(*args)
