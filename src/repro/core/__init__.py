"""Core contribution of the paper: variable-item-size bin packing with
rebalance cost (Rscore), the Modified Any Fit family, and the
monitor/controller control plane.
"""
from .assignment import (
    ConsumerId,
    PackResult,
    PartitionId,
    capacity_lower_bound,
    group_view,
    rebalanced_partitions,
)
from .binpack import CLASSICAL, Bins, pack
from .metrics import (
    StreamRun,
    average_rscores,
    cardinal_bin_score,
    evaluate_deltas,
    pareto_front,
    run_stream,
)
from .jaxpack import (
    SweepResult,
    evaluate_stream_jax,
    sweep_streams,
)
from .modified import MODIFIED, modified_any_fit


def __getattr__(name: str):
    # deprecated name tables forward to the per-module shims (which warn
    # once and resolve through repro.registry)
    if name == "ALL_ALGORITHMS":
        from . import modified as _modified
        return _modified.ALL_ALGORITHMS
    if name == "ALL_ALGORITHM_NAMES":
        from . import jaxpack as _jaxpack
        return _jaxpack.ALL_ALGORITHM_NAMES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from .rscore import recovery_iterations, rscore, rscore_of_set
from .scenarios import (
    SCENARIO_FAMILIES,
    generate_scenario,
    scenario_suite,
    stack_suite,
)
from .streams import PAPER_DELTAS, generate_stream, paper_streams

__all__ = [
    "ConsumerId",
    "PackResult",
    "PartitionId",
    "capacity_lower_bound",
    "group_view",
    "rebalanced_partitions",
    "CLASSICAL",
    "Bins",
    "pack",
    "StreamRun",
    "average_rscores",
    "cardinal_bin_score",
    "evaluate_deltas",
    "pareto_front",
    "run_stream",
    "MODIFIED",
    "modified_any_fit",
    "recovery_iterations",
    "rscore",
    "rscore_of_set",
    "PAPER_DELTAS",
    "generate_stream",
    "paper_streams",
    "SweepResult",
    "evaluate_stream_jax",
    "sweep_streams",
    "SCENARIO_FAMILIES",
    "generate_scenario",
    "scenario_suite",
    "stack_suite",
]
