"""Test-data generation (paper Sec. VI-A, Eq. 11).

A *measurement* maps each partition to its write speed at one instant; a
*stream* is a list of N measurements.  Speeds evolve by a bounded random walk

    s_i(p) = max{0, s_{i-1}(p) + phi(delta)/100 * C}

with phi(delta) uniform on [-delta, +delta].  The paper generates 6 streams
with N=500 and delta in {0, 5, 10, 15, 20, 25}; initial speeds are uniform on
[0, 100%]*C (the other three init modes showed no significant difference and
are provided for completeness).
"""
from __future__ import annotations

from typing import Literal, Sequence

import numpy as np

PAPER_DELTAS = (0, 5, 10, 15, 20, 25)
PAPER_N_MEASUREMENTS = 500

InitMode = Literal["random", "zero", "half", "full"]


def initial_speeds(
    n_partitions: int,
    capacity: float,
    init: InitMode = "random",
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    rng = rng or np.random.default_rng(0)
    if init == "random":
        return rng.uniform(0.0, capacity, size=n_partitions)
    if init == "zero":
        return np.zeros(n_partitions)
    if init == "half":
        return np.full(n_partitions, 0.5 * capacity)
    if init == "full":
        return np.full(n_partitions, float(capacity))
    raise ValueError(f"unknown init mode {init!r}")


def generate_stream(
    n_partitions: int,
    n_measurements: int = PAPER_N_MEASUREMENTS,
    delta: float = 10.0,
    capacity: float = 1.0,
    init: InitMode = "random",
    seed: int = 0,
) -> np.ndarray:
    """Return an (N, P) array of write speeds following Eq. 11."""
    rng = np.random.default_rng(seed)
    out = np.empty((n_measurements, n_partitions), dtype=np.float64)
    out[0] = initial_speeds(n_partitions, capacity, init, rng)
    for i in range(1, n_measurements):
        step = rng.uniform(-delta, delta, size=n_partitions) / 100.0 * capacity
        out[i] = np.maximum(0.0, out[i - 1] + step)
    return out


def paper_streams(
    n_partitions: int,
    capacity: float = 1.0,
    init: InitMode = "random",
    seed: int = 0,
    n_measurements: int = PAPER_N_MEASUREMENTS,
) -> dict:
    """The paper's six streams, keyed by delta."""
    return {
        d: generate_stream(n_partitions, n_measurements, d, capacity, init, seed + k)
        for k, d in enumerate(PAPER_DELTAS)
    }
