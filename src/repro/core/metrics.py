"""Evaluation metrics (paper Sec. VI-B) and the stream-evaluation driver.

* Cardinal Bin Score  CBS_delta(a)  -- Eq. 12: mean relative excess bins of
  algorithm ``a`` over the per-iteration best algorithm.  Encodes operational
  cost; lower is better.
* Average Rscore      E_delta^a(R)  -- Eq. 13: mean Rscore over a stream.
  Encodes rebalance cost; lower is better.
* Pareto front over (CBS, E[R])     -- Fig. 9.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Sequence, Tuple

import numpy as np

from .assignment import PackResult
from .rscore import rscore


@dataclasses.dataclass
class StreamRun:
    """Per-iteration trace of one algorithm over one stream."""

    name: str
    bins: List[int] = dataclasses.field(default_factory=list)
    rscores: List[float] = dataclasses.field(default_factory=list)

    @property
    def average_rscore(self) -> float:  # Eq. 13
        return float(np.mean(self.rscores)) if self.rscores else 0.0


def run_stream(
    algorithms: Mapping[str, Callable],
    stream: np.ndarray,
    capacity: float,
    partition_ids: Sequence | None = None,
    active: np.ndarray | None = None,
) -> Dict[str, StreamRun]:
    """Evolve every algorithm independently over a (N, P) stream.

    Each algorithm sees its *own* previous assignment when packing iteration
    i (the controller keeps one group per algorithm in the paper's tests).

    ``active`` (bool (N, P), optional) is the partition-existence mask:
    a dead partition is dropped from the iteration's speed map entirely
    (the reference packers' native notion of a partition that does not
    exist), its hand-off is never priced by the R-score, and on rebirth
    it re-enters with no sticky memory -- the same semantics as the
    masked array path in ``jaxpack`` (tests/test_masking.py pins the
    cross-backend agreement).
    """
    n_iter, n_parts = stream.shape
    pids = list(partition_ids) if partition_ids is not None else list(range(n_parts))
    assert len(pids) == n_parts
    if active is not None:
        active = np.asarray(active, bool)
        assert active.shape == stream.shape, (active.shape, stream.shape)
    runs = {name: StreamRun(name) for name in algorithms}
    prev: Dict[str, Dict] = {name: {} for name in algorithms}
    for i in range(n_iter):
        live = (range(n_parts) if active is None
                else [j for j in range(n_parts) if active[i, j]])
        speeds = {pids[j]: float(stream[i, j]) for j in live}
        for name, algo in algorithms.items():
            prev_live = {p: c for p, c in prev[name].items() if p in speeds}
            res: PackResult = algo(speeds, capacity, prev=prev_live)
            runs[name].bins.append(res.n_bins)
            runs[name].rscores.append(
                rscore(prev[name], res.pid_to_bin, speeds, capacity,
                       active=None if active is None else set(speeds)))
            prev[name] = res.pid_to_bin
    return runs


def cbs_from_bins(z) -> np.ndarray:
    """Eq. 12 on a per-iteration bin-count matrix ``(A, N)`` (algorithms x
    iterations): mean relative excess over the per-iteration best.  The
    single definition every CBS consumer (``cardinal_bin_score``,
    ``repro.api.evaluate``, ``benchmarks/paper_eval``) reduces through."""
    z = np.asarray(z, dtype=np.float64)
    zmin = z.min(axis=0)
    zmin = np.maximum(zmin, 1.0)  # guard: zero bins only if zero load for all
    return ((z - zmin) / zmin).mean(axis=1)


def cardinal_bin_score(runs: Mapping[str, StreamRun]) -> Dict[str, float]:
    """Eq. 12 over a family of runs on the same stream."""
    names = list(runs)
    cbs = cbs_from_bins([runs[n].bins for n in names])
    return {n: float(c) for n, c in zip(names, cbs)}


def average_rscores(runs: Mapping[str, StreamRun]) -> Dict[str, float]:
    return {n: r.average_rscore for n, r in runs.items()}


def pareto_front(points: Mapping[str, Tuple[float, float]]) -> List[str]:
    """Names of non-dominated points, minimizing both coordinates.

    ``a`` dominates ``b`` iff a.x <= b.x and a.y <= b.y with at least one
    strict inequality.
    """
    front: List[str] = []
    for a, (ax, ay) in points.items():
        dominated = any(
            (bx <= ax and by <= ay) and (bx < ax or by < ay)
            for b, (bx, by) in points.items()
            if b != a
        )
        if not dominated:
            front.append(a)
    return sorted(front)


def evaluate_deltas(
    algorithms: Mapping[str, Callable],
    streams_by_delta: Mapping[float, np.ndarray],
    capacity: float,
) -> Dict[float, Dict[str, Tuple[float, float]]]:
    """(CBS, E[R]) per algorithm per delta -- the inputs to Figs. 6-9."""
    out: Dict[float, Dict[str, Tuple[float, float]]] = {}
    for delta, stream in streams_by_delta.items():
        runs = run_stream(algorithms, stream, capacity)
        cbs = cardinal_bin_score(runs)
        er = average_rscores(runs)
        out[delta] = {n: (cbs[n], er[n]) for n in runs}
    return out
