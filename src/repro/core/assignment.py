"""Shared assignment/state dataclasses for the bin-packing autoscaler.

Terminology follows the paper (Landau et al., 2022):

* partition  -- an ordered queue (Kafka partition / request stream / data
  shard).  Identified by any hashable id.
* consumer   -- a bin.  Identified by a non-negative int ("bin index"; the
  paper's list-of-bins is indexed left to right).
* assignment -- map partition -> consumer.  Exactly one consumer per
  partition (paper Eq. 7); a consumer may hold many partitions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Hashable, Iterable, List, Mapping, Sequence, Set

PartitionId = Hashable
ConsumerId = int


@dataclasses.dataclass
class PackResult:
    """Outcome of one bin-packing iteration.

    ``pid_to_bin`` maps each partition to the *name* of its bin.  Bin names
    are consumer ids: with the sticky adaptation (paper Sec. IV-C) a newly
    created bin takes the name of the partition's previous consumer when that
    name is still free, so a partition that stays put is not counted as
    rebalanced.
    """

    pid_to_bin: Dict[PartitionId, ConsumerId]
    loads: Dict[ConsumerId, float]
    creation_order: List[ConsumerId]

    @property
    def n_bins(self) -> int:
        return len(self.creation_order)

    def bins(self) -> Dict[ConsumerId, List[PartitionId]]:
        out: Dict[ConsumerId, List[PartitionId]] = {c: [] for c in self.creation_order}
        for pid, cid in self.pid_to_bin.items():
            out[cid].append(pid)
        return out

    def composition(self) -> Set[frozenset]:
        """Multiset-as-set of bin contents (names stripped) for equivalence tests."""
        return {frozenset(ps) for ps in self.bins().values()}


def rebalanced_partitions(
    prev: Mapping[PartitionId, ConsumerId],
    new: Mapping[PartitionId, ConsumerId],
) -> Set[PartitionId]:
    """Partitions whose consumer changed between two iterations.

    A partition that was previously unassigned incurs no stop->start hand-off
    (nobody has to stop reading it), so only partitions present in *both*
    assignments with a different consumer count as rebalanced.
    """
    return {p for p, c in new.items() if p in prev and prev[p] != c}


def group_view(assignment: Mapping[PartitionId, ConsumerId]) -> Dict[ConsumerId, List[PartitionId]]:
    """Invert a partition->consumer map into the controller's group view."""
    try:
        pids = sorted(assignment)
    except TypeError:  # mixed / unorderable pid types
        pids = sorted(assignment, key=repr)
    out: Dict[ConsumerId, List[PartitionId]] = {}
    for pid in pids:
        out.setdefault(assignment[pid], []).append(pid)
    return out


def total_load(loads: Mapping[ConsumerId, float]) -> float:
    return float(sum(loads.values()))


def capacity_lower_bound(speeds: Iterable[float], capacity: float) -> int:
    """L1 lower bound ceil(sum w / C) on the number of bins."""
    total = float(sum(speeds))
    if total <= 0.0:
        return 0
    import math

    return int(math.ceil(total / capacity - 1e-12))
