"""Monitor process (paper Sec. V-A).

Samples each partition's log size via ``describe_log_dirs()``, keeps a 30 s
sliding window of (timestamp, size) pairs per partition, estimates the write
speed as (latest - earliest) / window span, and publishes the measurement map
to the ``monitor.writeSpeed`` topic for the controller.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Deque, Dict, Iterable, Optional, Tuple

from repro.broker import Broker, TopicPartition

WRITE_SPEED_TOPIC = "monitor.writeSpeed"
DEFAULT_WINDOW_SECS = 30.0


@dataclasses.dataclass
class Measurement:
    """One measurement map: write speed (bytes/s) per partition, stamped."""

    timestamp: float
    speeds: Dict[TopicPartition, float]

    def to_record(self) -> str:
        return json.dumps({
            "timestamp": self.timestamp,
            "speeds": [[tp.topic, tp.partition, s] for tp, s in self.speeds.items()],
        })

    @staticmethod
    def from_record(raw: str) -> "Measurement":
        d = json.loads(raw)
        return Measurement(
            timestamp=d["timestamp"],
            speeds={TopicPartition(t, int(p)): float(s) for t, p, s in d["speeds"]},
        )


class Monitor:
    def __init__(
        self,
        broker: Broker,
        topics: Iterable[str],
        window_secs: float = DEFAULT_WINDOW_SECS,
        publish: bool = True,
    ):
        self.broker = broker
        self.topics = list(topics)
        self.window = float(window_secs)
        self.publish = publish
        self._samples: Dict[TopicPartition, Deque[Tuple[float, int]]] = {}
        if publish:
            broker.create_topic(WRITE_SPEED_TOPIC, 1)

    def sample(self) -> Measurement:
        """Query partition sizes, update windows, publish + return speeds."""
        now = self.broker.clock.now()
        sizes = self.broker.describe_log_dirs(self.topics)
        speeds: Dict[TopicPartition, float] = {}
        for tp, size in sizes.items():
            q = self._samples.setdefault(tp, deque())
            q.append((now, size))
            # queries older than the window are guaranteed to be at the front
            while q and q[0][0] < now - self.window:
                q.popleft()
            t0, s0 = q[0]
            t1, s1 = q[-1]
            span = t1 - t0
            speeds[tp] = (s1 - s0) / span if span > 0 else 0.0
        m = Measurement(now, speeds)
        if self.publish:
            rec = m.to_record()
            self.broker.produce(TopicPartition(WRITE_SPEED_TOPIC, 0), rec,
                                nbytes=len(rec))
        return m


def read_latest_measurement(broker: Broker, group: str = "controller"
                            ) -> Optional[Measurement]:
    """Controller-side: drain monitor.writeSpeed, return the newest map."""
    tp = TopicPartition(WRITE_SPEED_TOPIC, 0)
    if WRITE_SPEED_TOPIC not in broker.topics:
        return None
    part = broker.partition(tp)
    off = broker.committed(group, tp)
    recs = part.read(off)
    if not recs:
        return None
    broker.commit(group, tp, recs[-1].offset + 1)
    return Measurement.from_record(recs[-1].value)
