"""Batched synthetic workload scenarios for the packer fleet.

The paper evaluates the algorithms on six bounded-random-walk streams
(Eq. 11, ``streams.py``).  Production consumer groups see far more shapes
than a random walk: daily traffic cycles, launch ramps, flash crowds,
topics appearing and disappearing, and heavy-tailed partition skew.  This
module generates *batches* of such trajectories as ``f32[batch, iters, n]``
arrays so the vmapped sweep driver (``jaxpack.sweep_streams``) can evaluate
every algorithm over a whole fleet of scenarios in one XLA program.

Families (see docs/paper_map.md for the full catalogue):

* ``random_walk`` -- the paper's Eq. 11 walk, batched (continuity baseline).
* ``diurnal``     -- sinusoidal day/night cycle with per-partition phase and
                     amplitude plus walk noise.
* ``ramp``        -- linear growth/decay per partition (product launches,
                     migrations draining traffic away).
* ``bursty``      -- flash crowds: Bernoulli spike arrivals with geometric
                     decay riding a calm baseline.
* ``churn``       -- partitions flip between hot and near-idle at random
                     switch times (topics created/abandoned mid-stream).
* ``heavy_tail``  -- log-normal per-partition base rates (a few whales, many
                     minnows) with multiplicative noise.
* ``topic_lifecycle`` -- partitions are *born* and *die* at random times:
                     before birth and after death a partition does not
                     exist at all (speed 0 and, through the masked API,
                     ``active == False``).
* ``adversarial``  -- the genome-parameterized composite family the
                     adversarial scenario search (``repro.scenarios``)
                     evolves: heavy-tailed partition skew under a timed
                     burst plateau (the sustained-ingest shape of the
                     Kafka benchmark paper, arXiv 2003.06452), plus
                     churn flips and lifecycle windows on a configurable
                     partition fraction.

Every family is *registered*: a :class:`FamilySpec` names its generator
functions together with the knobs a scenario search may turn --
each a :class:`KnobSpec` with bounds and a default -- so a genome is
just a vector over a family's registered knobs (``repro.scenarios.genome``
builds exactly that).  ``SCENARIO_FAMILIES`` / ``MASKED_SCENARIO_FAMILIES``
remain the plain name->generator views of the registry.

Masked scenarios (variable-N fleets): ``generate_masked_scenario`` /
``masked_scenario_suite`` return ``(speeds f32[B, T, N], active
bool[B, T, N])`` pairs.  ``churn`` and ``topic_lifecycle`` emit *true*
masks -- a dead partition is absent, not "near idle" -- while the
always-on families carry an all-``True`` mask, so one downstream
contract (``sweep_streams(..., active=...)``, ``sweep_lag(...,
active=...)``, ``repro.fleet``) covers every family.  The legacy
unmasked API is unchanged: ``generate_scenario("churn")`` still fakes
dead topics as near-idle speeds for callers that cannot represent
absence.

Everything is pure ``jax.random`` -- a fixed key gives a bit-identical
batch on every call -- and every generator clips speeds to ``>= 0``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _concrete_float(x) -> Optional[float]:
    """``x`` as a python float when it is a host-side constant, ``None``
    when it is a traced value (genome search passes traced knobs; host
    validation must not force them)."""
    if isinstance(x, (bool, int, float, np.floating, np.integer)):
        return float(x)
    return None


def _check_lifecycle_window(birth, death, *, birth_name: str,
                            death_name: str) -> None:
    """Satellite fix: an empty lifecycle window (death precedes birth)
    used to be silently accepted -- the partition just never existed,
    which reads as a mysteriously idle scenario.  Reject it by name when
    both knobs are host-side constants (traced genome decodes repair the
    ordering instead, see ``repro.scenarios.search``)."""
    b, d = _concrete_float(birth), _concrete_float(death)
    if b is not None and d is not None and d < b:
        raise ValueError(
            f"lifecycle window is empty: death precedes birth "
            f"({death_name}={d!r} < {birth_name}={b!r}); a partition's "
            f"death step must not precede its birth step")


def _walk(key: jax.Array, batch: int, iters: int, n: int, step_scale,
          init: jax.Array) -> jax.Array:
    """Unclipped drift: init + cumsum(uniform steps).  Used as additive /
    log-space noise; callers clip the final speeds, not the drift (the
    paper's per-step clip lives in ``_clipped_walk``)."""
    steps = jax.random.uniform(key, (batch, iters - 1, n),
                               minval=-1.0, maxval=1.0) * step_scale
    return init[:, None, :] + jnp.concatenate(
        [jnp.zeros((batch, 1, n)), jnp.cumsum(steps, axis=1)], axis=1)


def _clipped_walk(key: jax.Array, batch: int, iters: int, n: int, step_scale,
                  init: jax.Array) -> jax.Array:
    """Eq. 11 exactly: s_i = max{0, s_{i-1} + phi}, phi ~ U[-d, d] per step."""
    steps = jax.random.uniform(key, (iters - 1, batch, n),
                               minval=-1.0, maxval=1.0) * step_scale

    def body(s, phi):
        s = jnp.maximum(s + phi, 0.0)
        return s, s

    _, tail = jax.lax.scan(body, init, steps)
    return jnp.concatenate([init[None], tail], axis=0).transpose(1, 0, 2)


def random_walk(key: jax.Array, batch: int, iters: int, n: int, *,
                capacity: float = 1.0, delta: float = 10.0) -> jax.Array:
    """The paper's Eq. 11 stream, batched.  ``delta`` in percent of C."""
    k_init, k_walk = jax.random.split(key)
    init = jax.random.uniform(k_init, (batch, n), maxval=capacity)
    return _clipped_walk(k_walk, batch, iters, n,
                         delta / 100.0 * capacity, init)


def diurnal(key: jax.Array, batch: int, iters: int, n: int, *,
            capacity: float = 1.0, period: int = 96, amplitude: float = 0.4,
            noise: float = 0.02) -> jax.Array:
    """Day/night cycle: per-partition mean, phase and amplitude, plus walk
    noise.  ``period`` is the cycle length in iterations."""
    k_mean, k_phase, k_amp, k_noise = jax.random.split(key, 4)
    mean = jax.random.uniform(k_mean, (batch, 1, n), minval=0.1,
                              maxval=0.6) * capacity
    phase = jax.random.uniform(k_phase, (batch, 1, n), maxval=2 * jnp.pi)
    amp = jax.random.uniform(k_amp, (batch, 1, n),
                             maxval=amplitude) * capacity
    t = jnp.arange(iters, dtype=jnp.float32)[None, :, None]
    wave = mean + amp * jnp.sin(2 * jnp.pi * t / period + phase)
    drift = _walk(k_noise, batch, iters, n, noise * capacity,
                  jnp.zeros((batch, n)))
    return jnp.maximum(wave + drift, 0.0)


def ramp(key: jax.Array, batch: int, iters: int, n: int, *,
         capacity: float = 1.0, max_slope: float = 1.5,
         noise: float = 0.02) -> jax.Array:
    """Linear ramps: each partition grows or decays toward a target over the
    trace.  ``max_slope`` bounds total change in units of C."""
    k_init, k_slope, k_noise = jax.random.split(key, 3)
    init = jax.random.uniform(k_init, (batch, 1, n), maxval=0.8) * capacity
    slope = jax.random.uniform(k_slope, (batch, 1, n), minval=-max_slope,
                               maxval=max_slope) * capacity
    t = jnp.arange(iters, dtype=jnp.float32)[None, :, None] / max(iters - 1, 1)
    drift = _walk(k_noise, batch, iters, n, noise * capacity,
                  jnp.zeros((batch, n)))
    return jnp.maximum(init + slope * t + drift, 0.0)


def bursty(key: jax.Array, batch: int, iters: int, n: int, *,
           capacity: float = 1.0, base: float = 0.15, p_spike: float = 0.02,
           spike: float = 1.0, decay: float = 0.8) -> jax.Array:
    """Flash crowds: a calm baseline plus Bernoulli spike arrivals that decay
    geometrically (rate ``decay`` per iteration)."""
    k_base, k_arrive, k_size = jax.random.split(key, 3)
    floor = jax.random.uniform(k_base, (batch, 1, n), minval=0.2,
                               maxval=1.0) * base * capacity
    arrive = jax.random.bernoulli(k_arrive, p_spike, (iters, batch, n))
    size = jax.random.uniform(k_size, (iters, batch, n), minval=0.3,
                              maxval=1.0) * spike * capacity

    def body(level, xs):
        hit, s = xs
        level = jnp.maximum(level * decay, jnp.where(hit, s, 0.0))
        return level, level

    _, levels = jax.lax.scan(body, jnp.zeros((batch, n)), (arrive, size))
    return floor + levels.transpose(1, 0, 2)


def _churn_state(key: jax.Array, batch: int, iters: int, n: int, *,
                 capacity: float, p_flip: float, hot: float, noise: float
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared churn machinery: (on bool[B, T, N], level f32[B, 1, N],
    jitter f32[B, T, N]).  Both the legacy near-idle trace and the true
    masked variant derive from exactly this state, so the same key gives
    the same on/off timeline either way."""
    k_state, k_flip, k_hot, k_noise = jax.random.split(key, 4)
    state0 = jax.random.bernoulli(k_state, 0.5, (batch, n))
    flips = jax.random.bernoulli(k_flip, p_flip, (iters, batch, n))
    # parity of the running flip count toggles the initial state
    parity = jnp.cumsum(flips.astype(jnp.int32), axis=0) % 2
    on = (state0[None] ^ (parity == 1)).transpose(1, 0, 2)
    level = jax.random.uniform(k_hot, (batch, 1, n), minval=0.5,
                               maxval=1.5) * hot * capacity
    jitter = 1.0 + jax.random.uniform(k_noise, (batch, iters, n),
                                      minval=-1.0, maxval=1.0) * noise
    return on, level, jitter


def churn(key: jax.Array, batch: int, iters: int, n: int, *,
          capacity: float = 1.0, p_flip: float = 0.02, hot: float = 0.5,
          idle: float = 0.01, noise: float = 0.05) -> jax.Array:
    """Consumer churn: partitions toggle between a hot rate and near-idle at
    random flip times (topics created / abandoned mid-stream).

    This is the legacy unmasked degradation: a dead topic is faked as a
    near-idle speed ``idle * capacity`` because a plain speed array cannot
    say "absent".  ``churn_masked`` emits the honest form."""
    on, level, jitter = _churn_state(key, batch, iters, n, capacity=capacity,
                                     p_flip=p_flip, hot=hot, noise=noise)
    return jnp.maximum(jnp.where(on, level, idle * capacity) * jitter, 0.0)


def churn_masked(key: jax.Array, batch: int, iters: int, n: int, *,
                 capacity: float = 1.0, p_flip: float = 0.02,
                 hot: float = 0.5, noise: float = 0.05
                 ) -> Tuple[jax.Array, jax.Array]:
    """True-mask churn: the same on/off timeline as ``churn`` (same key =>
    same flips), but an off partition is *absent* -- speed exactly 0 and
    ``active False`` -- instead of near-idle."""
    on, level, jitter = _churn_state(key, batch, iters, n, capacity=capacity,
                                     p_flip=p_flip, hot=hot, noise=noise)
    speeds = jnp.maximum(jnp.where(on, level * jitter, 0.0), 0.0)
    return speeds, on


def heavy_tail(key: jax.Array, batch: int, iters: int, n: int, *,
               capacity: float = 1.0, sigma: float = 1.2, scale: float = 0.1,
               noise: float = 0.1) -> jax.Array:
    """Heavy-tailed skew: log-normal per-partition base rates (a few whales
    dominate) with multiplicative log-space noise over time."""
    k_base, k_noise = jax.random.split(key)
    log_base = jax.random.normal(k_base, (batch, 1, n)) * sigma
    base = jnp.exp(log_base) * scale * capacity
    # wob starts at 0 (zero init), so exp(wob) anchors iteration 0 at base
    wob = _walk(k_noise, batch, iters, n, noise, jnp.zeros((batch, n)))
    return base * jnp.exp(wob)


def topic_lifecycle_masked(key: jax.Array, batch: int, iters: int, n: int, *,
                           capacity: float = 1.0, p_alive0: float = 0.5,
                           min_life_frac: float = 0.15, hot: float = 0.5,
                           noise: float = 0.1
                           ) -> Tuple[jax.Array, jax.Array]:
    """Partition births and deaths at random times (true masks).

    Each partition gets one lifetime window ``[birth, death)``: with
    probability ``p_alive0`` it exists from iteration 0, otherwise it is
    born at a uniform random step (possibly past the end of the trace --
    a topic that never appears).  Lifetimes are uniform in
    ``[min_life_frac, 1] * iters``, so early-born partitions tend to die
    mid-stream and late births survive to the end.  While alive, a
    partition produces at a random hot level with walk noise; outside its
    window it is absent (speed 0, ``active False``).
    """
    mlf = _concrete_float(min_life_frac)
    if mlf is not None and mlf < 0.0:
        # a negative minimum lifetime lets ``death = birth + life`` land
        # before the birth step -- the empty-window bug _check_lifecycle_
        # window names; reject it at the same choke point
        raise ValueError(
            f"lifecycle window is empty: death precedes birth "
            f"(min_life_frac={mlf!r} < 0 allows a negative lifetime, so a "
            f"partition's death step may precede its birth step); "
            f"min_life_frac must be >= 0")
    k_alive0, k_birth, k_life, k_level, k_noise = jax.random.split(key, 5)
    alive0 = jax.random.bernoulli(k_alive0, p_alive0, (batch, n))
    birth = jax.random.uniform(k_birth, (batch, n), maxval=float(iters))
    birth = jnp.where(alive0, 0.0, birth)
    life = jax.random.uniform(k_life, (batch, n),
                              minval=min_life_frac * iters,
                              maxval=float(iters))
    death = birth + life
    t = jnp.arange(iters, dtype=jnp.float32)[None, :, None]
    active = (t >= birth[:, None, :]) & (t < death[:, None, :])
    level = jax.random.uniform(k_level, (batch, 1, n), minval=0.3,
                               maxval=1.5) * hot * capacity
    drift = _walk(k_noise, batch, iters, n, noise * capacity,
                  jnp.zeros((batch, n)))
    speeds = jnp.where(active, jnp.maximum(level + drift, 0.0), 0.0)
    return speeds, active


def topic_lifecycle(key: jax.Array, batch: int, iters: int, n: int, *,
                    capacity: float = 1.0, p_alive0: float = 0.5,
                    min_life_frac: float = 0.15, hot: float = 0.5,
                    noise: float = 0.1) -> jax.Array:
    """Legacy unmasked view of ``topic_lifecycle_masked``: a partition
    outside its lifetime window shows speed 0 (absence degraded to
    idleness, like ``churn``'s near-idle fake)."""
    speeds, _ = topic_lifecycle_masked(
        key, batch, iters, n, capacity=capacity, p_alive0=p_alive0,
        min_life_frac=min_life_frac, hot=hot, noise=noise)
    return speeds


def adversarial_masked(key: jax.Array, batch: int, iters: int, n: int, *,
                       capacity: float = 1.0, base_rate: float = 0.2,
                       tail_sigma: float = 1.0,
                       burst_start_frac: float = 0.4,
                       burst_len_frac: float = 0.25, burst_amp: float = 1.5,
                       churn_p: float = 0.0, lifecycle_frac: float = 0.0,
                       birth_frac: float = 0.0, death_frac: float = 1.0,
                       noise: float = 0.05) -> Tuple[jax.Array, jax.Array]:
    """The genome-parameterized composite family the adversarial search
    evolves (``repro.scenarios``): every knob an attack can turn, in one
    generator.

    * heavy-tailed per-partition skew: log-normal weights with index
      ``tail_sigma``, mean-normalized so ``base_rate`` stays the fleet
      average (the Kafka benchmark paper's partition imbalance);
    * a timed *burst plateau*: rates step up by ``burst_amp * capacity``
      over ``[burst_start_frac, burst_start_frac + burst_len_frac) *
      iters`` -- the sustained-ingest plateau of arXiv 2003.06452, with
      the search choosing when it lands and how hard it hits;
    * churn: partitions flip on/off at rate ``churn_p`` (true masks);
    * lifecycle windows: a ``lifecycle_frac`` fraction of partitions
      exists only during ``[birth_frac, death_frac) * iters``.  An empty
      window (death before birth) raises a named ``ValueError`` for
      host-side knobs; traced knobs are clamped to ``death >= birth``.

    Per-partition rates clamp to ``capacity``: the paper's feasibility
    assumption is that one consumer can drain any single partition, so
    an adversary must do damage through burst *timing*, skew, churn and
    lifecycle pressure -- an unconsumable partition would make every
    policy score ``violation_frac == 1`` and the search landscape flat.
    """
    _check_lifecycle_window(birth_frac, death_frac,
                            birth_name="birth_frac", death_name="death_frac")
    k_tail, k_churn, k_state, k_sel, k_noise = jax.random.split(key, 5)
    w = jnp.exp(jax.random.normal(k_tail, (batch, 1, n)) * tail_sigma)
    w = w / jnp.mean(w, axis=2, keepdims=True)
    t = jnp.arange(iters, dtype=jnp.float32)[None, :, None]
    f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731 (traced-safe)
    start = f32(burst_start_frac) * iters
    stop = start + f32(burst_len_frac) * iters
    plateau = ((t >= start) & (t < stop)).astype(jnp.float32)
    level = (f32(base_rate) + f32(burst_amp) * plateau) * capacity * w
    jitter = 1.0 + jax.random.uniform(k_noise, (batch, iters, n),
                                      minval=-1.0, maxval=1.0) * noise
    # churn on/off timeline (same parity machinery as ``churn``)
    state0 = jax.random.bernoulli(k_state, 0.9, (batch, n))
    flips = jax.random.bernoulli(k_churn, churn_p, (iters, batch, n))
    parity = jnp.cumsum(flips.astype(jnp.int32), axis=0) % 2
    on = (state0[None] ^ (parity == 1)).transpose(1, 0, 2)
    # lifecycle window on a lifecycle_frac subset; traced knobs cannot
    # raise, so the clamp enforces death >= birth under the search
    subject = jax.random.uniform(k_sel, (batch, 1, n)) < lifecycle_frac
    birth = f32(birth_frac) * iters
    death = jnp.maximum(f32(death_frac), f32(birth_frac)) * iters
    in_window = (t >= birth) & (t < death)
    alive = jnp.where(subject, in_window, True)
    active = on & alive
    speeds = jnp.clip(level * jitter, 0.0, capacity)
    speeds = jnp.where(active, speeds, 0.0)
    return speeds, active


def adversarial(key: jax.Array, batch: int, iters: int, n: int, *,
                capacity: float = 1.0, **knobs) -> jax.Array:
    """Legacy unmasked view of ``adversarial_masked`` (absence degraded
    to speed 0, like ``topic_lifecycle``)."""
    speeds, _ = adversarial_masked(key, batch, iters, n, capacity=capacity,
                                   **knobs)
    return speeds


ScenarioFn = Callable[..., jax.Array]
#: masked generators return (speeds f32[B, T, N], active bool[B, T, N])
MaskedScenarioFn = Callable[..., Tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class KnobSpec:
    """One genome-searchable knob of a scenario family: closed bounds
    ``[lo, hi]`` an adversarial search may explore, plus the generator's
    default.  Bounds are the *search space*, not hard limits -- direct
    ``generate_*`` calls may pass any value the generator accepts."""

    name: str
    lo: float
    hi: float
    default: float

    def __post_init__(self) -> None:
        if not self.lo <= self.default <= self.hi:
            raise ValueError(
                f"knob {self.name!r}: default {self.default!r} outside "
                f"bounds [{self.lo!r}, {self.hi!r}]")


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """One registered scenario family: its unmasked and masked
    generators, the knobs a genome may turn (:class:`KnobSpec` order =
    genome vector order), and ``ordered`` pairs ``(lo_knob, hi_knob)``
    whose values must satisfy ``lo <= hi`` (a search *repairs* them; a
    host-side call with the order violated raises, see
    ``_check_lifecycle_window``)."""

    name: str
    fn: ScenarioFn
    masked_fn: MaskedScenarioFn
    knobs: Tuple[KnobSpec, ...] = ()
    ordered: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self) -> None:
        names = {k.name for k in self.knobs}
        for lo, hi in self.ordered:
            if lo not in names or hi not in names:
                raise ValueError(
                    f"family {self.name!r}: ordered pair ({lo!r}, {hi!r}) "
                    f"names unregistered knobs; have {sorted(names)}")

    @property
    def knob_names(self) -> Tuple[str, ...]:
        return tuple(k.name for k in self.knobs)


#: the family registry, in registration order (genome machinery and the
#: plain name->generator views below all derive from it)
FAMILY_SPECS: Dict[str, FamilySpec] = {}
SCENARIO_FAMILIES: Dict[str, ScenarioFn] = {}
MASKED_SCENARIO_FAMILIES: Dict[str, MaskedScenarioFn] = {}


def _all_active(fn: ScenarioFn) -> MaskedScenarioFn:
    """Lift an always-on family into the masked contract."""
    def gen(key, batch, iters, n, **kw):
        speeds = fn(key, batch, iters, n, **kw)
        return speeds, jnp.ones(speeds.shape, bool)
    return gen


def register_family(name: str, fn: ScenarioFn, *,
                    masked_fn: Optional[MaskedScenarioFn] = None,
                    knobs: Sequence[KnobSpec] = (),
                    ordered: Sequence[Tuple[str, str]] = ()) -> FamilySpec:
    """Register a scenario family (the extension point scenario sources
    and the adversarial search share).  ``masked_fn=None`` lifts ``fn``
    into the masked contract with an all-``True`` mask."""
    if name in FAMILY_SPECS:
        raise ValueError(f"scenario family {name!r} already registered")
    spec = FamilySpec(name=name, fn=fn,
                      masked_fn=(masked_fn if masked_fn is not None
                                 else _all_active(fn)),
                      knobs=tuple(knobs), ordered=tuple(ordered))
    FAMILY_SPECS[name] = spec
    SCENARIO_FAMILIES[name] = spec.fn
    MASKED_SCENARIO_FAMILIES[name] = spec.masked_fn
    return spec


def family_spec(name: str) -> FamilySpec:
    """The registered :class:`FamilySpec` of ``name`` (named error)."""
    if name not in FAMILY_SPECS:
        raise ValueError(f"unknown scenario family {name!r}; "
                         f"have {sorted(FAMILY_SPECS)}")
    return FAMILY_SPECS[name]


K = KnobSpec
register_family("random_walk", random_walk,
                knobs=(K("delta", 1.0, 40.0, 10.0),))
register_family("diurnal", diurnal, knobs=(
    K("period", 16.0, 192.0, 96.0), K("amplitude", 0.0, 1.0, 0.4),
    K("noise", 0.0, 0.1, 0.02)))
register_family("ramp", ramp, knobs=(
    K("max_slope", 0.0, 3.0, 1.5), K("noise", 0.0, 0.1, 0.02)))
register_family("bursty", bursty, knobs=(
    K("base", 0.0, 0.5, 0.15), K("p_spike", 0.0, 0.2, 0.02),
    K("spike", 0.0, 4.0, 1.0), K("decay", 0.5, 0.99, 0.8)))
register_family("churn", churn, masked_fn=churn_masked, knobs=(
    K("p_flip", 0.0, 0.2, 0.02), K("hot", 0.0, 1.5, 0.5),
    K("noise", 0.0, 0.2, 0.05)))
register_family("heavy_tail", heavy_tail, knobs=(
    K("sigma", 0.0, 2.5, 1.2), K("scale", 0.01, 0.5, 0.1),
    K("noise", 0.0, 0.3, 0.1)))
register_family("topic_lifecycle", topic_lifecycle,
                masked_fn=topic_lifecycle_masked, knobs=(
                    K("p_alive0", 0.0, 1.0, 0.5),
                    K("min_life_frac", 0.05, 1.0, 0.15),
                    K("hot", 0.0, 1.5, 0.5), K("noise", 0.0, 0.3, 0.1)))
register_family("adversarial", adversarial, masked_fn=adversarial_masked,
                knobs=(
                    K("base_rate", 0.05, 1.0, 0.2),
                    K("tail_sigma", 0.0, 2.5, 1.0),
                    K("burst_start_frac", 0.0, 0.9, 0.4),
                    K("burst_len_frac", 0.05, 0.6, 0.25),
                    K("burst_amp", 0.0, 4.0, 1.5),
                    K("churn_p", 0.0, 0.15, 0.0),
                    K("lifecycle_frac", 0.0, 1.0, 0.0),
                    K("birth_frac", 0.0, 1.0, 0.0),
                    K("death_frac", 0.0, 1.0, 1.0),
                    K("noise", 0.0, 0.2, 0.05)),
                ordered=(("birth_frac", "death_frac"),))
del K


@functools.partial(jax.jit, static_argnames=("family", "batch", "iters", "n"))
def _generate(family: str, key: jax.Array, batch: int, iters: int, n: int,
              capacity: float) -> jax.Array:
    return SCENARIO_FAMILIES[family](key, batch, iters, n, capacity=capacity)


def generate_scenario(family: str, key: jax.Array, batch: int, iters: int,
                      n: int, *, capacity: float = 1.0,
                      **knobs) -> jax.Array:
    """Generate one family's batch of traces as ``f32[batch, iters, n]``.

    Deterministic: the same ``key`` (and knobs) always yields the same batch.
    Extra ``knobs`` are forwarded to the family generator (see each family's
    signature; e.g. ``delta=`` for random_walk, ``period=`` for diurnal).
    """
    if family not in SCENARIO_FAMILIES:
        raise ValueError(
            f"unknown scenario family {family!r}; "
            f"have {sorted(SCENARIO_FAMILIES)}")
    if knobs:
        out = SCENARIO_FAMILIES[family](key, batch, iters, n,
                                        capacity=capacity, **knobs)
    else:
        out = _generate(family, key, batch, iters, n, capacity)
    return out.astype(jnp.float32)


def generate_masked_scenario(family: str, key: jax.Array, batch: int,
                             iters: int, n: int, *, capacity: float = 1.0,
                             **knobs) -> Tuple[jax.Array, jax.Array]:
    """Generate one family's batch under the masked contract:
    ``(speeds f32[B, T, N], active bool[B, T, N])``.

    Deterministic under a fixed key like ``generate_scenario``; for the
    true-mask families (``churn``, ``topic_lifecycle``) the same key
    yields the same on/off timeline as the legacy unmasked generator.
    """
    if family not in MASKED_SCENARIO_FAMILIES:
        raise ValueError(
            f"unknown scenario family {family!r}; "
            f"have {sorted(MASKED_SCENARIO_FAMILIES)}")
    speeds, active = MASKED_SCENARIO_FAMILIES[family](
        key, batch, iters, n, capacity=capacity, **knobs)
    return speeds.astype(jnp.float32), active.astype(bool)


def scenario_suite(key: jax.Array, batch: int, iters: int, n: int, *,
                   capacity: float = 1.0,
                   families: Sequence[str] = tuple(SCENARIO_FAMILIES),
                   ) -> Dict[str, jax.Array]:
    """One batch per family, independently keyed: {family: f32[B, T, N]}."""
    keys = jax.random.split(key, len(families))
    return {f: generate_scenario(f, k, batch, iters, n, capacity=capacity)
            for f, k in zip(families, keys)}


def masked_scenario_suite(key: jax.Array, batch: int, iters: int, n: int, *,
                          capacity: float = 1.0,
                          families: Sequence[str] = tuple(
                              MASKED_SCENARIO_FAMILIES),
                          ) -> Dict[str, Tuple[jax.Array, jax.Array]]:
    """Masked twin of ``scenario_suite``: {family: (speeds, active)}.

    Keyed exactly like ``scenario_suite`` (same split per family
    position), so a family's speeds match between the two suites wherever
    the legacy generator and the masked one share their randomness.
    """
    keys = jax.random.split(key, len(families))
    return {f: generate_masked_scenario(f, k, batch, iters, n,
                                        capacity=capacity)
            for f, k in zip(families, keys)}


def stack_suite(suite: Dict[str, jax.Array]
                ) -> Tuple[Tuple[str, ...], jax.Array]:
    """Flatten a suite into (labels[B_total], f32[B_total, T, N]) for one
    sweep_streams call; labels[i] names trace i's family."""
    labels = tuple(f for f, v in suite.items() for _ in range(v.shape[0]))
    return labels, jnp.concatenate(list(suite.values()), axis=0)


def stack_masked_suite(suite: Dict[str, Tuple[jax.Array, jax.Array]]
                       ) -> Tuple[Tuple[str, ...], jax.Array, jax.Array]:
    """Flatten a masked suite into (labels[B_total], speeds f32[B_total,
    T, N], active bool[B_total, T, N]) for one masked sweep call."""
    labels = tuple(f for f, (v, _) in suite.items()
                   for _ in range(v.shape[0]))
    speeds = jnp.concatenate([v for v, _ in suite.values()], axis=0)
    active = jnp.concatenate([a for _, a in suite.values()], axis=0)
    return labels, speeds, active
