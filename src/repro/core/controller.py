"""Controller (paper Sec. V-C): orchestrates the consumer group.

State machine (Fig. 5):  SENTINEL -> REASSIGN -> GROUP_MANAGEMENT -> SENTINEL,
with SYNCHRONIZE on start-up / recovery.

* SENTINEL        -- ingest monitor measurements + consumer acks/heartbeats,
                     detect dead consumers, evaluate the exit conditions.
* REASSIGN        -- run the configured bin-packing algorithm on the current
                     write speeds given the current assignment.
* GROUP_MANAGEMENT-- compute the state diff (consumers to create, partitions
                     to stop/start per consumer, consumers to decommission)
                     and drive the **two-phase synchronous migration**: a
                     partition's `start` is only sent after the previous
                     owner's `stop` is acknowledged, so at most one consumer
                     of the group ever reads a partition (broker enforces it).
* SYNCHRONIZE     -- reconcile perceived state with the consumers' persisted
                     state (crash recovery).

Communication (Fig. 3): topic ``consumer.metadata``; partition 0 is the
controller inbox, partition N+1 is consumer N's mailbox -- every byte a
consumer reads is relevant to it (the paper's "efficient communication
model").
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Callable, Dict, List, Mapping, Optional, Set, Tuple

from repro.broker import Broker, TopicPartition

from repro.registry import PACKER_FAMILIES, list_policies, packer_for

from .assignment import ConsumerId, PackResult, group_view, rebalanced_partitions
from .rscore import rscore_of_set

METADATA_TOPIC = "consumer.metadata"
CONTROLLER_PARTITION = 0


def consumer_mailbox(cid: ConsumerId) -> TopicPartition:
    return TopicPartition(METADATA_TOPIC, int(cid) + 1)


CONTROLLER_INBOX = TopicPartition(METADATA_TOPIC, CONTROLLER_PARTITION)


def _tp_key(tp: TopicPartition) -> List:
    return [tp.topic, tp.partition]


def _tp_from(raw) -> TopicPartition:
    return TopicPartition(raw[0], int(raw[1]))


class ControllerState(enum.Enum):
    SYNCHRONIZE = "synchronize"
    SENTINEL = "sentinel"
    REASSIGN = "reassign"
    GROUP_MANAGEMENT = "group_management"


@dataclasses.dataclass
class StateDiff:
    """Difference between current and desired group state (Sec. V-C)."""

    to_create: List[ConsumerId]
    to_stop: Dict[ConsumerId, List[TopicPartition]]
    to_start: Dict[ConsumerId, List[TopicPartition]]
    to_delete: List[ConsumerId]

    @property
    def is_empty(self) -> bool:
        return not (self.to_create or self.to_stop or self.to_start or self.to_delete)


def state_diff(
    current: Mapping[TopicPartition, ConsumerId],
    desired: Mapping[TopicPartition, ConsumerId],
    live_consumers: Set[ConsumerId],
) -> StateDiff:
    to_create = sorted({c for c in desired.values() if c not in live_consumers})
    to_stop: Dict[ConsumerId, List[TopicPartition]] = {}
    to_start: Dict[ConsumerId, List[TopicPartition]] = {}
    for tp, new_c in desired.items():
        old_c = current.get(tp)
        if old_c == new_c:
            continue
        if old_c is not None:
            to_stop.setdefault(old_c, []).append(tp)
        to_start.setdefault(new_c, []).append(tp)
    keep = set(desired.values())
    to_delete = sorted(c for c in live_consumers if c not in keep)
    for d in (to_stop, to_start):
        for v in d.values():
            v.sort()
    return StateDiff(to_create, to_stop, to_start, to_delete)


@dataclasses.dataclass
class MigrationRecord:
    """Bookkeeping of one reassignment for Rscore accounting / tests."""

    iteration: int
    started_at: float
    rscore: float
    moved: Set[TopicPartition]
    n_bins: int
    finished_at: Optional[float] = None

    @property
    def duration(self) -> Optional[float]:
        return None if self.finished_at is None else self.finished_at - self.started_at


class ReplicaManagerProtocol:
    """Replica lifecycle (the paper's Kubernetes deployments)."""

    def create(self, cid: ConsumerId) -> None:
        raise NotImplementedError

    def delete(self, cid: ConsumerId) -> None:
        raise NotImplementedError

    def list(self) -> Set[ConsumerId]:
        raise NotImplementedError


@dataclasses.dataclass
class ControllerConfig:
    capacity: float
    algorithm: str = "MBFP"             # paper's best modified variant
    overload_factor: float = 1.0        # consumer load > f*C triggers repack
    scaledown_margin: int = 1           # repack if packer saves >= margin bins
    heartbeat_timeout: float = 60.0
    min_reassign_interval: float = 0.0  # cool-down between repacks
    group: str = "autoscaler"


class Controller:
    def __init__(self, broker: Broker, manager: ReplicaManagerProtocol,
                 config: ControllerConfig):
        self.broker = broker
        self.manager = manager
        self.cfg = config
        if config.algorithm not in list_policies(family=PACKER_FAMILIES,
                                                 backend="py"):
            raise ValueError(f"unknown algorithm {config.algorithm!r}")
        self.algorithm: Callable = packer_for(config.algorithm, backend="py")
        broker.create_topic(METADATA_TOPIC, 1)

        self.state = ControllerState.SYNCHRONIZE
        self.assignment: Dict[TopicPartition, ConsumerId] = {}   # perceived
        self.live: Set[ConsumerId] = set()
        self.speeds: Dict[TopicPartition, float] = {}
        self.last_heartbeat: Dict[ConsumerId, float] = {}
        self.replica_stats: Dict[ConsumerId, dict] = {}
        self.draining: Set[ConsumerId] = set()
        self.iteration = 0
        self.last_reassign_at = -1e18
        self.migrations: List[MigrationRecord] = []
        # in-flight two-phase migration: tp -> ("stop_sent"|"start_sent", old, new)
        self._inflight: Dict[TopicPartition, Tuple[str, Optional[ConsumerId], ConsumerId]] = {}
        self._pending_delete: Set[ConsumerId] = set()
        self._sync_waiting: Set[ConsumerId] = set()

    # ------------------------------------------------------------------ util
    def _send(self, cid: ConsumerId, msg: dict) -> None:
        raw = json.dumps(msg)
        self.broker.produce(consumer_mailbox(cid), raw, nbytes=len(raw))

    def _drain_inbox(self) -> List[dict]:
        part = self.broker.partition(CONTROLLER_INBOX)
        off = self.broker.committed(self.cfg.group, CONTROLLER_INBOX)
        recs = part.read(off)
        if recs:
            self.broker.commit(self.cfg.group, CONTROLLER_INBOX, recs[-1].offset + 1)
        return [json.loads(r.value) for r in recs]

    # -------------------------------------------------------------- sentinel
    def observe_measurement(self, speeds: Mapping[TopicPartition, float]) -> None:
        self.speeds = dict(speeds)

    def _process_inbox(self) -> None:
        now = self.broker.clock.now()
        for msg in self._drain_inbox():
            cid = int(msg["consumer"])
            typ = msg["type"]
            self.last_heartbeat[cid] = now
            if typ == "heartbeat":
                if "stats" in msg:
                    self.replica_stats[cid] = msg["stats"]
                continue
            if typ == "state_report":
                self._sync_waiting.discard(cid)
                self.live.add(cid)
                for raw in msg["partitions"]:
                    self.assignment[_tp_from(raw)] = cid
            elif typ == "stopped":
                for raw in msg["partitions"]:
                    tp = _tp_from(raw)
                    ent = self._inflight.get(tp)
                    if ent and ent[0] == "stop_sent":
                        _, old, new = ent
                        self._send(new, {"type": "start", "partitions": [_tp_key(tp)]})
                        self._inflight[tp] = ("start_sent", old, new)
                    if self.assignment.get(tp) == cid:
                        del self.assignment[tp]
            elif typ == "started":
                for raw in msg["partitions"]:
                    tp = _tp_from(raw)
                    ent = self._inflight.pop(tp, None)
                    self.assignment[tp] = cid
            elif typ == "shutdown_ack":
                self.live.discard(cid)
                self._pending_delete.discard(cid)
                self.manager.delete(cid)

    def _detect_failures(self) -> Set[ConsumerId]:
        now = self.broker.clock.now()
        dead = {c for c in self.live
                if now - self.last_heartbeat.get(c, now) > self.cfg.heartbeat_timeout}
        for c in dead:
            # Kafka group-coordinator semantics: expel the member, freeing its
            # partitions; its decode/read state is rebuilt from committed
            # offsets by whoever inherits the partitions.
            self.broker.expel(self.cfg.group, f"consumer-{c}")
            self.live.discard(c)
            self.manager.delete(c)
            for tp, cid in list(self.assignment.items()):
                if cid == c:
                    del self.assignment[tp]
            # abort in-flight migrations touching the dead consumer
            for tp, (phase, old, new) in list(self._inflight.items()):
                if old == c or new == c:
                    del self._inflight[tp]
        return dead

    def _loads(self) -> Dict[ConsumerId, float]:
        loads: Dict[ConsumerId, float] = {c: 0.0 for c in self.live}
        for tp, cid in self.assignment.items():
            loads[cid] = loads.get(cid, 0.0) + self.speeds.get(tp, 0.0)
        return loads

    def _should_reassign(self) -> bool:
        if self._inflight:
            return False                      # finish the current migration first
        now = self.broker.clock.now()
        if now - self.last_reassign_at < self.cfg.min_reassign_interval:
            return False
        if not self.speeds:
            return False
        unassigned = [tp for tp in self.speeds if tp not in self.assignment]
        if unassigned:
            return True
        if self.draining & set(self.assignment.values()):
            return True
        loads = self._loads()
        if any(l > self.cfg.overload_factor * self.cfg.capacity for l in loads.values()):
            return True
        # scale-down check: would the packer save >= margin bins?
        res = self._pack()
        return res.n_bins <= len([c for c in self.live if c not in self.draining]) \
            - self.cfg.scaledown_margin

    # -------------------------------------------------------------- reassign
    def _pack(self) -> PackResult:
        prev = {tp: c for tp, c in self.assignment.items() if c not in self.draining}
        res = self.algorithm(dict(self.speeds), self.cfg.capacity, prev=prev)
        return self._remap_draining(res)

    def _remap_draining(self, desired: PackResult) -> PackResult:
        """A draining (straggler) consumer must never be reused as a bin:
        rename colliding bins to fresh ids so the drained replica ends up
        with no assignment and is decommissioned."""
        bad = set(desired.pid_to_bin.values()) & self.draining
        if not bad:
            return desired
        used = set(desired.pid_to_bin.values()) | self.live | self.draining
        mapping: Dict[ConsumerId, ConsumerId] = {}
        nxt = 0
        for b in sorted(bad):
            while nxt in used:
                nxt += 1
            mapping[b] = nxt
            used.add(nxt)
        remap = lambda c: mapping.get(c, c)
        return PackResult(
            pid_to_bin={tp: remap(c) for tp, c in desired.pid_to_bin.items()},
            loads={remap(c): l for c, l in desired.loads.items()},
            creation_order=[remap(c) for c in desired.creation_order],
        )

    # ------------------------------------------------------------- lifecycle
    def run_once(self) -> ControllerState:
        """One controller step; returns the state it finished in."""
        self._process_inbox()

        if self.state == ControllerState.SYNCHRONIZE:
            if not self._sync_waiting:
                discovered = self.manager.list()
                if discovered - self.live:
                    self._sync_waiting = set(discovered - self.live)
                    for cid in self._sync_waiting:
                        self._send(cid, {"type": "report_state"})
                    return self.state
                self.state = ControllerState.SENTINEL
            return self.state

        self._detect_failures()

        if self.state == ControllerState.SENTINEL:
            if self._inflight:
                self._finish_migration_if_done()
                return self.state
            if self._should_reassign():
                self.state = ControllerState.REASSIGN
            else:
                return self.state

        if self.state == ControllerState.REASSIGN:
            desired = self._pack()
            self.state = ControllerState.GROUP_MANAGEMENT
            self._apply(desired)
            return self.state

        if self.state == ControllerState.GROUP_MANAGEMENT:
            self._finish_migration_if_done()
            return self.state

        return self.state

    def _apply(self, desired: PackResult) -> None:
        now = self.broker.clock.now()
        diff = state_diff(self.assignment, desired.pid_to_bin, self.live)
        moved = rebalanced_partitions(self.assignment, desired.pid_to_bin)
        self.iteration += 1
        self.migrations.append(MigrationRecord(
            iteration=self.iteration, started_at=now,
            rscore=rscore_of_set(moved, self.speeds, self.cfg.capacity),
            moved=set(moved), n_bins=desired.n_bins))
        self.last_reassign_at = now

        # 1. create new consumer instances (deployment name == mailbox id)
        for cid in diff.to_create:
            self.manager.create(cid)
            self.live.add(cid)
            self.last_heartbeat[cid] = now
        # 2. two-phase migration: stop first; start goes out on stop-ack.
        for tp, new_c in desired.pid_to_bin.items():
            old_c = self.assignment.get(tp)
            if old_c == new_c:
                continue
            if old_c is None or old_c not in self.live:
                # fresh partition (or owner died): start immediately
                self._send(new_c, {"type": "start", "partitions": [_tp_key(tp)]})
                self._inflight[tp] = ("start_sent", None, new_c)
            else:
                self._send(old_c, {"type": "stop", "partitions": [_tp_key(tp)]})
                self._inflight[tp] = ("stop_sent", old_c, new_c)
        # 3. consumers with no assignment are decommissioned once idle
        self._pending_delete |= set(diff.to_delete)
        self.draining -= set(diff.to_delete)
        self.state = ControllerState.GROUP_MANAGEMENT
        self._finish_migration_if_done()

    def _finish_migration_if_done(self) -> None:
        if self._inflight:
            return
        now = self.broker.clock.now()
        if self.migrations and self.migrations[-1].finished_at is None:
            self.migrations[-1].finished_at = now
        for cid in sorted(self._pending_delete):
            if not any(c == cid for c in self.assignment.values()):
                self._send(cid, {"type": "shutdown"})
        self.state = ControllerState.SENTINEL

    # ------------------------------------------------------------ extensions
    def drain(self, cid: ConsumerId) -> None:
        """Straggler mitigation: schedule ``cid`` for repack-away + removal."""
        self.draining.add(cid)

    def check_stragglers(self, rate_threshold: float = 0.5) -> Set[ConsumerId]:
        """Drain replicas whose achieved rate stays below
        ``rate_threshold * C`` while they still have backlog -- i.e. they are
        saturated but underperforming the calibrated capacity (extension of
        the paper's constant-capacity load model)."""
        found = set()
        for cid, stats in self.replica_stats.items():
            if cid not in self.live or cid in self.draining:
                continue
            if stats.get("backlog", 0) > 0 and \
                    stats.get("rate", 0.0) < rate_threshold * self.cfg.capacity:
                self.drain(cid)
                found.add(cid)
        return found

    def persisted_state(self) -> str:
        return json.dumps({
            "assignment": [[_tp_key(tp), cid] for tp, cid in self.assignment.items()],
            "iteration": self.iteration,
        })

    @staticmethod
    def recover(broker: Broker, manager: ReplicaManagerProtocol,
                config: ControllerConfig) -> "Controller":
        """Fresh controller that rebuilds its view via SYNCHRONIZE."""
        return Controller(broker, manager, config)
