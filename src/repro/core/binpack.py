"""Classical bin-packing approximation algorithms (paper Sec. II-B)
plus the rebalance-aware "sticky" adaptation of Sec. IV-C.

All algorithms share one packing engine; they differ only in

* the *fit strategy* used to select an open bin
  (``next`` / ``first`` / ``best`` / ``worst``), and
* whether the item list is pre-sorted in non-increasing order
  (the "Decreasing" offline variants).

Sticky adaptation (Sec. IV-C, quoted): "If the consumer that is currently
assigned to the partition has not yet been created in the future assignment,
this is the bin that is created, otherwise, the lowest index bin that does
not yet exist is the one created."  This never changes the number of bins an
algorithm uses -- it only renames newly created bins -- but it reduces the
Rscore because a partition whose bin keeps its old name was not migrated.

Oversized items (w > C, possible under the paper's stream model Eq. 11) can
never satisfy Eq. 6; they receive a dedicated bin that is allowed to
overflow.  Nothing else ever fits next to them (load already >= C), so the
remaining invariants are untouched.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .assignment import ConsumerId, PackResult, PartitionId

FIT_STRATEGIES = ("next", "first", "best", "worst")


class Bins:
    """Mutable list of open bins with the Sec. IV-C naming rule."""

    def __init__(
        self,
        capacity: float,
        prev: Optional[Mapping[PartitionId, ConsumerId]] = None,
        sticky: bool = True,
    ):
        self.capacity = float(capacity)
        self.prev = dict(prev or {})
        self.sticky = bool(sticky)
        self.loads: List[float] = []          # indexed by creation slot
        self.names: List[ConsumerId] = []     # slot -> bin name
        self._used_names: set = set()
        self.pid_to_bin: Dict[PartitionId, ConsumerId] = {}

    # -- naming ------------------------------------------------------------
    def _fresh_name(self, pid: PartitionId) -> ConsumerId:
        if self.sticky:
            c = self.prev.get(pid)
            if c is not None and c not in self._used_names:
                return c
        i = 0
        while i in self._used_names:
            i += 1
        return i

    # -- queries -----------------------------------------------------------
    def fits(self, slot: int, w: float) -> bool:
        return self.loads[slot] + w <= self.capacity

    def select_slot(self, w: float, strategy: str) -> Optional[int]:
        """Pick an open bin for an item of size ``w`` or None if nothing fits.

        Ties break toward the lowest creation slot (left-most bin), matching
        both the paper's list-of-bins semantics and ``argmin``/``argmax``
        first-occurrence semantics of the JAX implementation.
        """
        if strategy == "next":
            if self.loads and self.fits(len(self.loads) - 1, w):
                return len(self.loads) - 1
            return None
        best: Optional[int] = None
        for slot, load in enumerate(self.loads):
            if load + w > self.capacity:
                continue
            if strategy == "first":
                return slot
            if best is None:
                best = slot
            elif strategy == "best" and load > self.loads[best]:
                best = slot            # tightest fit = max load among fitting
            elif strategy == "worst" and load < self.loads[best]:
                best = slot            # most slack = min load among fitting
        return best

    # -- mutation ----------------------------------------------------------
    def place(self, slot: int, pid: PartitionId, w: float) -> None:
        self.loads[slot] += w
        self.pid_to_bin[pid] = self.names[slot]

    def create(self, pid: PartitionId, w: float, name: Optional[ConsumerId] = None) -> int:
        """Open a new bin (named per Sec. IV-C unless forced) holding ``pid``."""
        if name is None:
            name = self._fresh_name(pid)
        assert name not in self._used_names, f"bin name {name!r} already exists"
        slot = len(self.loads)
        self.loads.append(0.0)
        self.names.append(name)
        self._used_names.add(name)
        self.place(slot, pid, w)
        return slot

    def create_empty(self, name: ConsumerId) -> int:
        assert name not in self._used_names, f"bin name {name!r} already exists"
        slot = len(self.loads)
        self.loads.append(0.0)
        self.names.append(name)
        self._used_names.add(name)
        return slot

    def assign_any_fit(self, pid: PartitionId, w: float, strategy: str) -> int:
        """Any-fit insert: selected open bin, else a freshly created bin."""
        slot = self.select_slot(w, strategy)
        if slot is None:
            return self.create(pid, w)
        self.place(slot, pid, w)
        return slot

    def result(self) -> PackResult:
        return PackResult(
            pid_to_bin=dict(self.pid_to_bin),
            loads={self.names[s]: self.loads[s] for s in range(len(self.loads))},
            creation_order=list(self.names),
        )


def _as_items(items) -> List[Tuple[PartitionId, float]]:
    if isinstance(items, Mapping):
        return list(items.items())
    return [(pid, float(w)) for pid, w in items]


def pack(
    items,
    capacity: float,
    *,
    strategy: str = "first",
    decreasing: bool = False,
    prev: Optional[Mapping[PartitionId, ConsumerId]] = None,
    sticky: bool = True,
) -> PackResult:
    """Run one classical bin-packing pass.

    ``items`` -- mapping pid -> write speed, or sequence of (pid, speed).
    Sequence order is the online arrival order; ``decreasing=True`` applies
    the offline non-increasing pre-sort (stable, so equal speeds keep their
    arrival order).
    """
    if strategy not in FIT_STRATEGIES:
        raise ValueError(f"unknown fit strategy {strategy!r}")
    lst = _as_items(items)
    if decreasing:
        lst = sorted(lst, key=lambda kv: -kv[1])
    bins = Bins(capacity, prev=prev, sticky=sticky)
    for pid, w in lst:
        bins.assign_any_fit(pid, w, strategy)
    return bins.result()


# -- the paper's eight classical baselines ---------------------------------

def _make(strategy: str, decreasing: bool):
    def algo(speeds, capacity, prev=None, sticky: bool = True, unassigned=None):
        # `unassigned` accepted for signature compatibility with the modified
        # family (classical algorithms repack everything each iteration).
        return pack(speeds, capacity, strategy=strategy, decreasing=decreasing,
                    prev=prev, sticky=sticky)
    algo.__name__ = ("" if not decreasing else "") + strategy
    return algo


next_fit = _make("next", False)
next_fit_decreasing = _make("next", True)
first_fit = _make("first", False)
first_fit_decreasing = _make("first", True)
best_fit = _make("best", False)
best_fit_decreasing = _make("best", True)
worst_fit = _make("worst", False)
worst_fit_decreasing = _make("worst", True)

CLASSICAL = {
    "NF": next_fit,
    "NFD": next_fit_decreasing,
    "FF": first_fit,
    "FFD": first_fit_decreasing,
    "BF": best_fit,
    "BFD": best_fit_decreasing,
    "WF": worst_fit,
    "WFD": worst_fit_decreasing,
}
