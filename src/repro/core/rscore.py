"""Rscore -- the paper's rebalance-cost metric (Eq. 10).

    R_i = (1/C) * sum_{p in P_i} s(p)

where P_i is the set of partitions rebalanced in iteration i and s(p) the
partition's current write speed.  Units: consumer-iterations per second of
backlog accumulation while the hand-off is in flight; multiplied by the
wall-clock rebalance duration it bounds the number of full-throttle consumer
iterations needed to drain the backlog (paper Sec. IV-A).
"""
from __future__ import annotations

from typing import Container, Mapping, Optional, Set

from .assignment import ConsumerId, PartitionId, rebalanced_partitions


def rscore(
    prev: Mapping[PartitionId, ConsumerId],
    new: Mapping[PartitionId, ConsumerId],
    speeds: Mapping[PartitionId, float],
    capacity: float,
    *,
    missing: str = "zero",
    active: Optional[Container[PartitionId]] = None,
) -> float:
    """Eq. 10 between two assignments.

    ``active`` (optional): the set of partitions that currently exist.
    A partition outside it never counts as rebalanced -- a deleted topic's
    hand-off stalls nothing (its consumer simply stops reading), matching
    the masked array contract where dead partitions assign to ``-1``.
    """
    moved = rebalanced_partitions(prev, new)
    if active is not None:
        moved = {p for p in moved if p in active}
    return rscore_of_set(moved, speeds, capacity, missing=missing)


def rscore_of_set(
    moved: Set[PartitionId],
    speeds: Mapping[PartitionId, float],
    capacity: float,
    *,
    missing: str = "zero",
) -> float:
    """Eq. 10 over an explicit moved-set.

    ``missing`` fixes the contract for partitions in ``moved`` that have
    no entry in ``speeds``:

    * ``"zero"`` (default): count them as speed 0.0.  This is deliberate,
      not an accident of ``dict.get``: the monitor has no write-speed
      sample yet for a partition that appeared mid-iteration, and a
      never-measured partition has consumed nothing a hand-off could
      stall (its backlog-accumulation cost is genuinely unknown but
      bounded by ~one monitor window).
    * ``"raise"``: raise ``KeyError`` naming every uncovered partition --
      for callers (benchmarks, the oracle bridge) whose speed maps are
      supposed to be total, where a miss means a bookkeeping bug.
    """
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    if missing not in ("zero", "raise"):
        raise ValueError(
            f"missing must be 'zero' or 'raise', got {missing!r}")
    if missing == "raise":
        unknown = [p for p in moved if p not in speeds]
        if unknown:
            raise KeyError(
                f"no write-speed sample for rebalanced partitions "
                f"{sorted(unknown, key=repr)!r}; pass missing='zero' to "
                f"count them as 0 (the monitor-gap contract)")
    return float(sum(speeds.get(p, 0.0) for p in moved)) / float(capacity)


def recovery_iterations(r: float, rebalance_seconds: float) -> float:
    """Max consumer iterations to recover the backlog accumulated while
    rebalancing (Sec. IV-A: 'the combination of the time it took to rebalance
    ... and the Rscore')."""
    return r * rebalance_seconds
