"""Rscore -- the paper's rebalance-cost metric (Eq. 10).

    R_i = (1/C) * sum_{p in P_i} s(p)

where P_i is the set of partitions rebalanced in iteration i and s(p) the
partition's current write speed.  Units: consumer-iterations per second of
backlog accumulation while the hand-off is in flight; multiplied by the
wall-clock rebalance duration it bounds the number of full-throttle consumer
iterations needed to drain the backlog (paper Sec. IV-A).
"""
from __future__ import annotations

from typing import Mapping, Set

from .assignment import ConsumerId, PartitionId, rebalanced_partitions


def rscore(
    prev: Mapping[PartitionId, ConsumerId],
    new: Mapping[PartitionId, ConsumerId],
    speeds: Mapping[PartitionId, float],
    capacity: float,
) -> float:
    moved = rebalanced_partitions(prev, new)
    return rscore_of_set(moved, speeds, capacity)


def rscore_of_set(
    moved: Set[PartitionId],
    speeds: Mapping[PartitionId, float],
    capacity: float,
) -> float:
    if capacity <= 0:
        raise ValueError("capacity must be positive")
    return float(sum(speeds.get(p, 0.0) for p in moved)) / float(capacity)


def recovery_iterations(r: float, rebalance_seconds: float) -> float:
    """Max consumer iterations to recover the backlog accumulated while
    rebalancing (Sec. IV-A: 'the combination of the time it took to rebalance
    ... and the Rscore')."""
    return r * rebalance_seconds
