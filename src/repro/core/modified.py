"""Modified Any Fit algorithms (paper Sec. IV-B, Algorithm 1).

The four family members (Table II):

  MWF   worst-fit insert, consumers sorted by cumulative write speed
  MBF   best-fit insert,  consumers sorted by cumulative write speed
  MWFP  worst-fit insert, consumers sorted by max partition write speed
  MBFP  best-fit insert,  consumers sorted by max partition write speed

Faithful to Algorithm 1 line by line, including its break semantics:

* consumers are processed in sorted order (non-increasing key);
* per consumer, its partitions are sorted decreasing and tried
  **smallest -> biggest** against the bins already created for the next
  iteration (``assignOpenBin``; no bin creation) -- first failure breaks;
* if partitions remain, the consumer's *own* bin is created
  (``createConsumer(c)`` -- the bin keeps the consumer's name, which is what
  makes the family rebalance-frugal) and the remaining partitions are
  inserted **biggest -> smallest** -- first failure breaks, all leftovers
  (including smaller ones that might still have fit) join the unassigned set,
  exactly as the pseudocode's lines 18-25 state;
* finally the unassigned set is sorted decreasing and placed with the fit
  strategy, creating sticky-named bins on demand.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .assignment import ConsumerId, PackResult, PartitionId
from .binpack import Bins

SORT_KEYS = ("cumulative", "max_partition")


def _consumer_key(parts: Sequence[PartitionId], speeds: Mapping[PartitionId, float], key: str) -> float:
    vals = [speeds[p] for p in parts if p in speeds]
    if not vals:
        return 0.0
    return float(sum(vals)) if key == "cumulative" else float(max(vals))


def modified_any_fit(
    speeds: Mapping[PartitionId, float],
    capacity: float,
    group: Optional[Mapping[ConsumerId, Sequence[PartitionId]]] = None,
    *,
    fit: str = "best",
    sort_key: str = "cumulative",
    unassigned: Optional[Iterable[PartitionId]] = None,
) -> PackResult:
    """One iteration of Algorithm 1.

    ``group``      -- current consumer-group configuration C (consumer ->
                      partitions).  Partitions no longer present in ``speeds``
                      (deleted upstream) are dropped.
    ``unassigned`` -- currently unassigned partitions U (new partitions, or
                      partitions of failed consumers).  Defaults to every
                      partition in ``speeds`` not covered by ``group``.
    """
    if fit not in ("best", "worst"):
        raise ValueError(f"modified any fit requires 'best' or 'worst', got {fit!r}")
    if sort_key not in SORT_KEYS:
        raise ValueError(f"unknown sort key {sort_key!r}")
    group = {c: [p for p in parts if p in speeds] for c, parts in (group or {}).items()}

    covered = {p for parts in group.values() for p in parts}
    if unassigned is None:
        pending: List[PartitionId] = [p for p in speeds if p not in covered]
    else:
        pending = [p for p in unassigned if p in speeds and p not in covered]

    prev_map = {p: c for c, parts in group.items() for p in parts}
    bins = Bins(capacity, prev=prev_map, sticky=True)

    # line 2: S <- sort C on cumulative or max partition (non-increasing;
    # stable tie-break on consumer id for determinism)
    order = sorted(group, key=lambda c: (-_consumer_key(group[c], speeds, sort_key), c))

    for c in order:                                            # line 3
        pset = sorted(group[c], key=lambda p: -speeds[p])      # lines 4-5 (decreasing)
        if not pset:
            continue
        # lines 6-13: smallest -> biggest into already-created bins
        i = len(pset) - 1
        while i >= 0:
            p = pset[i]
            slot = bins.select_slot(speeds[p], fit)            # assignOpenBin
            if slot is None:
                break                                          # line 9-10
            bins.place(slot, p, speeds[p])
            pset.pop(i)                                        # line 12
            i -= 1
        if not pset:                                           # lines 14-16
            continue
        # line 17: createConsumer(c) -- the consumer's own bin, keeping its name
        own = bins.create_empty(c)
        # lines 18-24: biggest -> smallest into the own bin, break on failure.
        # Oversized exception (w > C, possible under Eq. 11 streams): an item
        # that can never satisfy Eq. 6 is allowed to occupy its own *empty*
        # bin -- otherwise it would bounce through U into a renamed bin and
        # register as a phantom migration every iteration.
        while pset:
            p = pset[0]
            ok = bins.fits(own, speeds[p]) or (
                bins.loads[own] == 0.0 and speeds[p] > bins.capacity)
            if not ok:
                break                                          # lines 20-21
            bins.place(own, p, speeds[p])
            pset.pop(0)                                        # line 23
        pending.extend(pset)                                   # line 25

    # lines 27-29: decreasing any-fit over the unassigned set
    pending.sort(key=lambda p: -speeds[p])
    for p in pending:
        bins.assign_any_fit(p, speeds[p], fit)

    return bins.result()


def _member(fit: str, sort_key: str):
    def algo(speeds, capacity, prev=None, sticky: bool = True, unassigned=None,
             group=None):
        if group is None and prev is not None:
            from .assignment import group_view
            group = group_view(prev)
        return modified_any_fit(speeds, capacity, group, fit=fit,
                                sort_key=sort_key, unassigned=unassigned)
    algo.__name__ = f"M{'B' if fit == 'best' else 'W'}F{'P' if sort_key == 'max_partition' else ''}"
    return algo


mwf = _member("worst", "cumulative")
mbf = _member("best", "cumulative")
mwfp = _member("worst", "max_partition")
mbfp = _member("best", "max_partition")

MODIFIED = {"MWF": mwf, "MBF": mbf, "MWFP": mwfp, "MBFP": mbfp}


def __getattr__(name: str):
    # deprecation shim: the combined name->callable table is now derived
    # from the registry (tests/test_registry.py pins the warning)
    if name == "ALL_ALGORITHMS":
        from repro.registry import PACKER_FAMILIES, list_policies, packer_for
        from repro.registry.compat import warn_deprecated

        warn_deprecated(__name__, "ALL_ALGORITHMS",
                        "repro.registry.packer_for(name, backend='py')")
        return {n: packer_for(n, backend="py")
                for n in list_policies(family=PACKER_FAMILIES, backend="py")}
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
