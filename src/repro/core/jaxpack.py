"""JAX implementations of the paper's packing algorithms.

Pure ``jax.lax`` control flow (scan over items, masked argmin/argmax over
bins), so a whole 500-iteration stream evaluation jit-compiles into a single
XLA program and the packer can run *inside* the controller's jitted decision
step on device.  Semantics (including tie-breaking and the Sec. IV-C sticky
naming rule) match ``binpack.py`` / ``modified.py`` bit-for-bit; the property
tests in ``tests/test_jaxpack.py`` enforce exact agreement.

Conventions
-----------
* ``speeds``: f32[n] item sizes.
* ``prev``:   i32[n] previous bin name per item, ``-1`` = unassigned.
* ``active``: optional bool[n] partition mask.  An inactive item -- a
  partition that does not currently exist (topic deleted, not yet
  created, or fleet padding) -- packs to ``NEG``, contributes no load,
  claims no bin name and never creates a bin.  ``active=None`` keeps
  the exact unmasked program, and an all-``True`` mask reproduces the
  unmasked pack bit-for-bit (tests/test_masking.py).
* bin *names* are ints in ``[0, 2n+1)``; ``-1`` never names a bin.
* returns ``PackedJax(bin_of: i32[n], loads: f32[M], names: i32[M], n_bins)``
  where slot ``s < n_bins`` holds ``loads[s]`` and is named ``names[s]``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedJax:
    bin_of: jax.Array   # i32[n]  bin name per item
    loads: jax.Array    # f32[M]  load per creation slot
    names: jax.Array    # i32[M]  name per creation slot
    n_bins: jax.Array   # i32[]   number of created bins


def _select_slot(loads, k, w, capacity, strategy: str):
    """Masked fit-strategy selection over created slots [0, k). Returns
    (slot, found)."""
    m = loads.shape[0]
    created = jnp.arange(m) < k
    fits = created & (loads + w <= capacity)
    if strategy == "next":
        last = jnp.maximum(k - 1, 0)
        ok = (k > 0) & fits[last]
        return last, ok
    if strategy == "first":
        return jnp.argmax(fits), fits.any()
    if strategy == "best":    # tightest fit = max load among fitting, first on tie
        score = jnp.where(fits, loads, -jnp.inf)
        return jnp.argmax(score), fits.any()
    if strategy == "worst":   # most slack = min load among fitting, first on tie
        score = jnp.where(fits, loads, jnp.inf)
        return jnp.argmin(score), fits.any()
    raise ValueError(f"unknown strategy {strategy!r}")


def _fresh_name(used, prev_name):
    """Sec. IV-C naming: the item's previous bin if still unused, else the
    lowest unused name."""
    lowest = jnp.argmin(used)                     # first False
    sticky_ok = (prev_name >= 0) & ~used[jnp.clip(prev_name, 0)]
    return jnp.where(sticky_ok, prev_name, lowest)


def _place_or_create(state, j, w, prev_name, capacity, strategy: str, sticky: bool):
    """Any-fit insert of item ``j``: selected open bin, else a new bin."""
    loads, names, used, k, bin_of = state
    slot, found = _select_slot(loads, k, w, capacity, strategy)
    name_new = _fresh_name(used, prev_name if sticky else jnp.int32(NEG))
    slot = jnp.where(found, slot, k)
    name = jnp.where(found, names[slot], name_new)
    loads = loads.at[slot].add(w)
    names = names.at[slot].set(name)
    used = used.at[name].set(True)
    k = jnp.where(found, k, k + 1)
    bin_of = bin_of.at[j].set(name)
    return loads, names, used, k, bin_of


# ---------------------------------------------------------------------------
# classical algorithms (NF/FF/BF/WF and their Decreasing variants)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("strategy", "decreasing", "sticky"))
def pack_jax(
    speeds: jax.Array,
    prev: jax.Array,
    capacity,
    *,
    strategy: str = "first",
    decreasing: bool = False,
    sticky: bool = True,
    active: jax.Array | None = None,
) -> PackedJax:
    n = speeds.shape[0]
    m = n + 1
    u = 2 * n + 2                  # name universe
    speeds = speeds.astype(jnp.float32)
    prev = prev.astype(jnp.int32)
    capacity = jnp.float32(capacity)
    if active is not None:
        active = active.astype(bool)

    if decreasing:
        # stable non-increasing sort: (-speed, original index)
        order = jnp.lexsort((jnp.arange(n), -speeds))
    else:
        order = jnp.arange(n)

    def body(state, j):
        w = speeds[j]
        new = _place_or_create(state, j, w, prev[j], capacity, strategy, sticky)
        if active is not None:
            # an inactive item leaves every piece of packing state untouched
            new = jax.tree_util.tree_map(
                lambda a, b: jnp.where(active[j], a, b), new, state)
        return new, None

    init = (
        jnp.zeros(m, jnp.float32),
        jnp.full(m, NEG, jnp.int32),
        jnp.zeros(u, bool),
        jnp.int32(0),
        jnp.full(n, NEG, jnp.int32),
    )
    (loads, names, used, k, bin_of), _ = lax.scan(body, init, order)
    return PackedJax(bin_of=bin_of, loads=loads, names=names, n_bins=k)


# ---------------------------------------------------------------------------
# Modified Any Fit (Algorithm 1) -- MWF / MBF / MWFP / MBFP
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("fit", "sort_key"))
def modified_any_fit_jax(
    speeds: jax.Array,
    prev: jax.Array,
    capacity,
    *,
    fit: str = "best",
    sort_key: str = "cumulative",
    active: jax.Array | None = None,
) -> PackedJax:
    """Algorithm 1 as a single lax.scan over a 2n-entry flattened schedule.

    Each item appears twice: once in its consumer's phase-1 slot (smallest ->
    biggest, try open bins only) and once in phase-2 (biggest -> smallest,
    own-bin insert).  Consumers are visited in non-increasing key order and
    their two phases are contiguous, reproducing the per-consumer interleave
    of the pseudocode.  Leftovers are packed by a final decreasing any-fit
    scan with sticky bin naming.

    With an ``active`` mask, an inactive item counts as *absent*: it is
    treated as neither assigned nor pending (so it enters no phase and
    never reaches the final any-fit stage), matching the reference
    semantics of simply dropping the partition from the ``speeds`` map.
    """
    if fit not in ("best", "worst"):
        raise ValueError(fit)
    n = speeds.shape[0]
    m = 2 * n + 1                   # phase-2 creates <= n bins, final <= n
    u = 2 * n + 2                   # name universe (names provably <= 2n)
    s = u                           # consumer-segment universe: prev names <= 2n
    speeds = speeds.astype(jnp.float32)
    prev = prev.astype(jnp.int32)
    capacity = jnp.float32(capacity)
    pid = jnp.arange(n)
    assigned = prev >= 0
    pending0 = ~assigned
    if active is not None:
        active = active.astype(bool)
        assigned = assigned & active
        pending0 = ~assigned & active
    cseg = jnp.where(assigned, prev, s - 1)   # s-1 = dummy for unassigned

    # consumer sort keys (non-increasing; tie -> lower consumer id first)
    zero = jnp.zeros(s, jnp.float32)
    cum = zero.at[cseg].add(speeds)
    mx = zero.at[cseg].max(speeds)
    key = cum if sort_key == "cumulative" else (
        mx if sort_key == "max_partition" else None)
    if key is None:
        raise ValueError(sort_key)
    has = jnp.zeros(s, bool).at[cseg].set(True)
    key = jnp.where(has, key, -jnp.inf)
    crank_order = jnp.lexsort((jnp.arange(s), -key))          # rank -> consumer
    crank = jnp.zeros(s, jnp.int32).at[crank_order].set(jnp.arange(s, dtype=jnp.int32))
    item_rank = crank[cseg]                                    # i32[n]

    # phase-1 within-consumer order: speed asc, pid desc  (reverse of the
    # decreasing list, traversed back-to-front as in lines 6-13)
    p1 = jnp.lexsort((-pid, speeds, item_rank))
    # phase-2 within-consumer order: speed desc, pid asc (lines 18-24)
    p2 = jnp.lexsort((pid, -speeds, item_rank))
    # interleave: for each consumer, all its phase-1 entries then phase-2.
    seq_items = jnp.concatenate([p1, p2])
    seq_phase = jnp.concatenate([jnp.zeros(n, jnp.int32), jnp.ones(n, jnp.int32)])
    seq_pos = jnp.concatenate([jnp.arange(n), jnp.arange(n)])
    seq_rank = item_rank[seq_items]
    entry_order = jnp.lexsort((seq_pos, seq_phase, seq_rank))
    seq_items = seq_items[entry_order]
    seq_phase = seq_phase[entry_order]

    def body(state, ent):
        (loads, names, used, k, bin_of, placed, to_u, u_order,
         fail1, own_slot, own_fail) = state
        j, phase, entry_idx = ent
        w = speeds[j]
        c = cseg[j]
        skip = placed[j] | ~assigned[j]

        def phase1(args):
            (loads, names, used, k, bin_of, placed, to_u, u_order,
             fail1, own_slot, own_fail, entry_idx) = args
            slot, found = _select_slot(loads, k, w, capacity, fit)
            found = found & ~fail1[c]
            loads = jnp.where(found, loads.at[slot].add(w), loads)
            bin_of = jnp.where(found, bin_of.at[j].set(names[slot]), bin_of)
            placed = placed.at[j].set(placed[j] | found)
            fail1 = fail1.at[c].set(fail1[c] | ~found)
            return (loads, names, used, k, bin_of, placed, to_u, u_order,
                    fail1, own_slot, own_fail, entry_idx)

        def phase2(args):
            (loads, names, used, k, bin_of, placed, to_u, u_order,
             fail1, own_slot, own_fail, entry_idx) = args
            # create the consumer's own bin (named c) on its first
            # still-unplaced item (pset nonempty <=> some phase-1 failure)
            need_create = own_slot[c] < 0
            slot_new = k
            names = jnp.where(need_create, names.at[slot_new].set(c), names)
            used = jnp.where(need_create, used.at[c].set(True), used)
            own_slot = jnp.where(need_create, own_slot.at[c].set(slot_new), own_slot)
            k = jnp.where(need_create, k + 1, k)
            own = own_slot[c]
            # oversized exception: an item with w > C may hold its own
            # empty bin (matches modified.py; see comment there)
            fits = ((loads[own] + w <= capacity) |
                    ((loads[own] == 0.0) & (w > capacity))) & ~own_fail[c]
            loads = jnp.where(fits, loads.at[own].add(w), loads)
            bin_of = jnp.where(fits, bin_of.at[j].set(c), bin_of)
            placed = placed.at[j].set(placed[j] | fits)
            own_fail = own_fail.at[c].set(own_fail[c] | ~fits)
            deferred = ~fits
            to_u = to_u.at[j].set(to_u[j] | deferred)
            u_order = jnp.where(deferred, u_order.at[j].set(n + entry_idx), u_order)
            return (loads, names, used, k, bin_of, placed, to_u, u_order,
                    fail1, own_slot, own_fail, entry_idx)

        args = (loads, names, used, k, bin_of, placed, to_u, u_order,
                fail1, own_slot, own_fail, entry_idx)
        args = lax.cond(skip, lambda a: a,
                        lambda a: lax.cond(phase == 0, phase1, phase2, a), args)
        (loads, names, used, k, bin_of, placed, to_u, u_order,
         fail1, own_slot, own_fail, _) = args
        return (loads, names, used, k, bin_of, placed, to_u, u_order,
                fail1, own_slot, own_fail), None

    init = (
        jnp.zeros(m, jnp.float32),            # loads
        jnp.full(m, NEG, jnp.int32),          # names
        jnp.zeros(u, bool),                   # used names
        jnp.int32(0),                         # k
        jnp.full(n, NEG, jnp.int32),          # bin_of
        jnp.zeros(n, bool),                   # placed
        pending0,                             # to_u (initially: unassigned items)
        jnp.where(assigned, 3 * n, pid).astype(jnp.int32),  # u_order (pid for initial U)
        jnp.zeros(s, bool),                   # fail1 per consumer
        jnp.full(s, NEG, jnp.int32),          # own_slot per consumer
        jnp.zeros(s, bool),                   # own_fail per consumer
    )
    ents = jnp.stack([seq_items, seq_phase, jnp.arange(2 * n, dtype=jnp.int32)], axis=1)
    state, _ = lax.scan(body, init, ents)
    (loads, names, used, k, bin_of, placed, to_u, u_order, *_rest) = state

    # final stage (lines 27-29): decreasing any-fit over U with sticky naming
    final_order = jnp.lexsort((u_order, -speeds))

    def fbody(state, j):
        loads, names, used, k, bin_of = state
        pending = to_u[j]

        def do(args):
            return _place_or_create(args, j, speeds[j], prev[j], capacity, fit, True)

        state = lax.cond(pending, do, lambda a: a, (loads, names, used, k, bin_of))
        return state, None

    (loads, names, used, k, bin_of), _ = lax.scan(
        fbody, (loads, names, used, k, bin_of), final_order)
    return PackedJax(bin_of=bin_of, loads=loads, names=names, n_bins=k)


# ---------------------------------------------------------------------------
# whole-stream evaluation (bins + Rscore per iteration) in one jitted scan
# ---------------------------------------------------------------------------

def packer_for(name: str):
    """Public dispatch: ``name`` -> ``fn(speeds, prev, capacity) -> PackedJax``.

    The callable is scan-safe (pure jax.lax control flow), so downstream
    closed loops -- the controller decision step, ``repro.lagsim`` -- can run
    a repack every simulated step inside one jitted program.  Names resolve
    through ``repro.registry`` (the single policy catalogue); the identity
    of each algorithm -- fit strategy, decreasing pre-sort, consumer sort
    key -- lives in its registered ``PolicySpec``.
    """
    from repro.registry import packer_for as _registry_packer_for

    return _registry_packer_for(name, backend="jax")


def _stream_scan(stream: jax.Array, capacity, algorithm: str,
                 active: jax.Array | None = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared scan over an (N, P) stream: the previous iteration's assignment
    feeds the next, as in the controller loop.  ``active`` (bool[N, P],
    optional) masks partitions per iteration: a dead partition packs to
    ``NEG``, so a *death* costs no migration and a *rebirth* restarts with
    no sticky memory.  Returns per-iteration (bins i32[N], rscore f32[N],
    migrations i32[N])."""
    packer = packer_for(algorithm)
    n = stream.shape[1]
    capacity = jnp.float32(capacity)

    def step(prev, xs):
        if active is None:
            speeds = xs
            res = packer(speeds, prev, capacity)
        else:
            speeds, act = xs
            res = packer(speeds, prev, capacity, active=act)
        # NEG never counts as a move: a newly-dead partition hands off
        # nothing (its consumer just stops reading), and res.bin_of >= 0
        # always holds in the unmasked path
        moved = (prev >= 0) & (res.bin_of >= 0) & (res.bin_of != prev)
        r = jnp.sum(jnp.where(moved, speeds, 0.0)) / capacity
        migs = jnp.sum(moved.astype(jnp.int32))
        return res.bin_of, (res.n_bins, r, migs)

    xs = (stream.astype(jnp.float32) if active is None
          else (stream.astype(jnp.float32), active.astype(bool)))
    _, (bins, rs, migs) = lax.scan(step, jnp.full(n, NEG, jnp.int32), xs)
    return bins, rs, migs


@functools.partial(jax.jit, static_argnames=("algorithm",))
def evaluate_stream_jax(stream: jax.Array, capacity, *, algorithm: str,
                        active: jax.Array | None = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Run one algorithm over an (N, P) stream.

    Returns (bins_per_iter i32[N], rscore_per_iter f32[N]).  The previous
    iteration's assignment feeds the next, as in the controller loop.
    ``active`` (bool[N, P]) masks partitions per iteration.
    """
    bins, rs, _ = _stream_scan(stream, capacity, algorithm, active)
    return bins, rs


# ---------------------------------------------------------------------------
# batched scenario sweep: all algorithms x a whole batch of streams
# ---------------------------------------------------------------------------

def __getattr__(name: str):
    # deprecation shim: the hand-enumerated name table is now derived from
    # the registry (tests/test_registry.py pins the warning)
    if name == "ALL_ALGORITHM_NAMES":
        from repro.registry import PACKER_FAMILIES, list_policies
        from repro.registry.compat import warn_deprecated

        warn_deprecated(__name__, "ALL_ALGORITHM_NAMES",
                        "repro.registry.list_policies(family=('heuristic', "
                        "'sticky'), backend='jax')")
        return list_policies(family=PACKER_FAMILIES, backend="jax")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SweepResult:
    """Per-step traces of a batched sweep, indexed [algorithm, stream, iter].

    ``algorithms`` records the row order of axis 0 (static metadata).
    """
    bins: jax.Array        # i32[A, B, T]  consumers used per iteration
    rscores: jax.Array     # f32[A, B, T]  Eq. 10 rebalance cost per iteration
    migrations: jax.Array  # i32[A, B, T]  partitions moved per iteration
    algorithms: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))

    def for_algorithm(self, name: str
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        a = self.algorithms.index(name.upper())
        return self.bins[a], self.rscores[a], self.migrations[a]


def _sweep_streams_impl(algorithms: Tuple[str, ...], speeds_batch: jax.Array,
                        capacity, active: jax.Array | None = None
                        ) -> SweepResult:
    """Unjitted sweep core, shared by the module-level jit below and the
    fleet execution layer (``repro.fleet``), which jits it under its own
    bounded per-bucket cache."""
    if active is None:
        per_algo = [
            jax.vmap(lambda s, a=a: _stream_scan(s, capacity, a))(speeds_batch)
            for a in algorithms
        ]
    else:
        per_algo = [
            jax.vmap(lambda s, m, a=a: _stream_scan(s, capacity, a, m))(
                speeds_batch, active)
            for a in algorithms
        ]
    bins = jnp.stack([p[0] for p in per_algo])
    rs = jnp.stack([p[1] for p in per_algo])
    migs = jnp.stack([p[2] for p in per_algo])
    return SweepResult(bins=bins, rscores=rs, migrations=migs,
                       algorithms=algorithms)


@functools.partial(jax.jit, static_argnames=("algorithms",))
def _sweep_streams_jit(algorithms: Tuple[str, ...], speeds_batch: jax.Array,
                       capacity, active: jax.Array | None = None
                       ) -> SweepResult:
    return _sweep_streams_impl(algorithms, speeds_batch, capacity, active)


def sweep_streams(algorithms: Tuple[str, ...], speeds_batch: jax.Array,
                  capacity, active: jax.Array | None = None) -> SweepResult:
    """Evaluate ``algorithms`` over a whole batch of streams in one program.

    ``speeds_batch``: f32[B, T, N] -- B streams of T measurements over N
    partitions (e.g. from ``scenarios.scenario_suite`` / ``stack_suite``).
    ``active``: optional bool[B, T, N] partition mask (see
    ``scenarios.masked_scenario_suite``); inactive partitions pack to
    ``NEG`` and contribute no bins, load, or R-score.
    Each algorithm's scan is vmapped over the batch axis; with batch size 1
    the result is bit-identical to ``evaluate_stream_jax`` on the single
    stream (enforced by tests/test_scenarios.py).

    Names are case-normalized *before* the jit boundary so equivalent
    spellings share one compile-cache entry.
    """
    return _sweep_streams_jit(tuple(a.upper() for a in algorithms),
                              speeds_batch, capacity, active)
