"""Benchmark driver: one section per paper table/figure, printing
``name,us_per_call,derived`` CSV rows (us_per_call = evaluation wall time
where meaningful, else 0; derived = the quantity the paper reports).

  fig6_cbs_*          Cardinal Bin Score per algorithm/delta   (Fig. 6/7)
  fig8_rscore_*       Average Rscore per algorithm/delta       (Fig. 8)
  fig9_pareto_*       Pareto-front membership per delta        (Fig. 9)
  tab6_capacity_*     consumer max-throughput calibration      (Table VI/Fig. 10)
  packer_latency_*    reassignment-decision latency            (Sec. III premise)
  lagsim_*            closed-loop lag SLO sweep + speedup      (Sec. VI-D claim)
  controlplane_*      scaler friction: delay x cooldown grid   (Sec. V scalers)
  opt_*               optimality gaps + frontier hypervolume   (Sec. II model /
                                                               2024 follow-up)
  fleet_*             bucketed/sharded fleet throughput        (ROADMAP scaling)
  roofline_*          dry-run roofline aggregates              (EXPERIMENTS §Roofline)
  adversarial_*       worst-case SLO envelope per policy       (robustness gate)

Sections self-register: each benchmark module owns its rows via
``benchmarks.sections.section(name, prefixes=..., bench_json=...)`` and
this driver just imports the modules (registration order = output order)
and replays the registry -- a section's rows cannot silently drift from
the module that computes them, and a row outside its declared prefixes
is an error.  Policy/algorithm names inside every section resolve
through ``repro.registry``.

Run:  PYTHONPATH=src:. python benchmarks/run.py
"""
from __future__ import annotations

from benchmarks import sections

# importing a benchmark module registers its sections; this order is the
# output order
from benchmarks import paper_eval          # noqa: F401  fig6/fig8/fig9
from benchmarks import capacity_calibration  # noqa: F401  tab6
from benchmarks import packer_latency      # noqa: F401  packer_latency
from benchmarks import lag_slo             # noqa: F401  lagsim (BENCH_lagsim.json)
from benchmarks import controlplane_bench  # noqa: F401  controlplane (BENCH_controlplane.json)
from benchmarks import optimality_gap      # noqa: F401  opt (BENCH_opt.json)
from benchmarks import fleet_bench         # noqa: F401  fleet (BENCH_fleet.json)
from benchmarks import roofline            # noqa: F401  roofline
from benchmarks import adversarial_bench   # noqa: F401  adversarial (BENCH_adversarial.json)


def main() -> None:
    sections.emit_all()


if __name__ == "__main__":
    main()
