"""Benchmark driver: one section per paper table/figure, printing
``name,us_per_call,derived`` CSV rows (us_per_call = evaluation wall time
where meaningful, else 0; derived = the quantity the paper reports).

  fig6_cbs_*          Cardinal Bin Score per algorithm/delta   (Fig. 6/7)
  fig8_rscore_*       Average Rscore per algorithm/delta       (Fig. 8)
  fig9_pareto_*       Pareto-front membership per delta        (Fig. 9)
  tab6_capacity_*     consumer max-throughput calibration      (Table VI/Fig. 10)
  packer_latency_*    reassignment-decision latency            (Sec. III premise)
  lagsim_*            closed-loop lag SLO sweep + speedup      (Sec. VI-D claim)
  opt_*               optimality gaps + frontier hypervolume   (Sec. II model /
                                                               2024 follow-up)
  roofline_*          dry-run roofline aggregates              (EXPERIMENTS §Roofline)

The fig6/fig8/fig9 sections run through the batched scenario-sweep engine
(``repro.core.jaxpack.sweep_streams``): each algorithm evaluates all six
delta-streams in one vmapped XLA program.

Run:  PYTHONPATH=src:. python benchmarks/run.py
"""
from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")

    from benchmarks import paper_eval
    data = paper_eval.sweep()
    cbs = paper_eval.cbs_table(data)
    for delta, per in sorted(cbs.items()):
        for algo, val in per.items():
            us = data["seconds"][(delta, algo)] * 1e6
            print(f"fig6_cbs_d{delta}_{algo},{us:.1f},{val:.6f}")
    rs = paper_eval.rscore_table(data)
    for delta, per in sorted(rs.items()):
        for algo, val in per.items():
            print(f"fig8_rscore_d{delta}_{algo},0,{val:.6f}")
    pareto = paper_eval.pareto_table(data)
    for delta, (front, pts) in sorted(pareto.items()):
        for algo in paper_eval.ALGORITHMS:
            print(f"fig9_pareto_d{delta}_{algo},0,{int(algo in front)}")

    from benchmarks import capacity_calibration
    for name, res in capacity_calibration.run().items():
        print(f"tab6_capacity_{name}_mode_bytes_s,0,"
              f"{res['measured_mode_bytes_s']:.0f}")
        print(f"tab6_capacity_{name}_mode_over_capacity,0,"
              f"{res['mode_over_capacity']:.4f}")

    from benchmarks import packer_latency
    for name, us in packer_latency.run().items():
        print(f"packer_latency_{name},{us:.1f},0")

    from benchmarks import lag_slo
    lag = lag_slo.run()                 # also writes BENCH_lagsim.json
    for fam, per_policy in sorted(lag["families"].items()):
        for pol, metrics in per_policy.items():
            for metric in ("violation_frac", "consumer_seconds",
                           "total_migrations"):
                print(f"lagsim_{fam}_{pol}_{metric},0,"
                      f"{metrics[metric]:.6f}")
    print(f"lagsim_speedup_vs_python,"
          f"{lag['timing']['lagsim_us_per_stream_step']:.1f},"
          f"{lag['timing']['speedup_vs_python']:.1f}")

    from benchmarks import optimality_gap
    opt = optimality_gap.run(**optimality_gap.FULL)   # writes BENCH_opt.json
    optimality_gap.check_invariants(opt)
    for fam, res in sorted(opt["families"].items()):
        for algo, g in res["gaps"].items():
            print(f"opt_gap_{fam}_{algo},0,{g['mean_gap_vs_opt']:.6f}")
        for algo, m in res["frontier"]["per_algorithm"].items():
            print(f"opt_hv_{fam}_{algo},0,{m['mean_hv_ratio']:.6f}")
        print(f"opt_anneal_gap_{fam},0,"
              f"{res['anneal']['mean_gap_vs_opt']:.6f}")

    from benchmarks import roofline
    for name, val in roofline.run().items():
        print(f"roofline_{name},0,{val:.4f}")


if __name__ == "__main__":
    main()
