"""Render the paper's Figs. 6-9 analogues as PNGs into benchmarks/figures/:
CBS bars per delta (Figs. 6-7), average-Rscore bars (Fig. 8) and the
(CBS, E[R]) Pareto scatter (Fig. 9), from ``paper_eval``'s batched sweep.
Requires matplotlib.

Run:  PYTHONPATH=src:. python benchmarks/figures.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

from benchmarks import paper_eval  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "figures")
MOD = ("MWF", "MBF", "MWFP", "MBFP")


def main():
    os.makedirs(OUT, exist_ok=True)
    data = paper_eval.sweep()
    cbs = paper_eval.cbs_table(data)
    rs = paper_eval.rscore_table(data)
    pareto = paper_eval.pareto_table(data)
    deltas = sorted(cbs)

    # Fig. 6/7 -- CBS vs delta
    fig, ax = plt.subplots(figsize=(9, 5))
    for a in paper_eval.ALGORITHMS:
        style = "-o" if a in MOD else "--s"
        ax.plot(deltas, [cbs[d][a] for d in deltas], style, label=a,
                linewidth=2 if a in MOD else 1)
    ax.set_xlabel("delta (max % speed variation per iteration)")
    ax.set_ylabel("Cardinal Bin Score (Eq. 12)")
    ax.set_title("CBS per algorithm (paper Figs. 6-7)")
    ax.legend(ncol=4, fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(OUT, "fig6_cbs.png"), dpi=120)

    # Fig. 8 -- E[R] vs delta
    fig, ax = plt.subplots(figsize=(9, 5))
    for a in paper_eval.ALGORITHMS:
        style = "-o" if a in MOD else "--s"
        ax.plot(deltas, [rs[d][a] for d in deltas], style, label=a,
                linewidth=2 if a in MOD else 1)
    ax.set_xlabel("delta")
    ax.set_ylabel("Average Rscore (Eq. 13)")
    ax.set_title("Rebalance cost per algorithm (paper Fig. 8)")
    ax.legend(ncol=4, fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(OUT, "fig8_rscore.png"), dpi=120)

    # Fig. 9 -- Pareto scatter per delta
    ds = [d for d in deltas if d > 0]
    fig, axes = plt.subplots(1, len(ds), figsize=(4 * len(ds), 4),
                             sharey=False)
    for ax, d in zip(axes, ds):
        front, pts = pareto[d]
        for a, (x, y) in pts.items():
            on = a in front
            ax.scatter(x, y, c="tab:red" if on else "tab:gray",
                       s=60 if on else 25, zorder=3 if on else 2)
            ax.annotate(a, (x, y), fontsize=7,
                        xytext=(3, 3), textcoords="offset points")
        ax.set_title(f"delta={d}")
        ax.set_xlabel("CBS")
    axes[0].set_ylabel("E[R]")
    fig.suptitle("Pareto fronts: operational vs rebalance cost (paper Fig. 9)")
    fig.tight_layout()
    fig.savefig(os.path.join(OUT, "fig9_pareto.png"), dpi=120)
    print(f"wrote {OUT}/fig6_cbs.png fig8_rscore.png fig9_pareto.png")


if __name__ == "__main__":
    main()
