"""Consumer max-throughput calibration (paper Table VI + Fig. 10).

The paper validates the SBSBP constant-capacity assumption by saturating a
consumer under three disparate conditions (different totals, partition
counts, destination-table counts) and observing a common throughput mode
(~2.3 MB/s on their GKE consumer).  We reproduce the *procedure* against the
simulated replica: pre-load the broker, let one replica drain at full
throttle under each condition, and report the measured rate distribution.

Run:  PYTHONPATH=src:. python benchmarks/run.py      (tab6_capacity_* rows)
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.broker import Broker, SimClock, TopicPartition
from repro.serving.replica import Replica, ReplicaConfig, Sink

# (total_bytes, n_partitions, n_tables) -- paper Table VI
CONDITIONS = [
    ("test1", 648e6, 32, 1),
    ("test2", 100e6, 116, 5),
    ("test3", 678e6, 144, 5),
]
CAPACITY = 2.3e6   # configured replica capacity (bytes/s)


def run_condition(total_bytes: float, n_partitions: int, n_tables: int,
                  record_bytes: int = 4096) -> List[float]:
    clock = SimClock()
    broker = Broker(clock)
    topics = [f"table{t}" for t in range(n_tables)]
    per_topic = max(1, n_partitions // n_tables)
    tps = []
    for t in topics:
        broker.create_topic(t, per_topic)
        tps += [TopicPartition(t, i) for i in range(per_topic)]
    # pre-load the backlog
    per_tp = int(total_bytes / len(tps) / record_bytes)
    for tp in tps:
        for _ in range(per_tp):
            broker.produce(tp, value=None, nbytes=record_bytes)
    broker.create_topic("consumer.metadata", 2)
    rep = Replica(0, broker, Sink(), ReplicaConfig(rate=CAPACITY,
                                                   batch_bytes=1 << 21))
    for tp in tps:
        rep.handle.assign(tp)
    rates = []
    for _ in range(120):
        consumed = rep.step(1.0)
        clock.advance(1.0)
        if consumed > 0:
            rates.append(float(consumed))
        if all(broker.lag("autoscaler", tp) == 0 for tp in tps):
            break
    return rates


def run() -> Dict[str, Dict[str, float]]:
    out = {}
    for name, total, parts, tables in CONDITIONS:
        rates = run_condition(total, parts, tables)
        hist, edges = np.histogram(rates, bins=20)
        mode = 0.5 * (edges[np.argmax(hist)] + edges[np.argmax(hist) + 1])
        out[name] = {
            "measured_mode_bytes_s": float(mode),
            "mean_bytes_s": float(np.mean(rates)),
            "configured_capacity": CAPACITY,
            "mode_over_capacity": float(mode / CAPACITY),
        }
    return out


from benchmarks.sections import section  # noqa: E402


@section("tab6_capacity", prefixes=("tab6_capacity_",))
def _rows():
    for name, res in run().items():
        yield (f"tab6_capacity_{name}_mode_bytes_s,0,"
               f"{res['measured_mode_bytes_s']:.0f}")
        yield (f"tab6_capacity_{name}_mode_over_capacity,0,"
               f"{res['mode_over_capacity']:.4f}")
