"""Optimality gaps and Pareto-frontier hypervolumes: every heuristic scored
against computed ground truth (``repro.opt``), per scenario family.

Three layers of truth per family:

1. **Exact oracle** -- branch-and-bound (`repro.opt.branch_bound`) solves
   every (stream, iteration) instance to proven optimality; each
   algorithm's per-iteration bin counts from the batched sweep
   (``jaxpack.sweep_streams``) are compared as
   ``gap = (bins - opt) / opt`` (and against the certified L2 lower
   bound, which keeps the gap >= 0 by construction).
2. **Annealed optimum** -- the batched simulated annealer at lambda = 0
   re-solves the same instances; its gap against the oracle certifies the
   stochastic optimizer itself.
3. **Frontier** -- per stream, a lambda sweep at a mid-trace instance
   (previous assignment = the sticky-BFD incumbent) traces the
   bins-vs-R-score Pareto front; each heuristic repacks the same instance
   and is scored by domination status and single-point hypervolume ratio
   against the annealed front.

Writes ``BENCH_opt.json`` at the repo root.  ``--smoke`` shrinks every
dimension for CI and asserts the invariants the acceptance criteria pin:
oracle exact everywhere, all 12 per-algorithm gaps vs the lower bound
nonnegative.

Run:  PYTHONPATH=src:. python benchmarks/run.py              (opt_* rows)
or    PYTHONPATH=src:. python benchmarks/optimality_gap.py   (JSON only)
"""
from __future__ import annotations

import argparse
import functools
import os
import time
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BenchReport
from repro.core.jaxpack import sweep_streams
from repro.core.scenarios import SCENARIO_FAMILIES, scenario_suite
from repro.opt import (
    anneal_chains,
    anneal_frontier,
    branch_and_bound,
    heuristic_point,
    incumbent_assignment,
    optimality_gap,
)
from repro.registry import PACKER_FAMILIES, list_policies

from benchmarks.sections import observability_block, section, telemetry_block

ALGORITHMS = list_policies(family=PACKER_FAMILIES, backend="jax")

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_opt.json")

CAPACITY = 1.0
SEED = 0

FULL = dict(batch=2, iters=12, n=8, lambdas=(0.0, 0.5, 1.0, 2.0, 4.0, 8.0),
            restarts=3, steps=250, chains=16)
SMOKE = dict(batch=1, iters=6, n=6, lambdas=(0.0, 1.0, 4.0),
             restarts=2, steps=150, chains=12)


@functools.partial(jax.jit, static_argnames=("chains", "steps"))
def _anneal_bins_many(speeds_many, key, *, chains: int, steps: int):
    """Best annealed (lambda = 0) bin count per instance ``f32[I, N]``."""
    n = speeds_many.shape[1]
    lam = jnp.zeros((chains,), jnp.float32)
    prev = jnp.full((n,), -1, jnp.int32)
    keys = jax.random.split(key, speeds_many.shape[0])

    def one(speeds, k):
        res = anneal_chains(speeds, prev, jnp.float32(CAPACITY), lam, k,
                            steps=steps)
        return jnp.min(res.bins)

    return jax.vmap(one)(speeds_many, keys)


def run(batch: int, iters: int, n: int, lambdas: Sequence[float],
        restarts: int, steps: int, chains: int,
        families: Sequence[str] = tuple(SCENARIO_FAMILIES),
        seed: int = SEED) -> Dict:
    """Full evaluation -> nested result dict (also written to
    BENCH_opt.json)."""
    suite = scenario_suite(jax.random.key(seed), batch, iters, n,
                           capacity=CAPACITY, families=tuple(families))
    t_rep = max(iters // 2, 1)
    out_families: Dict[str, Dict] = {}

    for fi, (fam, traces) in enumerate(suite.items()):
        tr = np.asarray(traces, np.float64)              # [B, T, N]
        sweep = sweep_streams(ALGORITHMS, traces, CAPACITY)
        bins = np.asarray(sweep.bins)                    # [A, B, T]

        # 1) exact oracle on every (stream, iteration) instance
        t0 = time.perf_counter()
        opt = np.zeros((batch, iters), np.int64)
        lb = np.zeros((batch, iters), np.int64)
        exact = 0
        for b in range(batch):
            for t in range(iters):
                r = branch_and_bound(tr[b, t].tolist(), CAPACITY)
                opt[b, t] = r.n_bins
                lb[b, t] = r.lower_bound
                exact += int(r.optimal)
        oracle_s = time.perf_counter() - t0

        gaps = {}
        for a, name in enumerate(ALGORITHMS):
            g_opt = optimality_gap(bins[a], opt)
            g_lb = optimality_gap(bins[a], lb)
            gaps[name] = {
                "mean_bins": float(bins[a].mean()),
                "mean_gap_vs_opt": float(g_opt.mean()),
                "max_gap_vs_opt": float(g_opt.max()),
                "mean_gap_vs_lb": float(g_lb.mean()),
                "min_gap_vs_lb": float(g_lb.min()),
            }

        # 2) annealed optimum (lambda = 0) on the same instances
        flat = jnp.asarray(tr.reshape(batch * iters, n), jnp.float32)
        ann = np.asarray(_anneal_bins_many(
            flat, jax.random.fold_in(jax.random.key(seed), fi),
            chains=chains, steps=steps)).reshape(batch, iters)
        g_ann = optimality_gap(ann, opt)
        anneal_summary = {
            "mean_gap_vs_opt": float(g_ann.mean()),
            "match_frac": float((ann == opt).mean()),
        }

        # 3) frontier at a mid-trace instance per stream
        hv_list = []
        per_algo = {name: {"hv_ratio": [], "dominated": [], "bins": [],
                           "rscore": []} for name in ALGORITHMS}
        for b in range(batch):
            prev = incumbent_assignment(tr[b], CAPACITY, t_rep)
            speeds_t = tr[b, t_rep]
            fr = anneal_frontier(
                speeds_t, prev, CAPACITY,
                jax.random.fold_in(jax.random.key(seed + 1), fi * batch + b),
                lambdas=lambdas, restarts=restarts, steps=steps)
            hv_list.append(fr.hypervolume)
            for name in ALGORITHMS:
                pt = heuristic_point(name, speeds_t, prev, CAPACITY)
                met = fr.heuristic_metrics(pt)
                per_algo[name]["hv_ratio"].append(met["hv_ratio"])
                per_algo[name]["dominated"].append(met["dominated"])
                per_algo[name]["bins"].append(met["bins"])
                per_algo[name]["rscore"].append(met["rscore"])

        out_families[fam] = {
            "oracle": {
                "mean_opt_bins": float(opt.mean()),
                "mean_lower_bound": float(lb.mean()),
                "exact_frac": exact / (batch * iters),
                "seconds": oracle_s,
            },
            "gaps": gaps,
            "anneal": anneal_summary,
            "frontier": {
                "lambdas": list(lambdas),
                "t_rep": t_rep,
                "mean_hypervolume": float(np.mean(hv_list)),
                "per_algorithm": {
                    name: {
                        "mean_hv_ratio": float(np.mean(v["hv_ratio"])),
                        "dominated_frac": float(np.mean(v["dominated"])),
                        "mean_bins": float(np.mean(v["bins"])),
                        "mean_rscore": float(np.mean(v["rscore"])),
                    }
                    for name, v in per_algo.items()
                },
            },
        }

    report = BenchReport(
        kind="opt",
        config={
            "batch": batch, "iters": iters, "n_partitions": n,
            "capacity": CAPACITY, "seed": seed, "lambdas": list(lambdas),
            "restarts": restarts, "steps": steps, "chains": chains,
            "algorithms": list(ALGORITHMS),
            "families": list(suite),
        },
        families=out_families,
        extra={"telemetry": telemetry_block(),
               "observability": observability_block(seed=seed)},
    )
    return report.write(BENCH_PATH)


def check_invariants(out: Dict) -> None:
    """The acceptance bars: the oracle proved every instance, and no
    heuristic ever beats the certified lower bound."""
    for fam, res in out["families"].items():
        assert res["oracle"]["exact_frac"] == 1.0, (
            f"{fam}: oracle left instances unproven")
        for name, g in res["gaps"].items():
            # per-instance, not mean: a single bins < lower_bound anywhere
            # is a soundness bug that averaging must not hide
            assert g["min_gap_vs_lb"] >= 0.0, (
                f"{fam}/{name}: some instance beat the certified lower "
                f"bound (min gap {g['min_gap_vs_lb']} < 0)")
        assert res["anneal"]["mean_gap_vs_opt"] >= 0.0, (
            f"{fam}: annealer below the proven optimum")


@section("opt", prefixes=("opt_",), bench_json="BENCH_opt.json")
def _rows():
    out = run(**FULL)                   # also writes BENCH_opt.json
    check_invariants(out)
    for fam, res in sorted(out["families"].items()):
        for algo, g in res["gaps"].items():
            yield f"opt_gap_{fam}_{algo},0,{g['mean_gap_vs_opt']:.6f}"
        for algo, m in res["frontier"]["per_algorithm"].items():
            yield f"opt_hv_{fam}_{algo},0,{m['mean_hv_ratio']:.6f}"
        yield (f"opt_anneal_gap_{fam},0,"
               f"{res['anneal']['mean_gap_vs_opt']:.6f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; asserts gap/oracle invariants")
    args = ap.parse_args()
    p = SMOKE if args.smoke else FULL
    out = run(**p)
    check_invariants(out)
    print(f"wrote {BENCH_PATH}")
    for fam, res in out["families"].items():
        worst = max(res["gaps"].items(),
                    key=lambda kv: kv[1]["mean_gap_vs_opt"])
        best = min(res["gaps"].items(),
                   key=lambda kv: kv[1]["mean_gap_vs_opt"])
        print(f"{fam:<12} opt={res['oracle']['mean_opt_bins']:.2f} bins  "
              f"anneal match={res['anneal']['match_frac']:.0%}  "
              f"best {best[0]} (+{100 * best[1]['mean_gap_vs_opt']:.1f}%)  "
              f"worst {worst[0]} (+{100 * worst[1]['mean_gap_vs_opt']:.1f}%)")
    if args.smoke:
        print("smoke invariants OK: oracle exact everywhere, "
              "all gaps vs lower bound >= 0")


if __name__ == "__main__":
    main()
