"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads dryrun_results.jsonl and renders, per (arch x shape x mesh):
the three terms in seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS,
and HBM fit.  Pure post-processing -- no device work.

Also emits the analytic fused-loop roofline (``roofline_fused_*`` rows):
HBM bytes moved vs arithmetic per K-block of ``kernels/loop_fused.py``
at the paper shape (N = 10), with the lag/assignment/downtime carry
resident in VMEM -- see :func:`fused_loop_model`.

Run:  PYTHONPATH=src:. python benchmarks/run.py      (roofline_* rows)
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.jsonl")


def load(path: str = DEFAULT_PATH) -> List[Dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                   r.get("rules", "baseline"))
            seen[key] = r  # last write wins (reruns)
    return list(seen.values())


def table(rows: List[Dict], mesh: str = "16x16",
          rules: str = "baseline") -> List[Dict]:
    out = []
    for r in rows:
        if r.get("mesh") != mesh or r.get("rules", "baseline") != rules:
            continue
        if "skipped" in r:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "skipped": r["skipped"]})
            continue
        if "error" in r:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "error": r["error"][:120]})
            continue
        rl = r["roofline"]
        t = {
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_s": rl["t_compute_s"],
            "t_memory_s": rl["t_memory_s"],
            "t_collective_s": rl["t_collective_s"],
            "bottleneck": rl["bottleneck"],
            "useful_flops_ratio": r.get("useful_flops_ratio"),
            "live_gib": r["memory"].get("live_bytes_per_device", 0) / 2 ** 30,
            "fits_hbm": r["memory"].get("fits_hbm"),
            "compile_s": r.get("compile_s"),
        }
        dom = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        t["roofline_fraction"] = (t["t_compute_s"] / dom) if dom > 0 else None
        out.append(t)
    out.sort(key=lambda x: (x["arch"], x["shape"]))
    return out


def render(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>10s} {'t_mem':>10s} "
           f"{'t_coll':>10s} {'bound':>10s} {'MF/HLO':>7s} {'liveGiB':>8s} "
           f"{'fit':>4s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for t in rows:
        if "skipped" in t:
            lines.append(f"{t['arch']:24s} {t['shape']:12s} "
                         f"{t['skipped']}")
            continue
        if "error" in t:
            lines.append(f"{t['arch']:24s} {t['shape']:12s} ERROR {t['error']}")
            continue
        lines.append(
            f"{t['arch']:24s} {t['shape']:12s} {t['t_compute_s']:10.3e} "
            f"{t['t_memory_s']:10.3e} {t['t_collective_s']:10.3e} "
            f"{t['bottleneck']:>10s} "
            f"{(t['useful_flops_ratio'] or 0):7.3f} {t['live_gib']:8.2f} "
            f"{'Y' if t['fits_hbm'] else 'N':>4s} "
            f"{100 * (t['roofline_fraction'] or 0):6.1f}%")
    return "\n".join(lines)


def run(path: str = DEFAULT_PATH) -> Dict[str, float]:
    rows = load(path)
    out: Dict[str, float] = {}
    for mesh in ("16x16", "2x16x16"):
        tab = table(rows, mesh=mesh)
        ok = [t for t in tab if "skipped" not in t and "error" not in t]
        if not ok:
            continue
        out[f"{mesh}_cells_ok"] = len(ok)
        out[f"{mesh}_cells_err"] = len([t for t in tab if "error" in t])
        fracs = [t["roofline_fraction"] for t in ok if t["roofline_fraction"]]
        if fracs:
            out[f"{mesh}_mean_roofline_frac"] = sum(fracs) / len(fracs)
    return out


def fused_loop_model(k: int = 8, n: int = 10) -> Dict[str, float]:
    """Analytic roofline of one ``kernels/loop_fused.py`` K-block: HBM
    bytes moved vs arithmetic per (stream, K-block) at the paper shape.

    Per block the kernel streams the ``[K, N]`` rate slab in, writes five
    ``[K]`` per-step outputs plus the ``[K, N]`` assignment slab, and
    keeps the whole carry (lag f32[N], prev/down i32[N]) in VMEM scratch
    across blocks -- zero HBM traffic for state, which is what the fused
    path buys over the per-step scan.  Arithmetic per step: the pairwise
    decreasing rank (~3 N^2 lane ops), the M-slot packing loop (~8 N M),
    the bitmask sticky naming (~12 N int ops) and the one-hot drain
    (~4 N M + 2 M), with M = 2 N + 1 name slots.
    """
    m = 2 * n + 1
    bytes_per_block = 4.0 * (k * n          # rate slab in
                             + 5 * k        # five per-step outputs
                             + k * n)       # assignment slab out
    ops_per_step = 3 * n * n + 12 * n * m + 12 * n + 2 * m
    flops_per_block = float(k * ops_per_step)
    return {
        "k_steps": float(k),
        "n_partitions": float(n),
        "hbm_bytes_per_block": bytes_per_block,
        "flops_per_block": flops_per_block,
        "flops_per_byte": flops_per_block / bytes_per_block,
        "vmem_carry_bytes": 3.0 * 4 * n,
    }


from benchmarks.sections import section  # noqa: E402


@section("roofline", prefixes=("roofline_",))
def _rows():
    for name, val in run().items():
        yield f"roofline_{name},0,{val:.4f}"
    for name, val in fused_loop_model().items():
        yield f"roofline_fused_{name},0,{val:.4f}"


if __name__ == "__main__":
    rows = load()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n=== mesh {mesh} ===")
        print(render(table(rows, mesh=mesh)))
