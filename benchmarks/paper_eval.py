"""Reproduces the evaluation behind the paper's Figs. 6-9: Cardinal Bin
Score (Eq. 12), average Rscore (Eq. 13) and the Pareto fronts for all 12
algorithms over the six delta-streams (Eq. 11).

The six streams are stacked into one ``f32[6, N, P]`` batch and evaluated
through the vmapped sweep driver (``repro.core.jaxpack.sweep_streams``), so
each algorithm's whole six-delta evaluation is a single XLA program; the
recorded per-(delta, algorithm) seconds are the batched wall time amortized
over the six streams.

Run:  PYTHONPATH=src:. python benchmarks/run.py      (fig6_/fig8_/fig9_ rows)
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.jaxpack import ALL_ALGORITHM_NAMES, sweep_streams
from repro.core.metrics import pareto_front
from repro.core.streams import PAPER_DELTAS, generate_stream

ALGORITHMS = ALL_ALGORITHM_NAMES
N_PARTITIONS = 50
CAPACITY = 1.0


@functools.lru_cache(maxsize=1)
def sweep(n_partitions: int = N_PARTITIONS, n_measurements: int = 500,
          seed: int = 0) -> Dict:
    """Returns {delta: {algo: (bins i32[N], rscores f32[N])}} + timings."""
    out: Dict = {"deltas": {d: {} for d in PAPER_DELTAS}, "seconds": {}}
    batch = jnp.asarray(np.stack([
        generate_stream(n_partitions, n_measurements, delta, CAPACITY,
                        seed=seed + i)
        for i, delta in enumerate(PAPER_DELTAS)
    ]), jnp.float32)
    for algo in ALGORITHMS:
        t0 = time.perf_counter()
        res = sweep_streams((algo,), batch, CAPACITY)
        bins = np.asarray(res.bins[0])      # (6, N)
        rs = np.asarray(res.rscores[0])     # (6, N)
        per_stream = (time.perf_counter() - t0) / len(PAPER_DELTAS)
        for i, delta in enumerate(PAPER_DELTAS):
            out["seconds"][(delta, algo)] = per_stream
            out["deltas"][delta][algo] = (bins[i], rs[i])
    return out


def cbs_table(data: Dict) -> Dict[float, Dict[str, float]]:
    """Eq. 12 per delta."""
    table = {}
    for delta, per_algo in data["deltas"].items():
        z = np.stack([per_algo[a][0] for a in ALGORITHMS])  # (A, N)
        zmin = np.maximum(z.min(axis=0), 1)
        cbs = ((z - zmin) / zmin).mean(axis=1)
        table[delta] = dict(zip(ALGORITHMS, cbs.tolist()))
    return table


def rscore_table(data: Dict) -> Dict[float, Dict[str, float]]:
    """Eq. 13 per delta."""
    return {delta: {a: float(per_algo[a][1].mean()) for a in ALGORITHMS}
            for delta, per_algo in data["deltas"].items()}


def pareto_table(data: Dict) -> Dict[float, Tuple[list, dict]]:
    cbs = cbs_table(data)
    er = rscore_table(data)
    out = {}
    for delta in cbs:
        pts = {a: (cbs[delta][a], er[delta][a]) for a in ALGORITHMS}
        out[delta] = (pareto_front(pts), pts)
    return out
