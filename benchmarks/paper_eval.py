"""Shared evaluation sweep behind the paper's Figs. 6-9: all 12 algorithms
over the six delta-streams (Eq. 11), via the jitted whole-stream scan."""
from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.jaxpack import evaluate_stream_jax
from repro.core.metrics import pareto_front
from repro.core.streams import PAPER_DELTAS, generate_stream

ALGORITHMS = ("NF", "NFD", "FF", "FFD", "BF", "BFD", "WF", "WFD",
              "MWF", "MBF", "MWFP", "MBFP")
N_PARTITIONS = 50
CAPACITY = 1.0


@functools.lru_cache(maxsize=1)
def sweep(n_partitions: int = N_PARTITIONS, n_measurements: int = 500,
          seed: int = 0) -> Dict:
    """Returns {delta: {algo: (bins i32[N], rscores f32[N])}} + timings."""
    out: Dict = {"deltas": {}, "seconds": {}}
    for i, delta in enumerate(PAPER_DELTAS):
        stream = generate_stream(n_partitions, n_measurements, delta,
                                 CAPACITY, seed=seed + i)
        stream_j = jnp.asarray(stream, jnp.float32)
        per_algo = {}
        for algo in ALGORITHMS:
            t0 = time.perf_counter()
            bins, rs = evaluate_stream_jax(stream_j, CAPACITY, algorithm=algo)
            bins = np.asarray(bins)
            rs = np.asarray(rs)
            out["seconds"][(delta, algo)] = time.perf_counter() - t0
            per_algo[algo] = (bins, rs)
        out["deltas"][delta] = per_algo
    return out


def cbs_table(data: Dict) -> Dict[float, Dict[str, float]]:
    """Eq. 12 per delta."""
    table = {}
    for delta, per_algo in data["deltas"].items():
        z = np.stack([per_algo[a][0] for a in ALGORITHMS])  # (A, N)
        zmin = np.maximum(z.min(axis=0), 1)
        cbs = ((z - zmin) / zmin).mean(axis=1)
        table[delta] = dict(zip(ALGORITHMS, cbs.tolist()))
    return table


def rscore_table(data: Dict) -> Dict[float, Dict[str, float]]:
    """Eq. 13 per delta."""
    return {delta: {a: float(per_algo[a][1].mean()) for a in ALGORITHMS}
            for delta, per_algo in data["deltas"].items()}


def pareto_table(data: Dict) -> Dict[float, Tuple[list, dict]]:
    cbs = cbs_table(data)
    er = rscore_table(data)
    out = {}
    for delta in cbs:
        pts = {a: (cbs[delta][a], er[delta][a]) for a in ALGORITHMS}
        out[delta] = (pareto_front(pts), pts)
    return out
