"""Reproduces the evaluation behind the paper's Figs. 6-9: Cardinal Bin
Score (Eq. 12), average Rscore (Eq. 13) and the Pareto fronts for all 12
algorithms over the six delta-streams (Eq. 11).

The six streams are stacked into one ``f32[6, N, P]`` batch and evaluated
through the fleet execution layer (``repro.api.default_fleet`` ->
``repro.fleet.FleetRunner`` -> the vmapped sweep driver), so each
algorithm's whole six-delta evaluation is a single XLA program, sharded
over available devices; the recorded per-(delta, algorithm) seconds are
the batched wall time amortized over the six streams.

Run:  PYTHONPATH=src:. python benchmarks/run.py      (fig6_/fig8_/fig9_ rows)
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api import default_fleet
from repro.core.metrics import cbs_from_bins, pareto_front
from repro.core.streams import PAPER_DELTAS, generate_stream
from repro.registry import PACKER_FAMILIES, list_policies

from benchmarks.sections import section

ALGORITHMS = list_policies(family=PACKER_FAMILIES, backend="jax")
N_PARTITIONS = 50
CAPACITY = 1.0


@functools.lru_cache(maxsize=1)
def sweep(n_partitions: int = N_PARTITIONS, n_measurements: int = 500,
          seed: int = 0) -> Dict:
    """Returns {delta: {algo: (bins i32[N], rscores f32[N])}} + timings."""
    out: Dict = {"deltas": {d: {} for d in PAPER_DELTAS}, "seconds": {}}
    batch = jnp.asarray(np.stack([
        generate_stream(n_partitions, n_measurements, delta, CAPACITY,
                        seed=seed + i)
        for i, delta in enumerate(PAPER_DELTAS)
    ]), jnp.float32)
    fleet = default_fleet()
    for algo in ALGORITHMS:
        t0 = time.perf_counter()
        res = fleet.sweep((algo,), batch, CAPACITY)
        bins_all, rs_all, _ = res.stacked()
        bins = bins_all[0]                  # (6, N)
        rs = rs_all[0]                      # (6, N)
        per_stream = (time.perf_counter() - t0) / len(PAPER_DELTAS)
        for i, delta in enumerate(PAPER_DELTAS):
            out["seconds"][(delta, algo)] = per_stream
            out["deltas"][delta][algo] = (bins[i], rs[i])
    return out


def cbs_table(data: Dict) -> Dict[float, Dict[str, float]]:
    """Eq. 12 per delta."""
    table = {}
    for delta, per_algo in data["deltas"].items():
        cbs = cbs_from_bins(np.stack([per_algo[a][0] for a in ALGORITHMS]))
        table[delta] = dict(zip(ALGORITHMS, cbs.tolist()))
    return table


def rscore_table(data: Dict) -> Dict[float, Dict[str, float]]:
    """Eq. 13 per delta."""
    return {delta: {a: float(per_algo[a][1].mean()) for a in ALGORITHMS}
            for delta, per_algo in data["deltas"].items()}


def pareto_table(data: Dict) -> Dict[float, Tuple[list, dict]]:
    cbs = cbs_table(data)
    er = rscore_table(data)
    out = {}
    for delta in cbs:
        pts = {a: (cbs[delta][a], er[delta][a]) for a in ALGORITHMS}
        out[delta] = (pareto_front(pts), pts)
    return out


# ---------------------------------------------------------------------------
# benchmark sections (rows of benchmarks/run.py)
# ---------------------------------------------------------------------------

@section("fig6_cbs", prefixes=("fig6_cbs_",))
def _rows_fig6():
    data = sweep()
    for delta, per in sorted(cbs_table(data).items()):
        for algo, val in per.items():
            us = data["seconds"][(delta, algo)] * 1e6
            yield f"fig6_cbs_d{delta}_{algo},{us:.1f},{val:.6f}"


@section("fig8_rscore", prefixes=("fig8_rscore_",))
def _rows_fig8():
    data = sweep()
    for delta, per in sorted(rscore_table(data).items()):
        for algo, val in per.items():
            yield f"fig8_rscore_d{delta}_{algo},0,{val:.6f}"


@section("fig9_pareto", prefixes=("fig9_pareto_",))
def _rows_fig9():
    data = sweep()
    for delta, (front, pts) in sorted(pareto_table(data).items()):
        for algo in ALGORITHMS:
            yield f"fig9_pareto_d{delta}_{algo},0,{int(algo in front)}"
