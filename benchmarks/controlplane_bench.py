"""Control-plane friction sweep: the paper's R-score packers vs
realistically configured reactive scalers, over a delay x cooldown grid.

``repro.lagsim.controlplane`` makes scaler *friction* -- polling cadence,
metric-pipeline delays, cooldown windows, rebalance warm-up storms -- a
first-class, scan-safe part of the closed-loop twin.  This benchmark
quantifies what that friction costs: every policy in ``POLICIES`` runs
the bursty scenario suite under

* ``zero_friction`` -- an explicit all-defaults :class:`ControlPlaneConfig`
  (polling every step, no delays, no cooldown, no warm-up), which the
  equivalence goldens pin to be bit-identical to the bare engine, so
  these rows match the bursty family in ``BENCH_lagsim.json``; and
* a ``d{delay}_c{cooldown}`` grid (DELAYS x COOLDOWNS, polling every
  ``POLLING`` steps, ``WARMUP`` warm-up steps) where ``delay`` sets both
  the observation and the actuation delay -- the two hops of the
  KEDA / Cloud Run metric-read -> Admin-API pipeline.

The REAL reactive scalers (``KEDA_LAG_REAL``, ``CLOUD_RUN_CPU_LAG``)
declare the control-plane knobs as hyperparameters, so the same grid
overrides reconfigure their self-wrapped control plane in place; the
R-score packers are engine-wrapped with the identical config.  Per
(config, policy) the batch-averaged SLO metrics (violation_frac,
time_to_drain, consumer_seconds, ...) go to ``BENCH_controlplane.json``.

``--smoke`` (CI) runs a reduced grid and asserts, exactly:

* the ``zero_friction`` rows are bit-identical to a bare
  (``control_plane=None``) run for every non-REAL policy;
* every metric is finite, with ``violation_frac`` in [0, 1];
* friction is not free on this pinned workload: no grid cell beats
  ``zero_friction`` mean violation_frac by more than ``SMOKE_TOL``.

Run:  PYTHONPATH=src:. python benchmarks/run.py            (controlplane_* rows)
or    PYTHONPATH=src:. python benchmarks/controlplane_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import math
import os
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.api import BenchReport, ControlPlaneConfig, default_fleet
from repro.core.scenarios import SCENARIO_FAMILIES, scenario_suite
from repro.lagsim import LagSimConfig

from benchmarks.sections import observability_block, section, telemetry_block

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_controlplane.json")

# Workload constants mirror benchmarks/lag_slo.py so the zero-friction
# rows are directly comparable with BENCH_lagsim's bursty family.
BATCH = 2
ITERS = 48
N_PARTITIONS = 10
CAPACITY = 1.0
SEED = 0
FAMILY = "bursty"

# >= 3 R-score policies vs >= 2 reactive scalers (ISSUE acceptance).
RSCORE_POLICIES = ("BFD", "MBFP", "MWFP")
REACTIVE_POLICIES = ("KEDA_LAG_REAL", "CLOUD_RUN_CPU_LAG")
POLICIES = RSCORE_POLICIES + REACTIVE_POLICIES

# delay x cooldown grid (>= 3x3).  ``delay`` drives observation AND
# actuation delay; polling/warm-up are held fixed across the grid.
DELAYS = (0, 1, 3)
COOLDOWNS = (0, 6, 12)
POLLING = 2
WARMUP = 2

REPORT_METRICS = ("violation_frac", "time_to_drain", "consumer_seconds")
SMOKE_TOL = 1e-6


def _traces(seed: int):
    """The bursty batch, keyed exactly as in benchmarks/lag_slo.py.

    ``scenario_suite`` splits its key by family *position*, so the full
    family list must be generated for the bursty entry to be the same
    array BENCH_lagsim ran -- that identity is what makes the
    zero_friction rows comparable across the two artifacts."""
    suite = scenario_suite(jax.random.key(seed), BATCH, ITERS, N_PARTITIONS,
                           capacity=CAPACITY,
                           families=tuple(SCENARIO_FAMILIES))
    return suite[FAMILY]


def _grid(delays: Sequence[int] = DELAYS,
          cooldowns: Sequence[int] = COOLDOWNS,
          ) -> Dict[str, Optional[ControlPlaneConfig]]:
    """Config label -> ControlPlaneConfig, zero_friction first."""
    configs: Dict[str, Optional[ControlPlaneConfig]] = {
        "zero_friction": ControlPlaneConfig(),
    }
    for d in delays:
        for c in cooldowns:
            configs[f"d{d}_c{c}"] = ControlPlaneConfig(
                polling_interval=POLLING,
                observation_delay=d,
                actuation_delay=d,
                cooldown_period=c,
                warmup_steps=WARMUP,
            )
    return configs


def _sweep(fleet, policies: Tuple[str, ...], traces,
           cp: Optional[ControlPlaneConfig]) -> Dict[str, Dict[str, float]]:
    """One fleet run -> {policy: {metric: batch-mean}}."""
    cfg = LagSimConfig(capacity=CAPACITY, dt=1.0, migration_steps=2,
                       control_plane=cp)
    res = fleet.simulate(policies, traces, cfg)
    summary = res.summarize(cfg)                       # {metric: [P, B]}
    return {
        pol: {metric: float(np.mean(vals[p]))
              for metric, vals in summary.items()}
        for p, pol in enumerate(policies)
    }


def run(policies: Sequence[str] = POLICIES,
        delays: Sequence[int] = DELAYS,
        cooldowns: Sequence[int] = COOLDOWNS,
        seed: int = SEED,
        write: bool = True) -> Dict:
    """Full sweep -> nested result dict (written to BENCH_controlplane.json)."""
    policies = tuple(p.upper() for p in policies)
    traces = _traces(seed)
    fleet = default_fleet()

    configs = _grid(delays, cooldowns)
    per_config: Dict[str, Dict[str, Dict[str, float]]] = {
        label: _sweep(fleet, policies, traces, cp)
        for label, cp in configs.items()
    }

    report = BenchReport(
        kind="controlplane",
        config={
            "batch": BATCH, "iters": ITERS, "n_partitions": N_PARTITIONS,
            "capacity": CAPACITY, "seed": seed, "family": FAMILY,
            "policies": list(policies),
            "delays": list(delays), "cooldowns": list(cooldowns),
            "polling_interval": POLLING, "warmup_steps": WARMUP,
            "grid": {label: (dict(cp.knobs()) if cp is not None else None)
                     for label, cp in configs.items()},
        },
        families=per_config,
        extra={"telemetry": telemetry_block(),
               "observability": observability_block(seed=seed)},
    )
    out = report.as_dict()
    if write:
        out = report.write(BENCH_PATH)
    return out


@section("controlplane", prefixes=("controlplane_",),
         bench_json="BENCH_controlplane.json")
def _rows():
    out = run()                 # also writes BENCH_controlplane.json
    for label, per_policy in out["families"].items():
        for pol, metrics in per_policy.items():
            for metric in REPORT_METRICS:
                yield (f"controlplane_{label}_{pol}_{metric},0,"
                       f"{metrics[metric]:.6f}")


# ---------------------------------------------------------------------------
# correctness smoke (CI: zero-friction == bare, grid sanity)
# ---------------------------------------------------------------------------

def smoke(seed: int = SEED) -> None:
    policies = POLICIES
    traces = _traces(seed)
    fleet = default_fleet()

    # Reduced grid: corners only, to keep CI wall time bounded.
    out = run(policies=policies, delays=(0, DELAYS[-1]),
              cooldowns=(0, COOLDOWNS[-1]), seed=seed, write=False)
    per_config = out["families"]

    # 1) zero-friction == bare engine, bit-for-bit, for every policy that
    #    does not carry its own registered control plane.  (The REAL
    #    scalers legitimately differ: with control_plane=None they keep
    #    their registered friction defaults; the zero_friction grid cell
    #    overrides those to the identity.)
    bare = _sweep(fleet, policies, traces, None)
    zf = per_config["zero_friction"]
    for pol in RSCORE_POLICIES:
        for metric, val in bare[pol].items():
            assert zf[pol][metric] == val, (pol, metric, zf[pol][metric], val)

    # 2) every reported metric is finite; violation_frac is a fraction.
    for label, per_policy in per_config.items():
        for pol, metrics in per_policy.items():
            for metric, val in metrics.items():
                assert math.isfinite(val), (label, pol, metric, val)
            assert 0.0 <= metrics["violation_frac"] <= 1.0, (label, pol)

    # 3) friction is not free on this pinned workload: averaged over the
    #    policy set, no frictionful cell beats zero_friction on
    #    violation_frac beyond float tolerance.
    def mean_viol(per_policy):
        return float(np.mean([m["violation_frac"]
                              for m in per_policy.values()]))

    base = mean_viol(zf)
    for label, per_policy in per_config.items():
        if label == "zero_friction":
            continue
        assert mean_viol(per_policy) >= base - SMOKE_TOL, (
            label, mean_viol(per_policy), base)

    print(f"controlplane smoke OK: {len(per_config) - 1} grid cells, "
          f"{len(policies)} policies, zero-friction == bare for "
          f"{len(RSCORE_POLICIES)} R-score policies "
          f"(mean violation_frac {base:.4f} at zero friction)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="reduced grid + exact zero-friction/bare "
                             "equivalence asserts (CI)")
    args = parser.parse_args()
    if args.smoke:
        smoke()
        return
    out = run()
    print(f"wrote {BENCH_PATH}")
    zf = out["families"]["zero_friction"]
    worst = out["families"][f"d{DELAYS[-1]}_c{COOLDOWNS[-1]}"]
    for pol in POLICIES:
        print(f"{pol:>18s}: violation_frac "
              f"{zf[pol]['violation_frac']:.3f} (zero friction) -> "
              f"{worst[pol]['violation_frac']:.3f} "
              f"(d={DELAYS[-1]}, c={COOLDOWNS[-1]})")


if __name__ == "__main__":
    main()
