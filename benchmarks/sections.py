"""Self-registering benchmark sections for ``benchmarks/run.py``.

Each benchmark module owns its CSV rows: it registers a runner under a
section name together with the row prefixes it is allowed to emit (and,
when it writes one, its ``BENCH_*.json`` artifact).  ``run.py`` just
replays the registry in registration order, so a section's rows can never
silently drift from (or outlive) the module that computes them --
``emit_all`` raises if a runner emits a row outside its declared
prefixes.

Registering a section::

    from benchmarks.sections import section

    @section("fig6_cbs", prefixes=("fig6_cbs_",))
    def rows():
        yield f"fig6_cbs_d5_BFD,0,{value:.6f}"
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

#: ``dispatch_us`` (dispatch-only steady time, see ``packer_latency``)
#: and ``fused_us`` (steady time of the same work on the fused
#: multi-step path, see the lag-twin rows) are optional -- shorter rows
#: are padded with empty trailing fields
HEADER = "name,us_per_call,derived,dispatch_us,fused_us"
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def telemetry_block(event_counts: Optional[Dict[str, int]] = None,
                    extra: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """The shared ``telemetry`` block every ``BENCH_*.json`` carries:
    per-span duration summary (first-call vs steady-state) from the
    process-wide tracer, plus optional recorder event counts."""
    from repro.telemetry.spans import default_tracer

    tracer = default_tracer()
    block: Dict[str, Any] = {
        "spans": tracer.summary(),
        "spans_dropped": tracer.dropped,
    }
    if event_counts is not None:
        block["event_counts"] = dict(event_counts)
    if extra:
        block.update(extra)
    return block


#: sketch channels the ``observability`` block keeps per policy -- the
#: full sketch carries every recorder channel; BENCH JSONs only embed
#: the ones operators actually compare across runs
OBSERVABILITY_CHANNELS = ("lag_total", "consumers", "unreadable")


def observability_block(policies: Tuple[str, ...] = ("MBFP", "KEDA_LAG"),
                        batch: int = 2, iters: int = 32, n: int = 6,
                        seed: int = 0) -> Dict[str, Any]:
    """The shared ``observability`` block: a fixed-seed sketch + alerts
    probe (frames off, ``topic_lifecycle`` -- the churniest family) run
    through the fleet, so every ``BENCH_*.json`` carries whole-run
    sketch summaries and per-rule incident roll-ups.

    ``bench_diff`` gates on the incident leaves (more incidents or
    longer burn than the baseline = regression); the sketch statistics
    stay informational.
    """
    import jax
    import numpy as np

    from repro.api import default_fleet
    from repro.core.scenarios import generate_masked_scenario
    from repro.lagsim import LagSimConfig
    from repro.telemetry import (AlertConfig, SketchConfig, TelemetryConfig,
                                 default_rules, incident_summary,
                                 merge_summaries)

    speeds, active = generate_masked_scenario(
        "topic_lifecycle", jax.random.key(seed), batch, iters, n)
    tele = TelemetryConfig(record_frames=False, sketch=SketchConfig(),
                           alerts=AlertConfig(rules=default_rules()))
    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2,
                       telemetry=tele)
    res = default_fleet().simulate(tuple(p.upper() for p in policies),
                                   speeds, cfg, active=active)
    per_policy: Dict[str, Any] = {}
    for p, pol in enumerate(res.policies):
        merged = merge_summaries([
            s for b in range(len(res.sketch))
            for idx, s in res.sketch_summaries(b) if idx[0] == p])
        full = merged.as_dict()
        state_p = jax.tree_util.tree_map(
            lambda *xs: np.stack(xs)[:, p], *res.incidents)
        per_policy[pol] = {
            "sketch": {
                "steps": full["count"],
                "channels": {ch: full["channels"][ch]
                             for ch in OBSERVABILITY_CHANNELS
                             if ch in full["channels"]},
            },
            "incidents": incident_summary(state_p, res.alert_config,
                                          dt=res.dt),
        }
    return {
        "probe": {
            "family": "topic_lifecycle", "policies": list(res.policies),
            "batch": batch, "iters": iters, "n_partitions": n, "seed": seed,
            "rules": list(res.alert_config.rule_names),
        },
        "per_policy": per_policy,
    }


@dataclasses.dataclass(frozen=True)
class Section:
    name: str                     # section id (registration order = run order)
    runner: Callable[[], Iterable[str]]   # yields "name,us,derived" rows
    prefixes: Tuple[str, ...]     # every emitted row must start with one
    bench_json: Optional[str]     # artifact the runner writes, if any


SECTIONS: List[Section] = []


def section(name: str, *, prefixes: Tuple[str, ...],
            bench_json: Optional[str] = None) -> Callable:
    """Decorator: register ``runner`` as benchmark section ``name``."""

    def deco(runner: Callable[[], Iterable[str]]) -> Callable:
        if any(s.name == name for s in SECTIONS):
            raise ValueError(f"benchmark section {name!r} already registered")
        SECTIONS.append(Section(name=name, runner=runner,
                                prefixes=tuple(prefixes),
                                bench_json=bench_json))
        return runner

    return deco


def emit_all(print_fn: Callable[[str], None] = print) -> None:
    """Run every registered section in registration order, printing its
    rows.  A row outside the section's declared prefixes is an error, and
    a section declaring a ``bench_json`` artifact must actually (re)write
    it at the repo root during its run."""
    print_fn(HEADER)
    n_cols = HEADER.count(",")
    for sec in SECTIONS:
        t0 = time.time()
        for row in sec.runner():
            if not row.startswith(sec.prefixes):
                raise RuntimeError(
                    f"section {sec.name!r} emitted row {row.split(',')[0]!r} "
                    f"outside its declared prefixes {sec.prefixes}")
            missing = n_cols - row.count(",")
            if missing < 0:
                raise RuntimeError(
                    f"section {sec.name!r} emitted row {row.split(',')[0]!r} "
                    f"with more fields than the header {HEADER!r}")
            print_fn(row + "," * missing)   # pad optional trailing columns
        if sec.bench_json is not None:
            path = os.path.join(REPO_ROOT, sec.bench_json)
            if not os.path.exists(path) or os.path.getmtime(path) < t0 - 1.0:
                raise RuntimeError(
                    f"section {sec.name!r} declared bench_json="
                    f"{sec.bench_json!r} but did not write it")
