"""Fleet-execution benchmark: steady-state throughput per shape bucket,
plus the sharded-equals-single-device correctness smoke.

A mixed fleet of masked scenarios (always-on families plus the true-mask
``churn`` / ``topic_lifecycle`` ones) runs through ``repro.fleet`` at
several padded ``(T, N)`` buckets.  Per bucket the benchmark reports
*steady-state* fleet throughput in scenarios*steps/s -- first-call
(compile) time is measured separately, never folded in -- for both verbs
(packing sweep and the closed-loop lag twin), and writes everything to
``BENCH_fleet.json`` under the shared ``BenchReport`` envelope together
with the runner's cache statistics.

Per bucket the JSON also splits the first call into its span-measured
parts -- ``*_trace_lower_us`` / ``*_compile_us`` / ``*_first_dispatch_us``
(the runner compiles ahead-of-time, so first dispatch no longer conflates
XLA compilation with dispatch) -- and carries the shared ``telemetry``
block plus ``runner_stats`` with the per-bucket hit/miss breakdown.

``--smoke`` (CI) additionally asserts, exactly:

* an all-active fleet sweep equals the direct ``sweep_streams`` result;
* a fleet sharded over *all* host devices equals the same fleet pinned
  to a single device, for both verbs, masks included.  Run it under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to make the
  check non-trivial on CPU hosts.

and writes the whole run as a validated Chrome/Perfetto trace to
``trace_fleet_smoke.json`` (uploaded as a CI artifact).

Run:  PYTHONPATH=src:. python benchmarks/run.py          (fleet_* rows)
or    PYTHONPATH=src:. python benchmarks/fleet_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax

from repro.api import BenchReport
from repro.core.scenarios import generate_masked_scenario
from repro.fleet import FleetConfig, FleetRunner
from repro.lagsim import LagSimConfig
from repro.telemetry import default_tracer, validate_chrome_trace

from benchmarks.sections import observability_block, section, telemetry_block

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_fleet.json")
TRACE_PATH = os.path.join(REPO_ROOT, "trace_fleet_smoke.json")

ALGORITHMS = ("BFD", "MBFP")
POLICIES = ("BFD", "MBFP", "KEDA_LAG")
FAMILIES = ("bursty", "churn", "topic_lifecycle")

#: benchmarked buckets: (T, N, scenarios per family)
BUCKETS: Tuple[Tuple[int, int, int], ...] = ((32, 8, 2), (64, 12, 2))
SMOKE_BUCKETS: Tuple[Tuple[int, int, int], ...] = ((16, 5, 1),)


def _fleet_for(t: int, n: int, per_family: int, seed: int
               ) -> List[Tuple[jax.Array, jax.Array]]:
    """``per_family`` masked scenarios of every family at shape (t, n)."""
    out = []
    for i, fam in enumerate(FAMILIES):
        speeds, active = generate_masked_scenario(
            fam, jax.random.key(seed + i), per_family, t, n)
        out.extend((speeds[b], active[b]) for b in range(per_family))
    return out


def _throughput(fn, scenarios_steps: int, reps: int = 3
                ) -> Tuple[float, float]:
    """-> (first_call_us, steady scenarios*steps/s)."""
    t0 = time.perf_counter()
    fn()
    first_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    steady_s = (time.perf_counter() - t0) / reps
    return first_us, scenarios_steps / steady_s if steady_s > 0 else 0.0


def _span_breakdown(verb: str, recs) -> Dict[str, float]:
    """Compile-vs-dispatch split of one verb's first call, from its span
    records: first-call wall time used to conflate XLA compilation with
    the first dispatch; these fields pin them apart in the JSON."""
    total = lambda name: sum(r.dur_us for r in recs if r.name == name)
    first_disp = [r.dur_us for r in recs
                  if r.name == "fleet.dispatch" and r.args.get("first")]
    return {
        f"{verb}_trace_lower_us": total("fleet.trace_lower"),
        f"{verb}_compile_us": total("fleet.compile"),
        f"{verb}_first_dispatch_us": first_disp[0] if first_disp else 0.0,
    }


def run(buckets: Sequence[Tuple[int, int, int]] = BUCKETS,
        seed: int = 0) -> Dict:
    """Per-bucket steady-state fleet throughput -> BENCH_fleet.json."""
    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
    runner = FleetRunner(FleetConfig(
        t_buckets=tuple(sorted({t for t, _, _ in buckets})),
        n_buckets=tuple(sorted({n for _, n, _ in buckets}))))
    tracer = default_tracer()
    per_bucket: Dict[str, Dict[str, float]] = {}
    for t, n, per_family in buckets:
        scen = _fleet_for(t, n, per_family, seed)
        steps = len(scen) * t
        n0 = len(tracer.records())
        sweep_first, sweep_tp = _throughput(
            lambda: runner.sweep(ALGORITHMS, scen, 1.0), steps)
        n1 = len(tracer.records())
        sim_first, sim_tp = _throughput(
            lambda: runner.simulate(POLICIES, scen, cfg), steps)
        recs = tracer.records()
        per_bucket[f"{t}x{n}"] = {
            "scenarios": len(scen),
            "steps_per_scenario": t,
            "sweep_scenario_steps_per_s": sweep_tp,
            "sweep_first_call_us": sweep_first,
            "simulate_scenario_steps_per_s": sim_tp,
            "simulate_first_call_us": sim_first,
            **_span_breakdown("sweep", recs[n0:n1]),
            **_span_breakdown("simulate", recs[n1:]),
        }
    report = BenchReport(
        kind="fleet",
        config={
            "algorithms": list(ALGORITHMS), "policies": list(POLICIES),
            "families": list(FAMILIES), "seed": seed,
            "devices": len(jax.devices()),
            "buckets": [list(b) for b in buckets],
        },
        families=per_bucket,
        extra={
            "runner_stats": runner.stats(),
            "telemetry": telemetry_block(),
            "observability": observability_block(seed=seed),
        },
    )
    return report.write(BENCH_PATH)


# ---------------------------------------------------------------------------
# correctness smoke (CI: sharded == single-device, fleet == direct)
# ---------------------------------------------------------------------------

def smoke(seed: int = 0) -> None:
    from repro.core.jaxpack import sweep_streams

    n_dev = len(jax.devices())
    rng = np.random.default_rng(seed)
    traces = np.asarray(rng.uniform(0, 1, (6, 20, 7)), np.float32)
    masks = rng.integers(0, 2, traces.shape).astype(bool)

    sharded = FleetRunner(FleetConfig(shard=True))
    single = FleetRunner(FleetConfig(devices=(jax.devices()[0],)))

    # 1) all-active fleet sweep == direct sweep_streams, exactly
    res = sharded.sweep(ALGORITHMS, traces, 1.0)
    direct = sweep_streams(ALGORITHMS, traces, 1.0)
    bins, rscores, migs = res.stacked()
    assert np.array_equal(bins, np.asarray(direct.bins))
    assert rscores.tobytes() == np.asarray(direct.rscores).tobytes()
    assert np.array_equal(migs, np.asarray(direct.migrations))

    # 2) sharded == single-device for both verbs, masked and unmasked
    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
    for active in (None, masks):
        a = sharded.sweep(ALGORITHMS, traces, 1.0, active=active)
        b = single.sweep(ALGORITHMS, traces, 1.0, active=active)
        for i in range(traces.shape[0]):
            assert np.array_equal(a.bins[i], b.bins[i]), i
            assert a.rscores[i].tobytes() == b.rscores[i].tobytes(), i
        c = sharded.simulate(POLICIES, traces, cfg, active=active)
        d = single.simulate(POLICIES, traces, cfg, active=active)
        for i in range(traces.shape[0]):
            assert c.lag_total[i].tobytes() == d.lag_total[i].tobytes(), i
            assert np.array_equal(c.consumers[i], d.consumers[i]), i
            assert np.array_equal(c.migrations[i], d.migrations[i]), i

    out = run(buckets=SMOKE_BUCKETS, seed=seed)
    assert os.path.exists(BENCH_PATH)

    # Perfetto trace artifact: the whole smoke as a host timeline
    trace = default_tracer().write(TRACE_PATH)
    validate_chrome_trace(trace)
    names = {ev["name"] for ev in trace["traceEvents"]}
    for required in ("fleet.trace_lower", "fleet.compile", "fleet.dispatch"):
        assert required in names, (
            f"span {required!r} missing from the fleet trace: {names}")
    print(f"fleet smoke OK on {n_dev} device(s): sharded == single-device, "
          f"fleet == direct; wrote {BENCH_PATH} "
          f"({sorted(out['families'])} buckets); Perfetto trace "
          f"({len(trace['traceEvents'])} events) -> {TRACE_PATH}")


@section("fleet", prefixes=("fleet_",), bench_json="BENCH_fleet.json")
def _rows():
    out = run()                       # also writes BENCH_fleet.json
    for bucket, vals in sorted(out["families"].items()):
        for verb in ("sweep", "simulate"):
            yield (f"fleet_{verb}_{bucket},"
                   f"{vals[f'{verb}_first_call_us']:.1f},"
                   f"{vals[f'{verb}_scenario_steps_per_s']:.1f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert sharded == single-device (+ direct-engine "
                         "parity) on tiny sizes, then write BENCH_fleet.json")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    out = run()
    print(f"wrote {BENCH_PATH}")
    for bucket, vals in sorted(out["families"].items()):
        print(f"  {bucket}: sweep {vals['sweep_scenario_steps_per_s']:.0f} "
              f"scen*steps/s, simulate "
              f"{vals['simulate_scenario_steps_per_s']:.0f} scen*steps/s "
              f"({vals['scenarios']} scenarios)")


if __name__ == "__main__":
    main()
