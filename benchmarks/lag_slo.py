"""Lag-SLO sweep: every packing algorithm + both reactive baselines x all
scenario families, through the closed-loop twin (``repro.lagsim``)
executed on the fleet layer (``repro.api.default_fleet``).

For each family a batch of traces runs under every policy in one vmapped
XLA program (compiled once across families via the fleet's bounded
bucket cache, sharded over available devices); the per-(policy, stream)
SLO metrics (peak lag, violation fraction, time-to-drain,
consumer-seconds, migrations) are averaged over the batch and written to
``BENCH_lagsim.json`` at the repo root -- the start of the perf/SLO
trajectory the ROADMAP asks for.

The file also records the speed claim behind the subsystem: wall time per
simulated (stream, step) for the batched twin vs the Python object loop
(``serving/simulation.py``) on a same-sized workload.  The acceptance bar
is a >= 50x advantage; on CPU the measured gap is orders of magnitude.

The ``timing.fused`` block records the fused multi-step path
(``LagSimConfig.fused_steps``, the ROADMAP megakernel item): steady-state
wall time per (stream, step) of a heuristic-family sweep at paper shapes
(N=10, long T), per-step scan vs fused, plus the measured speedup.
``bench_diff`` gates the ``fused_*`` throughput/speedup leaves
higher-is-better, so the fused path cannot silently slow back down.

The ``telemetry`` block of the JSON carries the flight-recorder view of
the same run: host-side span summaries (``api.*`` / ``fleet.*``, compile
split from dispatch) plus in-loop event counts from a telemetry-on
``topic_lifecycle`` probe.  ``--smoke`` (CI) runs a tiny telemetry-on
sweep end to end: decodes the event stream (must be non-empty), writes a
Chrome/Perfetto trace to ``trace_lag_smoke.json`` and validates it --
without touching the checked-in ``BENCH_lagsim.json``.

Run:  PYTHONPATH=src:. python benchmarks/run.py          (lagsim_* rows)
or    PYTHONPATH=src:. python benchmarks/lag_slo.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from repro.api import BenchReport, default_fleet
from repro.core.scenarios import SCENARIO_FAMILIES, scenario_suite
from repro.lagsim import LagSimConfig
from repro.registry import list_policies
from repro.serving import AutoscaleSimulation
from repro.telemetry import (EventStream, TelemetryConfig, default_tracer,
                             validate_chrome_trace)

from benchmarks.sections import observability_block, section, telemetry_block

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_lagsim.json")
TRACE_PATH = os.path.join(REPO_ROOT, "trace_lag_smoke.json")
PROM_PATH = os.path.join(REPO_ROOT, "metrics_lag_smoke.prom")
INCIDENTS_PATH = os.path.join(REPO_ROOT, "incidents_lag_smoke.json")

BATCH = 2
ITERS = 48
N_PARTITIONS = 10
CAPACITY = 1.0
SEED = 0

#: fused-path probe: the paper-shaped steady-state workload the ROADMAP
#: megakernel item is measured on (heuristic family only -- the policies
#: ``fused_steps`` accelerates; long T so dispatch amortizes)
FUSED_ITERS = 480
FUSED_STEPS = 8
FUSED_POLICIES = ("NF", "NFD", "FF", "FFD", "BF", "BFD", "WF", "WFD")


def _python_loop_us_per_step(n: int, steps: int = 120) -> float:
    """Wall time per tick of the Python closed loop on one stream."""
    cap = 1.0e6
    rates = [0.35e6 + 0.04e6 * i for i in range(n)]
    sim = AutoscaleSimulation(
        n_partitions=n, rate_fn=AutoscaleSimulation.constant_rates(rates),
        capacity=cap, algorithm="BFD", monitor_interval=5.0)
    sim.run(seconds=10, dt=1.0)            # warm up past consumer creation
    t0 = time.perf_counter()
    sim.run(seconds=steps, dt=1.0)
    return (time.perf_counter() - t0) / steps * 1e6


def _fused_timing(n: int, seed: int, batch: int = BATCH,
                  iters: int = FUSED_ITERS, reps: int = 3) -> Dict[str, float]:
    """Steady state of the heuristic-family sweep, per-step scan vs the
    fused multi-step path (``LagSimConfig.fused_steps``), on one
    paper-shaped workload (both compiled first; mean of ``reps`` warm
    calls).  Throughput/speedup leaves are ``fused_``-prefixed so
    ``bench_diff`` gates them higher-is-better; the ``*_us_per_*``
    latency leaves gate lower-is-better as usual."""
    import dataclasses

    from repro.core.scenarios import generate_scenario
    from repro.lagsim import sweep_lag

    traces = generate_scenario("bursty", jax.random.key(seed), batch,
                               iters, n)
    base = LagSimConfig(capacity=CAPACITY, dt=1.0, migration_steps=2)
    steady: Dict[str, float] = {}
    for name, cfg in (("scan", base),
                      ("fused", dataclasses.replace(
                          base, fused_steps=FUSED_STEPS))):
        def once(cfg=cfg):
            jax.block_until_ready(
                sweep_lag(FUSED_POLICIES, traces, cfg).lag_total)
        once()                               # trace + compile + run
        t0 = time.perf_counter()
        for _ in range(reps):
            once()
        steady[name] = (time.perf_counter() - t0) / reps
    denom = len(FUSED_POLICIES) * batch * iters   # policy-stream-steps
    return {
        "k_steps": FUSED_STEPS,
        "n_policies": len(FUSED_POLICIES),
        "batch": batch, "iters": iters,
        "scan_us_per_stream_step": steady["scan"] * 1e6 / denom,
        "fused_us_per_stream_step": steady["fused"] * 1e6 / denom,
        "fused_steps_per_s": denom / steady["fused"],
        "fused_speedup_vs_scan": steady["scan"] / steady["fused"],
    }


def run(batch: int = BATCH, iters: int = ITERS, n: int = N_PARTITIONS,
        policies: Optional[Sequence[str]] = None,
        families: Sequence[str] = tuple(SCENARIO_FAMILIES),
        seed: int = SEED) -> Dict:
    """Full sweep -> nested result dict (also written to BENCH_lagsim.json).

    ``policies`` defaults to every jax-backend policy in the registry
    (packers + reactive baselines + optimizers, in registration order)."""
    if policies is None:
        policies = list_policies(backend="jax")
    policies = tuple(p.upper() for p in policies)
    cfg = LagSimConfig(capacity=CAPACITY, dt=1.0, migration_steps=2)
    suite = scenario_suite(jax.random.key(seed), batch, iters, n,
                           capacity=CAPACITY, families=tuple(families))

    per_family: Dict[str, Dict[str, Dict[str, float]]] = {}
    seconds: Dict[str, float] = {}
    fleet = default_fleet()
    for fam, traces in suite.items():
        fleet.simulate(policies, traces, cfg)                # compile / warm
        t0 = time.perf_counter()
        res = fleet.simulate(policies, traces, cfg)          # numpy out: synced
        seconds[fam] = time.perf_counter() - t0
        summary = res.summarize(cfg)                         # {metric: [P, B]}
        per_family[fam] = {
            pol: {metric: float(np.mean(vals[p]))
                  for metric, vals in summary.items()}
            for p, pol in enumerate(policies)
        }

    jax_us = float(np.mean(list(seconds.values()))) * 1e6 / (
        len(policies) * batch * iters)
    py_us = _python_loop_us_per_step(n)
    fused = _fused_timing(n=n, seed=seed)
    # flight-recorder probe: one telemetry-on lifecycle run for event
    # counts (the timed sweep above stays recorder-free)
    counts = _event_counts(policies[:2], batch, iters, n, seed)
    report = BenchReport(
        kind="lagsim",
        config={
            "batch": batch, "iters": iters, "n_partitions": n,
            "capacity": CAPACITY, "migration_steps": cfg.migration_steps,
            "slo_lag": cfg.resolve(n).slo_lag, "seed": seed,
            "policies": list(policies), "families": list(suite),
        },
        families=per_family,
        extra={
            "timing": {
                "lagsim_us_per_stream_step": jax_us,
                "python_us_per_step": py_us,
                "speedup_vs_python": (py_us / jax_us if jax_us > 0
                                      else float("inf")),
                "sweep_seconds_per_family": seconds,
                "fused": fused,
            },
            "telemetry": telemetry_block(event_counts=counts),
            "observability": observability_block(seed=seed),
        },
    )
    return report.write(BENCH_PATH)


def _event_counts(policies: Sequence[str], batch: int, iters: int, n: int,
                  seed: int) -> Dict[str, int]:
    """Aggregate decoded event counts of a telemetry-on ``topic_lifecycle``
    fleet run (the churniest family: scale + migration + lifecycle)."""
    from repro.core.scenarios import generate_masked_scenario

    speeds, active = generate_masked_scenario(
        "topic_lifecycle", jax.random.key(seed), batch, iters, n)
    cfg = LagSimConfig(capacity=CAPACITY, dt=1.0, migration_steps=2,
                       telemetry=TelemetryConfig())
    res = default_fleet().simulate(policies, speeds, cfg, active=active)
    counts: Dict[str, int] = {}
    for frame in res.telemetry:
        for kind, c in EventStream.from_frame(frame).counts().items():
            counts[kind] = counts.get(kind, 0) + c
    return counts


@section("lagsim", prefixes=("lagsim_",), bench_json="BENCH_lagsim.json")
def _rows():
    lag = run()                 # also writes BENCH_lagsim.json
    for fam, per_policy in sorted(lag["families"].items()):
        for pol, metrics in per_policy.items():
            for metric in ("violation_frac", "consumer_seconds",
                           "total_migrations"):
                yield (f"lagsim_{fam}_{pol}_{metric},0,"
                       f"{metrics[metric]:.6f}")
    yield (f"lagsim_speedup_vs_python,"
           f"{lag['timing']['lagsim_us_per_stream_step']:.1f},"
           f"{lag['timing']['speedup_vs_python']:.1f}")
    fused = lag["timing"]["fused"]
    # fused_us column = the same steady step on the fused path
    yield (f"lagsim_fused_speedup_vs_scan,"
           f"{fused['scan_us_per_stream_step']:.3f},"
           f"{fused['fused_speedup_vs_scan']:.2f},,"
           f"{fused['fused_us_per_stream_step']:.3f}")


def smoke(seed: int = SEED) -> None:
    """CI: a tiny telemetry-on sweep must yield a decodable, non-empty
    event stream and a valid Perfetto trace; a sketch+alerts run through
    ``repro.api.simulate`` must export a lintable Prometheus scrape body
    (``metrics_lag_smoke.prom``) and a decoded incident JSON
    (``incidents_lag_smoke.json``), both uploaded as CI artifacts.  Does
    not touch the checked-in ``BENCH_lagsim.json``."""
    import json

    from repro.api import simulate
    from repro.telemetry import (AlertConfig, SketchConfig, TelemetryConfig,
                                 default_rules, merge_summaries,
                                 prometheus_exposition, validate_exposition)
    from repro.core.scenarios import generate_masked_scenario

    policies = ("MBFP", "KEDA_LAG")
    counts = _event_counts(policies, batch=2, iters=24, n=6, seed=seed)
    assert counts, "telemetry-on smoke run decoded no events at all"
    trace = default_tracer().write(TRACE_PATH)
    validate_chrome_trace(trace)
    span_names = {ev["name"] for ev in trace["traceEvents"]}
    for required in ("fleet.simulate", "fleet.compile", "fleet.dispatch"):
        assert required in span_names, (
            f"span {required!r} missing from the smoke trace: {span_names}")

    # sketch + alerts end to end: simulate -> export -> lint
    speeds, active = generate_masked_scenario(
        "topic_lifecycle", jax.random.key(seed), 2, 24, 6)
    out = simulate(speeds, policies=policies, active=active,
                   capacity=CAPACITY, migration_steps=2,
                   telemetry=TelemetryConfig(
                       record_frames=False, sketch=SketchConfig(),
                       alerts=AlertConfig(rules=default_rules())))
    assert out.sketches is not None and out.incidents is not None
    merged = merge_summaries([s for per_scen in out.sketches
                              for s in per_scen])
    incidents = [inc for per_scen in out.incidents for inc in per_scen]
    assert incidents, "sketch+alerts smoke run opened no incidents"
    prom = prometheus_exposition(sketch=merged, incidents=incidents,
                                 spans=default_tracer().summary(),
                                 labels={"probe": "lag_smoke"})
    validate_exposition(prom)
    with open(PROM_PATH, "w") as f:
        f.write(prom)
    with open(INCIDENTS_PATH, "w") as f:
        json.dump([inc.as_dict() for inc in incidents], f, indent=1)
    print(f"lag_slo smoke OK: events {counts}; "
          f"valid Perfetto trace with {len(trace['traceEvents'])} events "
          f"-> {TRACE_PATH}; {len(incidents)} incident(s) -> "
          f"{INCIDENTS_PATH}; lint-clean exposition "
          f"({len(prom.splitlines())} lines) -> {PROM_PATH}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny telemetry-on run: decode events, write + "
                         "validate a Perfetto trace (no BENCH rewrite)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    out = run()
    t = out["timing"]
    print(f"wrote {BENCH_PATH}")
    print(f"lagsim: {t['lagsim_us_per_stream_step']:.2f} us/(stream*step)  "
          f"python loop: {t['python_us_per_step']:.1f} us/step  "
          f"speedup: {t['speedup_vs_python']:.0f}x")
    f = t["fused"]
    print(f"fused (K={f['k_steps']}, heuristics, T={f['iters']}): "
          f"{f['scan_us_per_stream_step']:.3f} -> "
          f"{f['fused_us_per_stream_step']:.3f} us/(stream*step)  "
          f"speedup: {f['fused_speedup_vs_scan']:.2f}x")


if __name__ == "__main__":
    main()
