"""Lag-SLO sweep: every packing algorithm + both reactive baselines x all
scenario families, through the closed-loop twin (``repro.lagsim``)
executed on the fleet layer (``repro.api.default_fleet``).

For each family a batch of traces runs under every policy in one vmapped
XLA program (compiled once across families via the fleet's bounded
bucket cache, sharded over available devices); the per-(policy, stream)
SLO metrics (peak lag, violation fraction, time-to-drain,
consumer-seconds, migrations) are averaged over the batch and written to
``BENCH_lagsim.json`` at the repo root -- the start of the perf/SLO
trajectory the ROADMAP asks for.

The file also records the speed claim behind the subsystem: wall time per
simulated (stream, step) for the batched twin vs the Python object loop
(``serving/simulation.py``) on a same-sized workload.  The acceptance bar
is a >= 50x advantage; on CPU the measured gap is orders of magnitude.

Run:  PYTHONPATH=src:. python benchmarks/run.py          (lagsim_* rows)
or    PYTHONPATH=src:. python benchmarks/lag_slo.py      (JSON only)
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from repro.api import BenchReport, default_fleet
from repro.core.scenarios import SCENARIO_FAMILIES, scenario_suite
from repro.lagsim import LagSimConfig
from repro.registry import list_policies
from repro.serving import AutoscaleSimulation

from benchmarks.sections import section

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_lagsim.json")

BATCH = 2
ITERS = 48
N_PARTITIONS = 10
CAPACITY = 1.0
SEED = 0


def _python_loop_us_per_step(n: int, steps: int = 120) -> float:
    """Wall time per tick of the Python closed loop on one stream."""
    cap = 1.0e6
    rates = [0.35e6 + 0.04e6 * i for i in range(n)]
    sim = AutoscaleSimulation(
        n_partitions=n, rate_fn=AutoscaleSimulation.constant_rates(rates),
        capacity=cap, algorithm="BFD", monitor_interval=5.0)
    sim.run(seconds=10, dt=1.0)            # warm up past consumer creation
    t0 = time.perf_counter()
    sim.run(seconds=steps, dt=1.0)
    return (time.perf_counter() - t0) / steps * 1e6


def run(batch: int = BATCH, iters: int = ITERS, n: int = N_PARTITIONS,
        policies: Optional[Sequence[str]] = None,
        families: Sequence[str] = tuple(SCENARIO_FAMILIES),
        seed: int = SEED) -> Dict:
    """Full sweep -> nested result dict (also written to BENCH_lagsim.json).

    ``policies`` defaults to every jax-backend policy in the registry
    (packers + reactive baselines + optimizers, in registration order)."""
    if policies is None:
        policies = list_policies(backend="jax")
    policies = tuple(p.upper() for p in policies)
    cfg = LagSimConfig(capacity=CAPACITY, dt=1.0, migration_steps=2)
    suite = scenario_suite(jax.random.key(seed), batch, iters, n,
                           capacity=CAPACITY, families=tuple(families))

    per_family: Dict[str, Dict[str, Dict[str, float]]] = {}
    seconds: Dict[str, float] = {}
    fleet = default_fleet()
    for fam, traces in suite.items():
        fleet.simulate(policies, traces, cfg)                # compile / warm
        t0 = time.perf_counter()
        res = fleet.simulate(policies, traces, cfg)          # numpy out: synced
        seconds[fam] = time.perf_counter() - t0
        summary = res.summarize(cfg)                         # {metric: [P, B]}
        per_family[fam] = {
            pol: {metric: float(np.mean(vals[p]))
                  for metric, vals in summary.items()}
            for p, pol in enumerate(policies)
        }

    jax_us = float(np.mean(list(seconds.values()))) * 1e6 / (
        len(policies) * batch * iters)
    py_us = _python_loop_us_per_step(n)
    report = BenchReport(
        kind="lagsim",
        config={
            "batch": batch, "iters": iters, "n_partitions": n,
            "capacity": CAPACITY, "migration_steps": cfg.migration_steps,
            "slo_lag": cfg.resolve(n).slo_lag, "seed": seed,
            "policies": list(policies), "families": list(suite),
        },
        families=per_family,
        extra={"timing": {
            "lagsim_us_per_stream_step": jax_us,
            "python_us_per_step": py_us,
            "speedup_vs_python": py_us / jax_us if jax_us > 0 else float("inf"),
            "sweep_seconds_per_family": seconds,
        }},
    )
    return report.write(BENCH_PATH)


@section("lagsim", prefixes=("lagsim_",), bench_json="BENCH_lagsim.json")
def _rows():
    lag = run()                 # also writes BENCH_lagsim.json
    for fam, per_policy in sorted(lag["families"].items()):
        for pol, metrics in per_policy.items():
            for metric in ("violation_frac", "consumer_seconds",
                           "total_migrations"):
                yield (f"lagsim_{fam}_{pol}_{metric},0,"
                       f"{metrics[metric]:.6f}")
    yield (f"lagsim_speedup_vs_python,"
           f"{lag['timing']['lagsim_us_per_stream_step']:.1f},"
           f"{lag['timing']['speedup_vs_python']:.1f}")


def main() -> None:
    out = run()
    t = out["timing"]
    print(f"wrote {BENCH_PATH}")
    print(f"lagsim: {t['lagsim_us_per_stream_step']:.2f} us/(stream*step)  "
          f"python loop: {t['python_us_per_step']:.1f} us/step  "
          f"speedup: {t['speedup_vs_python']:.0f}x")


if __name__ == "__main__":
    main()
