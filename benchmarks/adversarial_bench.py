"""Adversarial robustness benchmark: the worst-case envelope per policy
family, published as ``BENCH_adversarial.json``.

For one representative policy per registry family (first registered jax
policy -- the paper's ordering) the evolutionary scenario search
(``repro.scenarios.search``) evolves the ``adversarial`` genome that
maximizes SLO damage, with burn-rate incidents folded into the fitness
(``incident_weight``), and a uniform random-search baseline run at the
*same* fitness-oracle eval budget.  The JSON records, per family:

* ``worst_violation_frac`` / ``worst_fitness`` / ``worst_incidents`` --
  the worst-case envelope.  ``bench_diff`` gates these with *higher is
  worse* semantics (a code change that lets the search do more damage to
  the same policy is a robustness regression; zero baselines still
  gate);
* the witness genome + decoded knobs that achieve it (the falsifiable
  part: replay it via ``repro.api.replay``), also written as a replayable
  trace ``witness_<family>.npz`` at the repo root (CI artifact);
* ``search_evals_per_s`` -- steady oracle throughput (gated, higher is
  better: every generation after the first must hit the fleet runner's
  warm compile cache);
* the random baseline's best and ``beats_baseline``.

``--smoke`` (CI) asserts, at tiny sizes: a fixed-seed search is
bit-deterministic (identical witness genome twice), and evolution
strictly beats random search at equal evals for >= 2 policy families.

Run:  PYTHONPATH=src:. python benchmarks/run.py        (adversarial_* rows)
or    PYTHONPATH=src:. python benchmarks/adversarial_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.api import BenchReport
from repro.fleet import FleetRunner
from repro.lagsim import LagSimConfig
from repro.scenarios import (SearchConfig, attack, family_representatives,
                             random_search, save_trace)
from repro.telemetry import AlertConfig, TelemetryConfig, default_rules

from benchmarks.sections import observability_block, section, telemetry_block

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_adversarial.json")

#: the full-run search budget (per policy family)
FULL = SearchConfig(pop_size=8, generations=6, iters=96, n=6,
                    incident_weight=0.05)
#: the CI smoke budget (same shape, fewer generations)
SMOKE = SearchConfig(pop_size=8, generations=5, iters=96, n=6,
                     incident_weight=0.05)


def _sim(cfg: SearchConfig) -> LagSimConfig:
    """The fitness oracle's sim config: alerting on whenever incidents
    are a fitness component."""
    if cfg.incident_weight == 0.0:
        return LagSimConfig()
    return LagSimConfig(telemetry=TelemetryConfig(
        record_frames=False, alerts=AlertConfig(rules=default_rules())))


def witness_path(family: str) -> str:
    return os.path.join(REPO_ROOT, f"witness_{family}.npz")


def run(config: SearchConfig = FULL, seed: int = 0,
        families: Optional[Sequence[str]] = None,
        write_witnesses: bool = True) -> Dict:
    """Search every (or the named) registry families' representatives;
    -> the BENCH_adversarial.json dict (also written to disk)."""
    reps = family_representatives()
    if families is not None:
        reps = {f: reps[f] for f in families}
    sim = _sim(config)
    runner = FleetRunner()
    envelope: Dict[str, Any] = {}
    for fam, pol in reps.items():
        t0 = time.perf_counter()
        ev = attack(pol, config=config, sim=sim, seed=seed, runner=runner)
        search_s = time.perf_counter() - t0
        rs = random_search(pol, config=config, sim=sim, seed=seed,
                           runner=runner, evals=ev.evals)
        if write_witnesses:
            save_trace(ev.witness_trace(config, seed=seed),
                       witness_path(fam))
        envelope[fam] = {
            "policy": ev.policy,
            "worst_violation_frac": ev.best_violation_frac,
            "worst_fitness": ev.best_fitness,
            "worst_incidents": ev.best_incidents,
            "witness_genome": [float(g) for g in ev.best_genome],
            "witness_knobs": {k: float(v)
                              for k, v in ev.best_knobs.items()},
            "evals": ev.evals,
            "generations_run": ev.generations_run,
            "search_evals_per_s": (ev.evals / search_s
                                   if search_s > 0 else 0.0),
            "baseline": {"best_fitness": rs.best_fitness,
                         "best_violation_frac": rs.best_violation_frac,
                         "evals": rs.evals},
            "beats_baseline": bool(ev.best_fitness > rs.best_fitness),
        }
    report = BenchReport(
        kind="adversarial",
        config={
            "family": "adversarial", "seed": seed,
            "pop_size": config.pop_size,
            "generations": config.generations,
            "iters": config.iters, "n_partitions": config.n,
            "scenarios_per_genome": config.scenarios_per_genome,
            "incident_weight": config.incident_weight,
            "representatives": dict(reps),
        },
        families=envelope,
        extra={
            "runner_stats": runner.stats(),
            "telemetry": telemetry_block(),
            "observability": observability_block(seed=seed),
        },
    )
    return report.write(BENCH_PATH)


# ---------------------------------------------------------------------------
# correctness smoke (CI: deterministic, beats random, witnesses replay)
# ---------------------------------------------------------------------------

def smoke(seed: int = 0) -> None:
    from repro.scenarios import load_trace

    config = SMOKE
    sim = _sim(config)
    runner = FleetRunner()

    # fixed seed => bit-identical search (the cheapest two families)
    reps = family_representatives()
    for fam in ("heuristic", "reactive"):
        a = attack(reps[fam], config=config, sim=sim, seed=seed,
                   runner=runner)
        b = attack(reps[fam], config=config, sim=sim, seed=seed,
                   runner=runner)
        assert np.array_equal(a.best_genome, b.best_genome), (
            f"{reps[fam]}: fixed-seed search is not deterministic: "
            f"{a.best_genome} vs {b.best_genome}")
        assert a.best_fitness == b.best_fitness, reps[fam]

    out = run(config=config, seed=seed)
    beats = [fam for fam, row in out["families"].items()
             if row["beats_baseline"]]
    assert len(beats) >= 2, (
        f"evolution must strictly beat random search at equal evals for "
        f">= 2 policy families; beat it only for {beats} "
        f"(envelope: { {f: r['worst_fitness'] for f, r in out['families'].items()} }, "
        f"baselines: { {f: r['baseline']['best_fitness'] for f, r in out['families'].items()} })")

    # every witness trace must load, validate, and carry its genome
    for fam, row in out["families"].items():
        tr = load_trace(witness_path(fam))
        assert tr.meta["genome"] == row["witness_genome"], fam
        assert tr.rates.shape == (4, config.iters, config.n), fam
    print(f"adversarial smoke OK: fixed-seed search deterministic, "
          f"evolution > random at equal evals for {len(beats)}/"
          f"{len(out['families'])} families ({', '.join(beats)}); wrote "
          f"{BENCH_PATH} + {len(out['families'])} witness trace(s)")


@section("adversarial", prefixes=("adversarial_",),
         bench_json="BENCH_adversarial.json")
def _rows():
    out = run()                     # also writes BENCH_adversarial.json
    for fam, row in sorted(out["families"].items()):
        us_per_eval = (1e6 / row["search_evals_per_s"]
                       if row["search_evals_per_s"] else 0.0)
        yield (f"adversarial_{fam}_{row['policy']},"
               f"{us_per_eval:.1f},{row['worst_violation_frac']:.6f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert fixed-seed determinism and evolution > "
                         "random at equal evals, then write "
                         "BENCH_adversarial.json + witness traces")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        smoke(seed=args.seed)
        return
    out = run(seed=args.seed)
    print(f"wrote {BENCH_PATH}")
    for fam, row in sorted(out["families"].items()):
        base = row["baseline"]["best_fitness"]
        print(f"  {fam:<10} {row['policy']:<12} worst violation "
              f"{row['worst_violation_frac']:.3f} (fitness "
              f"{row['worst_fitness']:.3f} vs random {base:.3f}, "
              f"{row['evals']} evals)")


if __name__ == "__main__":
    main()
