"""Bench regression gate: diff two ``BENCH_*.json`` artifacts and fail
on regressions beyond a threshold -- the first perf gate in CI.

Every ``BENCH_*.json`` is a nested dict of numeric leaves under the
shared ``BenchReport`` envelope.  The diff walks both trees, pairs
leaves by path, and classifies each pair by its key name:

* **higher-is-better** -- throughput/speedup leaves (``*_per_s``,
  ``*speedup*``): a regression is NEW < OLD by more than ``threshold``;
* **lower-is-better** -- latency/time leaves (``*_us``, ``*_seconds``,
  ``*us_per*``): a regression is NEW > OLD by more than ``threshold``;
* everything else (counts, configs, SLO metrics) is compared for
  information only and never gates -- those belong to correctness tests,
  not a perf gate.

Compile/trace-time leaves (``*compile*``, ``*trace_lower*``,
``*first_call*``) are informational too: first-call cost is environment
noise on shared CI hosts; the gate watches steady state.

Exit status: 0 = no regressions, 1 = at least one regression (or a
malformed/missing input).  ``--smoke`` self-checks the gate against the
checked-in artifacts: each file diffed against itself must produce zero
regressions, and an injected 50% throughput drop must be detected.

Run:  PYTHONPATH=src:. python benchmarks/bench_diff.py OLD.json NEW.json
or    PYTHONPATH=src:. python benchmarks/bench_diff.py --smoke
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys
from typing import Any, Dict, Iterator, List, Tuple

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: default gate: 30% relative change
DEFAULT_THRESHOLD = 0.30

#: checked-in artifacts the ``--smoke`` self-check runs over
SMOKE_ARTIFACTS = ("BENCH_lagsim.json", "BENCH_fleet.json")

#: leaf-key suffixes / fragments -> metric direction (matched on the
#: final path component only, so e.g. ``steps_per_scenario`` never
#: collides with the ``*_per_s`` throughput suffix)
HIGHER_SUFFIXES = ("_per_s",)
HIGHER_FRAGMENTS = ("speedup",)
LOWER_SUFFIXES = ("_us", "_seconds")
LOWER_FRAGMENTS = ("us_per",)
#: never gate on these even when they look like perf leaves:
#: first-call/compile cost is host noise (the gate watches steady
#: state), ``consumer_seconds`` is a paper SLO metric (correctness tests
#: own it), span summaries are diagnostics
INFORMATIONAL = ("compile", "trace_lower", "first_call", "first_dispatch",
                 "python_us_per_step", "telemetry", "spans",
                 "consumer_seconds")


def _leaves(tree: Any, path: Tuple[str, ...] = ()
            ) -> Iterator[Tuple[Tuple[str, ...], float]]:
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaves(v, path + (str(k),))
    elif isinstance(tree, bool):
        return
    elif isinstance(tree, (int, float)):
        yield path, float(tree)


def _direction(path: Tuple[str, ...]) -> str:
    """-> 'higher' | 'lower' | 'info' for one leaf path."""
    joined = "/".join(path).lower()
    if any(frag in joined for frag in INFORMATIONAL):
        return "info"
    key = path[-1].lower()
    if key.endswith(HIGHER_SUFFIXES) or any(
            frag in key for frag in HIGHER_FRAGMENTS):
        return "higher"
    if key.endswith(LOWER_SUFFIXES) or any(
            frag in key for frag in LOWER_FRAGMENTS):
        return "lower"
    return "info"


def diff(old: Dict, new: Dict, threshold: float = DEFAULT_THRESHOLD
         ) -> Dict[str, List[Tuple[str, float, float, float]]]:
    """-> {"regressions": [...], "improvements": [...], "info": [...]}.

    Each entry is ``(path, old, new, rel_change)`` with ``rel_change``
    signed so that positive = worse for gated leaves.
    """
    old_leaves = dict(_leaves(old))
    new_leaves = dict(_leaves(new))
    out: Dict[str, List] = {"regressions": [], "improvements": [],
                            "info": []}
    for path in sorted(old_leaves.keys() & new_leaves.keys()):
        a, b = old_leaves[path], new_leaves[path]
        direction = _direction(path)
        name = "/".join(path)
        if direction == "info" or a == 0.0:
            out["info"].append((name, a, b, 0.0))
            continue
        rel = (b - a) / abs(a)
        worse = -rel if direction == "higher" else rel
        if worse > threshold:
            out["regressions"].append((name, a, b, worse))
        elif worse < -threshold:
            out["improvements"].append((name, a, b, worse))
        else:
            out["info"].append((name, a, b, worse))
    return out


def run_diff(old_path: str, new_path: str,
             threshold: float = DEFAULT_THRESHOLD, quiet: bool = False
             ) -> int:
    """Diff two artifacts; print the verdict; -> process exit code."""
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    if old.get("kind") != new.get("kind"):
        print(f"bench_diff: kind mismatch: {old.get('kind')!r} vs "
              f"{new.get('kind')!r}", file=sys.stderr)
        return 1
    res = diff(old, new, threshold)
    if not quiet:
        for name, a, b, worse in res["improvements"]:
            print(f"  IMPROVED  {name}: {a:.6g} -> {b:.6g} "
                  f"({-worse:+.0%})")
    for name, a, b, worse in res["regressions"]:
        print(f"  REGRESSED {name}: {a:.6g} -> {b:.6g} ({worse:+.0%} "
              f"worse, gate {threshold:.0%})")
    gated = sum(1 for e in res.values() for _ in e)
    verdict = "FAIL" if res["regressions"] else "ok"
    print(f"bench_diff {verdict}: {os.path.basename(old_path)} -> "
          f"{os.path.basename(new_path)}: {len(res['regressions'])} "
          f"regression(s), {len(res['improvements'])} improvement(s), "
          f"{gated} leaves compared")
    return 1 if res["regressions"] else 0


def _inject_throughput_regression(report: Dict, factor: float = 0.5) -> Dict:
    """A copy of ``report`` with every throughput leaf cut to ``factor``
    (and every gated latency leaf inflated by ``1/factor``)."""
    out = copy.deepcopy(report)

    def walk(node):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            if isinstance(v, dict):
                walk(v)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                d = _direction((k,))
                if d == "higher":
                    node[k] = v * factor
                elif d == "lower":
                    node[k] = v / factor

    walk(out)
    return out


def smoke(threshold: float = DEFAULT_THRESHOLD) -> int:
    """Self-check against the checked-in artifacts: identity diffs must
    pass, an injected 50% throughput regression must fail."""
    import tempfile

    for name in SMOKE_ARTIFACTS:
        path = os.path.join(REPO_ROOT, name)
        if not os.path.exists(path):
            print(f"bench_diff smoke: missing artifact {name}",
                  file=sys.stderr)
            return 1
        code = run_diff(path, path, threshold, quiet=True)
        if code != 0:
            print(f"bench_diff smoke: identity diff of {name} reported "
                  f"regressions", file=sys.stderr)
            return 1
        with open(path) as f:
            report = json.load(f)
        hurt = _inject_throughput_regression(report, factor=0.5)
        if hurt == report:
            print(f"bench_diff smoke: {name} has no gated perf leaves; "
                  f"the gate would be vacuous", file=sys.stderr)
            return 1
        with tempfile.NamedTemporaryFile("w", suffix=".json",
                                         delete=False) as tmp:
            json.dump(hurt, tmp)
            hurt_path = tmp.name
        try:
            code = run_diff(path, hurt_path, threshold, quiet=True)
        finally:
            os.unlink(hurt_path)
        if code == 0:
            print(f"bench_diff smoke: injected 50% regression in {name} "
                  f"was NOT detected", file=sys.stderr)
            return 1
    print(f"bench_diff smoke OK: identity diffs clean, injected 50% "
          f"throughput regressions detected ({', '.join(SMOKE_ARTIFACTS)})")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("new", nargs="?", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression gate (default 0.30 = 30%%)")
    ap.add_argument("--smoke", action="store_true",
                    help="self-check the gate against the checked-in "
                         "artifacts (identity + injected regression)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke(args.threshold))
    if not args.old or not args.new:
        ap.error("OLD and NEW artifact paths are required (or --smoke)")
    sys.exit(run_diff(args.old, args.new, args.threshold))


if __name__ == "__main__":
    main()
