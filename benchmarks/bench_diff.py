"""Bench regression gate: diff two ``BENCH_*.json`` artifacts and fail
on regressions beyond a threshold -- the first perf gate in CI.

Every ``BENCH_*.json`` is a nested dict of numeric leaves under the
shared ``BenchReport`` envelope.  The diff walks both trees, pairs
leaves by path, and classifies each pair by its key name:

* **higher-is-better** -- throughput/speedup leaves (``*_per_s``,
  ``*speedup*``, and ``fused_*`` fused-path leaves that are not
  latency-suffixed): a regression is NEW < OLD by more than
  ``threshold``;
* **lower-is-better** -- latency/time leaves (``*_us``, ``*_seconds``,
  ``*us_per*``): a regression is NEW > OLD by more than ``threshold``;
* **incident leaves** -- anything under the ``observability`` probe's
  incident roll-ups (``*incident*`` in the path): lower is better, and
  -- unlike perf leaves -- a zero baseline still gates, with the
  relative change floored at one incident, so a run that starts paging
  (0 -> 1 SLO-burn incidents) fails the gate even though 0 has no
  well-defined relative change;
* **envelope leaves** -- the adversarial worst-case envelope
  (``worst_*`` leaf names in ``BENCH_adversarial.json``): *higher is
  worse* -- a code change that lets the scenario search do more SLO
  damage to the same policy is a robustness regression.  Zero baselines
  gate too (floored at 0.25, a quarter of the violation-fraction
  range), so a policy whose envelope was clean cannot silently start
  losing;
* everything else (counts, configs, SLO metrics, sketch means) is
  compared for information only and never gates -- those belong to
  correctness tests, not a perf gate.

Compile/trace-time leaves (``*compile*``, ``*trace_lower*``,
``*first_call*``) are informational too: first-call cost is environment
noise on shared CI hosts; the gate watches steady state.

Exit status: 0 = no regressions, 1 = at least one regression (or a
malformed/missing input).  ``--smoke`` self-checks the gate against the
checked-in artifacts: each file diffed against itself must produce zero
regressions, an injected 50% throughput drop must be detected, an
injected incident storm (every incident count/duration worsened) must
be detected via the incident leaves, and an injected envelope blow-up
(every ``worst_*`` leaf worsened) must be detected via the envelope
leaves.

Run:  PYTHONPATH=src:. python benchmarks/bench_diff.py OLD.json NEW.json
or    PYTHONPATH=src:. python benchmarks/bench_diff.py --smoke
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys
from typing import Any, Dict, Iterator, List, Tuple

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: default gate: 30% relative change
DEFAULT_THRESHOLD = 0.30

#: checked-in artifacts the ``--smoke`` self-check runs over
SMOKE_ARTIFACTS = ("BENCH_lagsim.json", "BENCH_fleet.json",
                   "BENCH_adversarial.json")

#: leaf-key suffixes / fragments -> metric direction (matched on the
#: final path component only, so e.g. ``steps_per_scenario`` never
#: collides with the ``*_per_s`` throughput suffix)
HIGHER_SUFFIXES = ("_per_s",)
HIGHER_FRAGMENTS = ("speedup",)
LOWER_SUFFIXES = ("_us", "_seconds")
LOWER_FRAGMENTS = ("us_per",)
#: fused-path throughput leaves (``BENCH_lagsim.json`` ``timing/fused``
#: block): ``fused_``-prefixed leaf names gate higher-is-better --
#: checked AFTER the lower-suffix rules, so ``fused_*_us`` latency
#: leaves keep gating lower-is-better
FUSED_PREFIXES = ("fused_",)
#: alerting leaves (the ``observability`` block's per-rule roll-ups):
#: matched on the full path and checked *before* the informational
#: fragments, so e.g. a probe nested under a ``telemetry`` block still
#: gates -- more incidents / longer burn than the baseline = regression
INCIDENT_FRAGMENTS = ("incident",)
#: adversarial worst-case envelope leaves (``BENCH_adversarial.json``
#: family rows): matched on the final path component, higher is worse.
#: Checked before the incident fragments so ``worst_incidents`` uses the
#: envelope formula (its baseline floor suits [0, 1]-scale leaves).
ENVELOPE_PREFIXES = ("worst_",)
#: zero-baseline floor for envelope leaves (violation fractions live in
#: [0, 1]; a quarter of that range keeps small absolute drifts gateable
#: without amplifying float noise around 0)
ENVELOPE_FLOOR = 0.25
#: never gate on these even when they look like perf leaves:
#: first-call/compile cost is host noise (the gate watches steady
#: state), ``consumer_seconds`` is a paper SLO metric (correctness tests
#: own it), span summaries are diagnostics
INFORMATIONAL = ("compile", "trace_lower", "first_call", "first_dispatch",
                 "python_us_per_step", "telemetry", "spans",
                 "consumer_seconds")


def _leaves(tree: Any, path: Tuple[str, ...] = ()
            ) -> Iterator[Tuple[Tuple[str, ...], float]]:
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaves(v, path + (str(k),))
    elif isinstance(tree, bool):
        return
    elif isinstance(tree, (int, float)):
        yield path, float(tree)


def _direction(path: Tuple[str, ...]) -> str:
    """-> 'higher' | 'lower' | 'incident' | 'envelope' | 'info' for one
    leaf path."""
    if path and path[0] == "config":
        return "info"          # config blocks are metadata, never perf
    joined = "/".join(path).lower()
    if path and path[-1].lower().startswith(ENVELOPE_PREFIXES):
        return "envelope"
    if any(frag in joined for frag in INCIDENT_FRAGMENTS):
        return "incident"
    if any(frag in joined for frag in INFORMATIONAL):
        return "info"
    key = path[-1].lower()
    if key.endswith(HIGHER_SUFFIXES) or any(
            frag in key for frag in HIGHER_FRAGMENTS):
        return "higher"
    if key.endswith(LOWER_SUFFIXES) or any(
            frag in key for frag in LOWER_FRAGMENTS):
        return "lower"
    if key.startswith(FUSED_PREFIXES):
        return "higher"
    return "info"


def diff(old: Dict, new: Dict, threshold: float = DEFAULT_THRESHOLD
         ) -> Dict[str, List[Tuple[str, float, float, float]]]:
    """-> {"regressions": [...], "improvements": [...], "info": [...]}.

    Each entry is ``(path, old, new, rel_change)`` with ``rel_change``
    signed so that positive = worse for gated leaves.
    """
    old_leaves = dict(_leaves(old))
    new_leaves = dict(_leaves(new))
    out: Dict[str, List] = {"regressions": [], "improvements": [],
                            "info": []}
    for path in sorted(old_leaves.keys() & new_leaves.keys()):
        a, b = old_leaves[path], new_leaves[path]
        direction = _direction(path)
        name = "/".join(path)
        if direction == "info" or (
                a == 0.0 and direction not in ("incident", "envelope")):
            out["info"].append((name, a, b, 0.0))
            continue
        if direction == "incident":
            # lower is better; the denominator floor of one incident
            # keeps a zero baseline gateable (0 -> 1 incident = +100%)
            worse = (b - a) / max(abs(a), 1.0)
        elif direction == "envelope":
            # worst-case adversarial damage: higher is worse, and a
            # clean (zero) baseline must still gate
            worse = (b - a) / max(abs(a), ENVELOPE_FLOOR)
        else:
            rel = (b - a) / abs(a)
            worse = -rel if direction == "higher" else rel
        if worse > threshold:
            out["regressions"].append((name, a, b, worse))
        elif worse < -threshold:
            out["improvements"].append((name, a, b, worse))
        else:
            out["info"].append((name, a, b, worse))
    return out


def run_diff(old_path: str, new_path: str,
             threshold: float = DEFAULT_THRESHOLD, quiet: bool = False
             ) -> int:
    """Diff two artifacts; print the verdict; -> process exit code."""
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    if old.get("kind") != new.get("kind"):
        print(f"bench_diff: kind mismatch: {old.get('kind')!r} vs "
              f"{new.get('kind')!r}", file=sys.stderr)
        return 1
    res = diff(old, new, threshold)
    if not quiet:
        for name, a, b, worse in res["improvements"]:
            print(f"  IMPROVED  {name}: {a:.6g} -> {b:.6g} "
                  f"({-worse:+.0%})")
    for name, a, b, worse in res["regressions"]:
        print(f"  REGRESSED {name}: {a:.6g} -> {b:.6g} ({worse:+.0%} "
              f"worse, gate {threshold:.0%})")
    gated = sum(1 for e in res.values() for _ in e)
    verdict = "FAIL" if res["regressions"] else "ok"
    print(f"bench_diff {verdict}: {os.path.basename(old_path)} -> "
          f"{os.path.basename(new_path)}: {len(res['regressions'])} "
          f"regression(s), {len(res['improvements'])} improvement(s), "
          f"{gated} leaves compared")
    return 1 if res["regressions"] else 0


def _inject_throughput_regression(report: Dict, factor: float = 0.5) -> Dict:
    """A copy of ``report`` with every throughput leaf cut to ``factor``
    (and every gated latency leaf inflated by ``1/factor``)."""
    out = copy.deepcopy(report)

    def walk(node):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            if isinstance(v, dict):
                walk(v)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                d = _direction((k,))
                if d == "higher":
                    node[k] = v * factor
                elif d == "lower":
                    node[k] = v / factor

    walk(out)
    return out


def _inject_incident_regression(report: Dict, extra: float = 3.0) -> Dict:
    """A copy of ``report`` with every incident leaf worsened
    (``2x + extra``): the additive term makes even zero-baseline
    incident counts regress, which the gate must catch."""
    out = copy.deepcopy(report)

    def walk(node, path):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            p = path + (str(k),)
            if isinstance(v, dict):
                walk(v, p)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                if _direction(p) == "incident":
                    node[k] = v * 2 + extra

    walk(out, ())
    return out


def _inject_envelope_regression(report: Dict, delta: float = 0.4) -> Dict:
    """A copy of ``report`` with every adversarial envelope leaf
    worsened by ``+delta``: additive, so a policy with a clean (zero)
    worst case regresses too -- the gate must catch both."""
    out = copy.deepcopy(report)

    def walk(node, path):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            p = path + (str(k),)
            if isinstance(v, dict):
                walk(v, p)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                if _direction(p) == "envelope":
                    node[k] = v + delta

    walk(out, ())
    return out


def _expect_fail(path: str, hurt: Dict, threshold: float, what: str) -> int:
    """Diff ``path`` against the injected ``hurt`` report; 0 iff the gate
    correctly reported at least one regression."""
    import tempfile

    with tempfile.NamedTemporaryFile("w", suffix=".json",
                                     delete=False) as tmp:
        json.dump(hurt, tmp)
        hurt_path = tmp.name
    try:
        code = run_diff(path, hurt_path, threshold, quiet=True)
    finally:
        os.unlink(hurt_path)
    if code == 0:
        print(f"bench_diff smoke: injected {what} in "
              f"{os.path.basename(path)} was NOT detected", file=sys.stderr)
        return 1
    return 0


def smoke(threshold: float = DEFAULT_THRESHOLD) -> int:
    """Self-check against the checked-in artifacts: identity diffs must
    pass; an injected 50% throughput regression, an injected incident
    storm and an injected envelope blow-up must all fail."""
    incident_checked = 0
    envelope_checked = 0
    for name in SMOKE_ARTIFACTS:
        path = os.path.join(REPO_ROOT, name)
        if not os.path.exists(path):
            print(f"bench_diff smoke: missing artifact {name}",
                  file=sys.stderr)
            return 1
        code = run_diff(path, path, threshold, quiet=True)
        if code != 0:
            print(f"bench_diff smoke: identity diff of {name} reported "
                  f"regressions", file=sys.stderr)
            return 1
        with open(path) as f:
            report = json.load(f)
        hurt = _inject_throughput_regression(report, factor=0.5)
        if hurt == report:
            print(f"bench_diff smoke: {name} has no gated perf leaves; "
                  f"the gate would be vacuous", file=sys.stderr)
            return 1
        if _expect_fail(path, hurt, threshold, "50% throughput regression"):
            return 1
        stormed = _inject_incident_regression(report)
        if stormed != report:
            incident_checked += 1
            if _expect_fail(path, stormed, threshold, "incident storm"):
                return 1
        blown = _inject_envelope_regression(report)
        if blown != report:
            envelope_checked += 1
            if _expect_fail(path, blown, threshold, "envelope blow-up"):
                return 1
    if incident_checked == 0:
        print("bench_diff smoke: no artifact carries incident leaves; the "
              "incident gate would be vacuous (run the benchmarks to "
              "regenerate the observability blocks)", file=sys.stderr)
        return 1
    if envelope_checked == 0:
        print("bench_diff smoke: no artifact carries adversarial envelope "
              "leaves; the robustness gate would be vacuous (run "
              "benchmarks/adversarial_bench.py to regenerate "
              "BENCH_adversarial.json)", file=sys.stderr)
        return 1
    print(f"bench_diff smoke OK: identity diffs clean, injected 50% "
          f"throughput regressions detected, injected incident storms "
          f"detected in {incident_checked} artifact(s), injected envelope "
          f"blow-ups detected in {envelope_checked} artifact(s) "
          f"({', '.join(SMOKE_ARTIFACTS)})")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("new", nargs="?", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression gate (default 0.30 = 30%%)")
    ap.add_argument("--smoke", action="store_true",
                    help="self-check the gate against the checked-in "
                         "artifacts (identity + injected regression)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke(args.threshold))
    if not args.old or not args.new:
        ap.error("OLD and NEW artifact paths are required (or --smoke)")
    sys.exit(run_diff(args.old, args.new, args.threshold))


if __name__ == "__main__":
    main()
