"""Packer latency (paper Sec. III premise: approximation algorithms run
'within the necessary time requirements').

Measures three tiers of the packing hot path:

* one reassignment decision -- python reference vs the jitted JAX packer --
  across partition counts (``ref_*`` / ``jax_*`` rows);
* a whole batched scenario sweep through ``sweep_streams`` -- B streams x
  T iterations x all-in-one XLA program -- reported as us per packed
  iteration (``sweep_*`` rows);
* the Pallas batched fit-select reduction (jitted
  ``ops.select_slot_batched``), one launch over a ``(B, N, M)`` grid,
  interpreter mode on CPU (``pallas_select_*`` rows).

Every measurement separates *first-call* time (tracing + XLA compile +
run; for the python reference just a cold call) from *steady-state* time
(mean over ``reps`` warm calls): a jitted packer's first call is
typically thousands of times slower than its steady state, and folding
it in used to dominate the throughput rows.  The CSV reports steady-state
microseconds in the ``us_per_call`` column and first-call microseconds
in the ``derived`` column.

Run:  PYTHONPATH=src:. python benchmarks/run.py      (packer_latency_* rows)
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jaxpack import modified_any_fit_jax, pack_jax, sweep_streams
from repro.core.scenarios import generate_scenario
from repro.kernels.ops import select_slot_batched
from repro.registry import packer_for

from benchmarks.sections import section


def _time(fn, reps=5) -> Tuple[float, float]:
    """-> (first_call_us, steady_us): compile/trace time vs warm mean."""
    t0 = time.perf_counter()
    fn()                               # first call: trace + compile + run
    first = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return first, (time.perf_counter() - t0) / reps * 1e6  # us


def run(sizes=(50, 200, 500)) -> Dict[str, Tuple[float, float]]:
    """-> {row_name: (first_call_us, steady_state_us)}."""
    out = {}
    rng = np.random.default_rng(0)
    for n in sizes:
        speeds = rng.uniform(0, 1, n)
        prev = rng.integers(-1, max(1, n // 4), n).astype(np.int32)
        sp = {j: float(w) for j, w in enumerate(speeds)}
        prev_map = {j: int(c) for j, c in enumerate(prev) if c >= 0}

        ref_bfd = packer_for("BFD", backend="py")
        ref_mbfp = packer_for("MBFP", backend="py")
        out[f"ref_BFD_n{n}_us"] = _time(
            lambda: ref_bfd(sp, 1.0, prev=prev_map))
        out[f"ref_MBFP_n{n}_us"] = _time(
            lambda: ref_mbfp(sp, 1.0, prev=prev_map))
        sj = jnp.asarray(speeds, jnp.float32)
        pj = jnp.asarray(prev)
        out[f"jax_BFD_n{n}_us"] = _time(
            lambda: jax.block_until_ready(
                pack_jax(sj, pj, 1.0, strategy="best", decreasing=True)))
        out[f"jax_MBFP_n{n}_us"] = _time(
            lambda: jax.block_until_ready(
                modified_any_fit_jax(sj, pj, 1.0, fit="best",
                                     sort_key="max_partition")))

    # batched sweep: B streams x T iterations in one program, us/iteration
    batch, iters, n = 8, 50, 20
    traces = generate_scenario("bursty", jax.random.key(0), batch, iters, n)
    for algo in ("BFD", "MBFP"):
        first, us = _time(lambda: jax.block_until_ready(
            sweep_streams((algo,), traces, 1.0)), reps=3)
        out[f"sweep_{algo}_b{batch}xt{iters}_us_per_iter"] = (
            first / (batch * iters), us / (batch * iters))

    # Pallas batched fit-select: one launch over the (B, N, M) grid
    b, ninst, m = 8, 512, 64
    loads = jnp.asarray(rng.uniform(0, 1, (b, ninst, m)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 0.6, (b, ninst)), jnp.float32)
    k = jnp.asarray(rng.integers(0, m + 1, (b, ninst)), jnp.int32)
    cap = jnp.ones((b, ninst), jnp.float32)
    for strat in ("first", "best", "worst"):
        out[f"pallas_select_{strat}_b{b}xn{ninst}_us"] = _time(
            lambda: jax.block_until_ready(
                select_slot_batched(loads, w, k, cap, strategy=strat)),
            reps=3)
    return out


@section("packer_latency", prefixes=("packer_latency_",))
def _rows():
    # us_per_call = steady state; derived = first call (compile+run)
    for name, (first_us, steady_us) in run().items():
        yield f"packer_latency_{name},{steady_us:.1f},{first_us:.1f}"
