"""Packer latency: the paper's premise is that approximation algorithms run
'within the necessary time requirements' (Sec. III).  Measures one
reassignment decision -- python reference vs the jitted JAX packer -- across
partition counts, plus the Pallas fit-select reduction."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binpack import CLASSICAL
from repro.core.jaxpack import modified_any_fit_jax, pack_jax
from repro.core.modified import MODIFIED


def _time(fn, reps=5) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(sizes=(50, 200, 500)) -> Dict[str, float]:
    out = {}
    rng = np.random.default_rng(0)
    for n in sizes:
        speeds = rng.uniform(0, 1, n)
        prev = rng.integers(-1, max(1, n // 4), n).astype(np.int32)
        sp = {j: float(w) for j, w in enumerate(speeds)}
        prev_map = {j: int(c) for j, c in enumerate(prev) if c >= 0}

        out[f"ref_BFD_n{n}_us"] = _time(
            lambda: CLASSICAL["BFD"](sp, 1.0, prev=prev_map))
        out[f"ref_MBFP_n{n}_us"] = _time(
            lambda: MODIFIED["MBFP"](sp, 1.0, prev=prev_map))
        sj = jnp.asarray(speeds, jnp.float32)
        pj = jnp.asarray(prev)
        out[f"jax_BFD_n{n}_us"] = _time(
            lambda: jax.block_until_ready(
                pack_jax(sj, pj, 1.0, strategy="best", decreasing=True)))
        out[f"jax_MBFP_n{n}_us"] = _time(
            lambda: jax.block_until_ready(
                modified_any_fit_jax(sj, pj, 1.0, fit="best",
                                     sort_key="max_partition")))
    return out
