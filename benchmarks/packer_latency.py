"""Packer latency (paper Sec. III premise: approximation algorithms run
'within the necessary time requirements').

Measures three tiers of the packing hot path:

* one reassignment decision -- python reference vs the jitted JAX packer --
  across partition counts (``ref_*`` / ``jax_*`` rows);
* a whole batched scenario sweep through ``sweep_streams`` -- B streams x
  T iterations x all-in-one XLA program -- reported as us per packed
  iteration (``sweep_*`` rows);
* the Pallas batched fit-select reduction (jitted
  ``ops.select_slot_batched``), one launch over a ``(B, N, M)`` grid,
  interpreter mode on CPU (``pallas_select_*`` rows).

Every measurement separates *first-call* time (tracing + XLA compile +
run; for the python reference just a cold call) from *steady-state* time
(mean over ``reps`` warm calls): a jitted packer's first call is
typically thousands of times slower than its steady state, and folding
it in used to dominate the throughput rows.  The CSV reports steady-state
microseconds in the ``us_per_call`` column, first-call microseconds in
the ``derived`` column, and -- for the jitted/Pallas rows -- *dispatch-only*
microseconds in the ``dispatch_us`` column: steady-state minus a no-op
baseline of identical call structure (a jitted identity for one-shot
rows, a no-op ``lax.scan`` of the same (B, T) geometry for sweep rows).
That column is the pinned before-number for the ROADMAP megakernel item:
it is the floor a fused kernel cannot beat without touching dispatch.

The closed-loop twin row (``lagsim_*_us_per_iter``) adds the after-number
in the ``fused_us`` column: the same steady sweep iteration on the fused
multi-step path (``LagSimConfig.fused_steps``), which advances K steps
per dispatch and so amortizes exactly the overhead the ``dispatch_us``
column isolates.

Run:  PYTHONPATH=src:. python benchmarks/run.py      (packer_latency_* rows)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jaxpack import modified_any_fit_jax, pack_jax, sweep_streams
from repro.core.scenarios import generate_scenario
from repro.kernels.ops import select_slot_batched
from repro.registry import packer_for

from benchmarks.sections import section

#: (first_us, steady_us, dispatch_us | None[, fused_us]) per row
Row = Tuple[float, ...]


def _time(fn, reps=5) -> Tuple[float, float]:
    """-> (first_call_us, steady_us): compile/trace time vs warm mean."""
    t0 = time.perf_counter()
    fn()                               # first call: trace + compile + run
    first = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return first, (time.perf_counter() - t0) / reps * 1e6  # us


def _noop_call_us(example, reps: int = 20) -> float:
    """Steady-state cost of dispatching a jitted identity on ``example``
    -- the pure call-overhead baseline for one-shot rows."""
    noop = jax.jit(lambda x: x)
    _, steady = _time(lambda: jax.block_until_ready(noop(example)),
                      reps=reps)
    return steady


def _noop_scan_us(traces, reps: int = 5) -> float:
    """Steady-state cost of a no-op scan of the sweep's (B, T) geometry:
    vmapped over streams, scanning the iteration axis, computing nothing."""

    @jax.jit
    def noop(tr):
        def one(stream):                       # stream: (T, N)
            return jax.lax.scan(
                lambda c, x: (c, jnp.float32(0.0)), jnp.float32(0.0),
                stream)[1]
        return jax.vmap(one)(tr)

    _, steady = _time(lambda: jax.block_until_ready(noop(traces)), reps=reps)
    return steady


def run(sizes=(50, 200, 500)) -> Dict[str, Row]:
    """-> {row_name: (first_call_us, steady_state_us, dispatch_us|None)}."""
    out: Dict[str, Row] = {}
    rng = np.random.default_rng(0)
    for n in sizes:
        speeds = rng.uniform(0, 1, n)
        prev = rng.integers(-1, max(1, n // 4), n).astype(np.int32)
        sp = {j: float(w) for j, w in enumerate(speeds)}
        prev_map = {j: int(c) for j, c in enumerate(prev) if c >= 0}

        ref_bfd = packer_for("BFD", backend="py")
        ref_mbfp = packer_for("MBFP", backend="py")
        # python reference rows: no jit dispatch, no dispatch column
        out[f"ref_BFD_n{n}_us"] = _time(
            lambda: ref_bfd(sp, 1.0, prev=prev_map)) + (None,)
        out[f"ref_MBFP_n{n}_us"] = _time(
            lambda: ref_mbfp(sp, 1.0, prev=prev_map)) + (None,)
        sj = jnp.asarray(speeds, jnp.float32)
        pj = jnp.asarray(prev)
        noop = _noop_call_us(sj)
        for name, fn in (
            ("BFD", lambda: jax.block_until_ready(
                pack_jax(sj, pj, 1.0, strategy="best", decreasing=True))),
            ("MBFP", lambda: jax.block_until_ready(
                modified_any_fit_jax(sj, pj, 1.0, fit="best",
                                     sort_key="max_partition"))),
        ):
            first, steady = _time(fn)
            out[f"jax_{name}_n{n}_us"] = (
                first, steady, max(0.0, steady - noop))

    # batched sweep: B streams x T iterations in one program, us/iteration
    batch, iters, n = 8, 50, 20
    traces = generate_scenario("bursty", jax.random.key(0), batch, iters, n)
    noop_scan = _noop_scan_us(traces)
    for algo in ("BFD", "MBFP"):
        first, us = _time(lambda: jax.block_until_ready(
            sweep_streams((algo,), traces, 1.0)), reps=3)
        out[f"sweep_{algo}_b{batch}xt{iters}_us_per_iter"] = (
            first / (batch * iters), us / (batch * iters),
            max(0.0, us - noop_scan) / (batch * iters))

    # Pallas batched fit-select: one launch over the (B, N, M) grid
    b, ninst, m = 8, 512, 64
    loads = jnp.asarray(rng.uniform(0, 1, (b, ninst, m)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 0.6, (b, ninst)), jnp.float32)
    k = jnp.asarray(rng.integers(0, m + 1, (b, ninst)), jnp.int32)
    cap = jnp.ones((b, ninst), jnp.float32)
    noop_sel = _noop_call_us(loads)
    for strat in ("first", "best", "worst"):
        first, steady = _time(
            lambda: jax.block_until_ready(
                select_slot_batched(loads, w, k, cap, strategy=strat)),
            reps=3)
        out[f"pallas_select_{strat}_b{b}xn{ninst}_us"] = (
            first, steady, max(0.0, steady - noop_sel))

    # closed-loop twin: per-step scan vs the fused multi-step path
    # (fused_us column) on a fused-friendly shape (N <= 14)
    from repro.lagsim import LagSimConfig, sweep_lag

    b2, t2, n2 = 2, 240, 10
    tw = generate_scenario("bursty", jax.random.key(1), b2, t2, n2)
    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
    first, us = _time(lambda: jax.block_until_ready(
        sweep_lag(("BFD",), tw, cfg).lag_total), reps=3)
    _, us_fused = _time(lambda: jax.block_until_ready(
        sweep_lag(("BFD",), tw,
                  dataclasses.replace(cfg, fused_steps=8)).lag_total),
        reps=3)
    out[f"lagsim_BFD_b{b2}xt{t2}_us_per_iter"] = (
        first / (b2 * t2), us / (b2 * t2), None, us_fused / (b2 * t2))
    return out


@section("packer_latency", prefixes=("packer_latency_",))
def _rows():
    # us_per_call = steady state; derived = first call (compile+run);
    # dispatch_us = steady minus the no-op baseline (empty for py refs);
    # fused_us = the same steady work on the fused multi-step path
    for name, row in run().items():
        first_us, steady_us, dispatch_us = row[:3]
        tail = "" if dispatch_us is None else f"{dispatch_us:.1f}"
        line = (f"packer_latency_{name},{steady_us:.1f},{first_us:.1f},"
                f"{tail}")
        if len(row) > 3:
            line += f",{row[3]:.2f}"
        yield line
