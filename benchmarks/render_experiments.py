"""Splice the final roofline tables + perf summary into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> / <!-- PERF_SUMMARY --> markers).
Supports EXPERIMENTS.md's §Roofline; reproduces no paper figure directly.

Run:  PYTHONPATH=src:. python benchmarks/render_experiments.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import load, render, table  # noqa: E402

HERE = os.path.join(os.path.dirname(__file__), "..")


def perf_summary(rows):
    """Baseline vs optimized-variant rows for the hillclimbed cells."""
    by = {(r.get("arch"), r.get("shape"), r.get("mesh"),
           r.get("rules", "baseline")): r for r in rows if "roofline" in r}

    def dom(r):
        rl = r["roofline"]
        base = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        fl = r.get("flush_amortized")
        if fl:
            base += fl["t_memory_s"] + fl["t_collective_s"]
        return base

    cells = [
        ("rwkv6-3b", "train_4k", "wkv_kernel"),
        ("qwen2-moe-a2.7b", "train_4k", "ep"),
        ("llama4-scout-17b-a16e", "train_4k", "ep"),
        ("jamba-v0.1-52b", "train_4k", "ep"),
        ("qwen2-moe-a2.7b", "prefill_32k", "ep"),
        ("deepseek-67b", "decode_32k", "tail256"),
        ("qwen2-vl-72b", "decode_32k", "tail256"),
    ]
    lines = ["| cell | baseline dominant (s) | optimized (s) | speedup | variant |",
             "|---|---|---|---|---|"]
    for arch, shape, var in cells:
        b = by.get((arch, shape, "16x16", "baseline"))
        o = by.get((arch, shape, "16x16", var))
        if not b or not o:
            continue
        db, do = dom(b), dom(o)
        lines.append(f"| {arch} × {shape} | {db:.3f} ({b['roofline']['bottleneck']}) "
                     f"| {do:.3f} ({o['roofline']['bottleneck']}) "
                     f"| **{db / do:.2f}×** | `{var}` |")
    return "\n".join(lines)


def main():
    rows = load(os.path.join(HERE, "dryrun_results.jsonl"))
    t16 = "```\n" + render(table(rows, mesh="16x16")) + "\n```"
    t512 = "```\n" + render(table(rows, mesh="2x16x16")) + "\n```"
    roof = ("### Single-pod 16x16 (256 chips) — optimized baseline\n\n" + t16 +
            "\n\n### Multi-pod 2x16x16 (512 chips)\n\n" + t512)
    perf = "### Final measured summary (dominant-term speedups)\n\n" + \
        perf_summary(rows)

    path = os.path.join(HERE, "EXPERIMENTS.md")
    src = open(path).read()
    src = src.replace("<!-- ROOFLINE_TABLE -->", roof)
    src = src.replace("<!-- PERF_SUMMARY -->", perf)
    open(path, "w").write(src)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
