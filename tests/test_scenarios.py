"""Scenario generator + batched sweep driver tests.

The load-bearing property (ISSUE acceptance criterion): the vmapped sweep
with batch size 1 is *bit-identical* to the existing single-stream
``evaluate_stream_jax`` path, so every figure produced through the batched
engine is the figure the single-stream code would have produced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jaxpack import (
    evaluate_stream_jax,
    sweep_streams,
)
from repro.registry import PACKER_FAMILIES, list_policies

ALGORITHMS = list_policies(family=PACKER_FAMILIES, backend="jax")
from repro.core.scenarios import (
    MASKED_SCENARIO_FAMILIES,
    SCENARIO_FAMILIES,
    generate_masked_scenario,
    generate_scenario,
    masked_scenario_suite,
    scenario_suite,
    stack_masked_suite,
    stack_suite,
)

KEY = jax.random.key(42)


# ---------------------------------------------------------------------------
# generator: shapes, dtypes, ranges, determinism
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", sorted(SCENARIO_FAMILIES))
def test_scenario_shape_dtype_nonnegative(family):
    out = generate_scenario(family, KEY, batch=3, iters=20, n=7)
    assert out.shape == (3, 20, 7)
    assert out.dtype == jnp.float32
    assert bool((np.asarray(out) >= 0.0).all()), f"{family} produced negatives"


@pytest.mark.parametrize("family", sorted(SCENARIO_FAMILIES))
def test_scenario_deterministic_under_fixed_key(family):
    a = generate_scenario(family, KEY, batch=2, iters=16, n=5)
    b = generate_scenario(family, KEY, batch=2, iters=16, n=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = generate_scenario(family, jax.random.key(1), batch=2, iters=16, n=5)
    assert not np.array_equal(np.asarray(a), np.asarray(c)), (
        f"{family}: different keys gave identical traces")


def test_suite_bit_identical_across_recompilation():
    """Same seed => bit-identical trace batch for all six families, even
    after the jit caches are dropped (a recompile must not change bits)."""
    def build():
        suite = scenario_suite(jax.random.key(123), batch=2, iters=12, n=5)
        return {f: np.asarray(v) for f, v in suite.items()}

    first = build()
    assert sorted(first) == sorted(SCENARIO_FAMILIES)
    jax.clear_caches()
    second = build()
    for family in SCENARIO_FAMILIES:
        a, b = first[family], second[family]
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes(), (
            f"{family}: recompilation changed trace bits")


def test_scenario_knobs_forwarded():
    calm = generate_scenario("random_walk", KEY, 1, 32, 6, delta=0.0)
    wild = generate_scenario("random_walk", KEY, 1, 32, 6, delta=25.0)
    calm = np.asarray(calm)
    assert np.abs(calm - calm[:, :1]).max() < 1e-7  # delta=0: flat
    assert np.abs(np.diff(np.asarray(wild), axis=1)).max() > 0


def test_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown scenario family"):
        generate_scenario("tsunami", KEY, 1, 4, 2)


def test_suite_and_stack():
    suite = scenario_suite(KEY, batch=2, iters=8, n=4,
                           families=("diurnal", "bursty", "churn"))
    assert sorted(suite) == ["bursty", "churn", "diurnal"]
    labels, batch = stack_suite(suite)
    assert batch.shape == (6, 8, 4)
    assert labels == ("diurnal", "diurnal", "bursty", "bursty",
                      "churn", "churn")


# ---------------------------------------------------------------------------
# masked scenarios (variable-N fleets)
# ---------------------------------------------------------------------------
def test_masked_families_cover_all_families():
    assert sorted(MASKED_SCENARIO_FAMILIES) == sorted(SCENARIO_FAMILIES)


@pytest.mark.parametrize("family", sorted(MASKED_SCENARIO_FAMILIES))
def test_masked_scenario_contract(family):
    """(speeds, active) pairs: matching shapes, bool mask, absent => 0."""
    speeds, active = generate_masked_scenario(family, KEY, batch=2,
                                              iters=20, n=6)
    assert speeds.shape == active.shape == (2, 20, 6)
    assert speeds.dtype == jnp.float32 and active.dtype == jnp.bool_
    sp, ac = np.asarray(speeds), np.asarray(active)
    assert (sp[~ac] == 0.0).all(), f"{family}: dead partitions must be silent"
    # determinism
    s2, a2 = generate_masked_scenario(family, KEY, batch=2, iters=20, n=6)
    np.testing.assert_array_equal(sp, np.asarray(s2))
    np.testing.assert_array_equal(ac, np.asarray(a2))


def test_churn_masked_matches_legacy_timeline():
    """The true-mask churn shares the legacy generator's on/off timeline:
    wherever the mask is on, the speeds agree; wherever off, the legacy
    trace shows the near-idle fake and the masked one shows absence."""
    legacy = np.asarray(generate_scenario("churn", KEY, 2, 30, 5))
    speeds, active = generate_masked_scenario("churn", KEY, 2, 30, 5)
    sp, ac = np.asarray(speeds), np.asarray(active)
    np.testing.assert_allclose(sp[ac], legacy[ac], rtol=1e-6)
    assert (sp[~ac] == 0.0).all()
    assert (legacy[~ac] > 0.0).all()          # the legacy near-idle fake


def test_topic_lifecycle_has_births_and_deaths():
    _, active = generate_masked_scenario("topic_lifecycle", KEY, batch=4,
                                         iters=64, n=8)
    ac = np.asarray(active)
    assert ac.any() and (~ac).any()
    flips = np.diff(ac.astype(int), axis=1)
    assert (flips == 1).any(), "need births mid-stream"
    assert (flips == -1).any(), "need deaths mid-stream"
    # one lifetime window per partition: alive is a single contiguous run
    assert (np.abs(flips).sum(axis=1) <= 2).all()


def test_always_on_families_emit_all_true_masks():
    for family in ("random_walk", "diurnal", "ramp", "bursty", "heavy_tail"):
        _, active = generate_masked_scenario(family, KEY, 1, 8, 3)
        assert bool(np.asarray(active).all()), family


def test_masked_suite_and_stack():
    suite = masked_scenario_suite(KEY, batch=2, iters=8, n=4,
                                  families=("churn", "topic_lifecycle"))
    labels, speeds, active = stack_masked_suite(suite)
    assert speeds.shape == active.shape == (4, 8, 4)
    assert labels == ("churn", "churn", "topic_lifecycle", "topic_lifecycle")


def test_masked_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown scenario family"):
        generate_masked_scenario("tsunami", KEY, 1, 4, 2)


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------
def _trace_batch(batch=3, iters=24, n=8):
    return generate_scenario("bursty", jax.random.key(7), batch, iters, n)


def test_sweep_shapes_and_dtypes():
    batch = _trace_batch()
    res = sweep_streams(("NF", "BFD", "MBFP"), batch, 1.0)
    assert res.algorithms == ("NF", "BFD", "MBFP")
    for arr, dt in ((res.bins, jnp.int32), (res.rscores, jnp.float32),
                    (res.migrations, jnp.int32)):
        assert arr.shape == (3, 3, 24)
        assert arr.dtype == dt
    # first iteration starts from an empty assignment: nothing can migrate
    assert int(np.asarray(res.migrations)[:, :, 0].sum()) == 0
    assert float(np.asarray(res.rscores)[:, :, 0].sum()) == 0.0


@pytest.mark.parametrize("algo", sorted(ALGORITHMS))
def test_sweep_batch1_bit_identical_to_single_stream(algo):
    batch = _trace_batch(batch=1, iters=30, n=10)
    res = sweep_streams((algo,), batch, 1.0)
    bins, rs = evaluate_stream_jax(batch[0], 1.0, algorithm=algo)
    np.testing.assert_array_equal(np.asarray(res.bins[0, 0]),
                                  np.asarray(bins))
    # bit-identical, not approx: same scan, vmapped over a singleton axis
    np.testing.assert_array_equal(np.asarray(res.rscores[0, 0]),
                                  np.asarray(rs))


def test_sweep_batched_rows_match_individual_streams():
    """Each row of a batch>1 sweep equals that stream swept alone."""
    batch = _trace_batch(batch=3, iters=20, n=6)
    res = sweep_streams(("BFD", "MWF"), batch, 1.0)
    for b in range(3):
        solo = sweep_streams(("BFD", "MWF"), batch[b:b + 1], 1.0)
        np.testing.assert_array_equal(np.asarray(res.bins[:, b]),
                                      np.asarray(solo.bins[:, 0]))
        np.testing.assert_array_equal(np.asarray(res.rscores[:, b]),
                                      np.asarray(solo.rscores[:, 0]))
        np.testing.assert_array_equal(np.asarray(res.migrations[:, b]),
                                      np.asarray(solo.migrations[:, 0]))


def test_sweep_migration_counts_consistent_with_rscore():
    """Zero migrations in an iteration forces a zero Rscore and vice versa
    (all generated speeds are > 0 with probability 1)."""
    batch = _trace_batch(batch=2, iters=24, n=8)
    res = sweep_streams(("FFD",), batch, 1.0)
    migs = np.asarray(res.migrations[0])
    rs = np.asarray(res.rscores[0])
    assert ((migs == 0) == (rs == 0.0)).all()


def test_sweep_result_for_algorithm_lookup():
    batch = _trace_batch(batch=2, iters=10, n=5)
    res = sweep_streams(("NF", "WFD"), batch, 1.0)
    bins, rs, migs = res.for_algorithm("wfd")
    np.testing.assert_array_equal(np.asarray(bins), np.asarray(res.bins[1]))
    np.testing.assert_array_equal(np.asarray(migs),
                                  np.asarray(res.migrations[1]))


# ---------------------------------------------------------------------------
# family registry + knob specs (repro.scenarios genome source of truth)
# ---------------------------------------------------------------------------
def test_family_registry_covers_every_generator():
    from repro.core.scenarios import FAMILY_SPECS, family_spec

    assert set(FAMILY_SPECS) == set(SCENARIO_FAMILIES)
    assert set(FAMILY_SPECS) == set(MASKED_SCENARIO_FAMILIES)
    for name, spec in FAMILY_SPECS.items():
        assert spec is family_spec(name)
        assert spec.name == name
        for knob in spec.knobs:
            assert knob.lo <= knob.default <= knob.hi, (name, knob)
        for a, b in spec.ordered:
            assert a in spec.knob_names and b in spec.knob_names, name
    with pytest.raises(ValueError, match="unknown scenario family"):
        family_spec("nope")


def test_adversarial_knobs_drive_generator():
    """Every registered adversarial knob is accepted by the generator
    (the search decodes genomes into exactly these kwargs)."""
    from repro.core.scenarios import family_spec

    spec = family_spec("adversarial")
    defaults = {k.name: k.default for k in spec.knobs}
    sp, ac = generate_masked_scenario("adversarial", jax.random.key(0),
                                      2, 16, 5, **defaults)
    assert sp.shape == ac.shape == (2, 16, 5)
    assert not np.asarray(sp)[~np.asarray(ac)].any()
    # capacity clamp: the feasibility assumption the search relies on
    assert float(jnp.max(sp)) <= 1.0 + 1e-6


def test_lifecycle_death_before_birth_raises():
    """Regression: an empty lifecycle window (death step precedes birth
    step) used to be silently accepted, producing partitions that never
    exist; it must be a named error for concrete knobs."""
    with pytest.raises(ValueError, match="death precedes birth"):
        generate_masked_scenario("adversarial", jax.random.key(0), 2, 16, 5,
                                 birth_frac=0.8, death_frac=0.2)
    # topic_lifecycle draws its windows; its degenerate-window knob is a
    # negative minimum lifetime, which likewise must be a named error
    with pytest.raises(ValueError, match="min_life_frac"):
        generate_masked_scenario("topic_lifecycle", jax.random.key(0),
                                 2, 16, 5, min_life_frac=-0.5)


def test_lifecycle_death_before_birth_traced_is_repaired_not_raised():
    """Under tracing (the search's vmapped oracle) the same constraint
    cannot raise; the in-graph repair clamps death >= birth instead."""
    def gen(b, d):
        sp, ac = generate_masked_scenario(
            "adversarial", jax.random.key(1), 1, 12, 4,
            birth_frac=b, death_frac=jnp.maximum(d, b), lifecycle_frac=1.0)
        return sp, ac

    sp, ac = jax.jit(gen)(jnp.float32(0.8), jnp.float32(0.2))
    assert not np.asarray(sp)[~np.asarray(ac)].any()
