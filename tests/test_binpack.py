"""Unit + property tests for the reference bin-packing core (paper Secs. II-B,
IV-A, IV-B, IV-C)."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CLASSICAL,
    MODIFIED,
    capacity_lower_bound,
    group_view,
    modified_any_fit,
    pack,
    rebalanced_partitions,
    rscore,
)
from repro.registry import PACKER_FAMILIES, list_policies, packer_for

# every registered py-backend packer (the registry-era ALL_ALGORITHMS)
PY_PACKERS = {name: packer_for(name, backend="py")
              for name in list_policies(family=PACKER_FAMILIES, backend="py")}

C = 1.0


# ---------------------------------------------------------------------------
# strategies: quantized speeds (k/1024) so float32/float64 sums are exact and
# the JAX comparison in test_jaxpack.py is bitwise meaningful.
# ---------------------------------------------------------------------------
speeds_st = st.lists(
    st.integers(min_value=0, max_value=2048).map(lambda k: k / 1024.0),
    min_size=1,
    max_size=40,
)


def with_prev(draw_speeds, seed):
    rng = np.random.default_rng(seed)
    n = len(draw_speeds)
    prev = {}
    for j in range(n):
        c = int(rng.integers(-1, max(1, n // 2)))
        if c >= 0:
            prev[j] = c
    return prev


# ---------------------------------------------------------------------------
# hand-checked examples
# ---------------------------------------------------------------------------
def test_ffd_classic_example():
    speeds = {i: w for i, w in enumerate([0.6, 0.5, 0.4, 0.3, 0.2, 0.1])}
    res = pack(speeds, C, strategy="first", decreasing=True)
    assert res.n_bins == 3
    assert res.composition() == {frozenset({0, 2}), frozenset({1, 3, 4}), frozenset({5})}


def test_next_fit_never_looks_back():
    # NF: 0.6 opens bin0; 0.5 doesn't fit -> bin1; 0.3 fits bin1; 0.4 doesn't
    # fit bin1 (0.5+0.3+0.4 > 1) -> bin2, even though bin0 had room.
    speeds = [(0, 0.6), (1, 0.5), (2, 0.3), (3, 0.4)]
    res = pack(speeds, C, strategy="next")
    assert res.n_bins == 3
    assert res.pid_to_bin[3] != res.pid_to_bin[0]


def test_best_vs_worst_fit():
    # bins after [0.5], [0.6]: best-fit puts 0.4 with 0.6 (tightest fit),
    # worst-fit with 0.5 (most slack).
    items = [(0, 0.5), (1, 0.6), (2, 0.4)]
    bf = pack(items, C, strategy="best")
    wf = pack(items, C, strategy="worst")
    assert bf.pid_to_bin[2] == bf.pid_to_bin[1]
    assert wf.pid_to_bin[2] == wf.pid_to_bin[0]


def test_oversized_item_gets_dedicated_bin():
    res = pack({0: 1.5, 1: 0.4, 2: 0.4}, C, strategy="first", decreasing=True)
    bins = res.bins()
    big = res.pid_to_bin[0]
    assert bins[big] == [0]
    assert res.loads[big] == pytest.approx(1.5)
    for name, load in res.loads.items():
        if name != big:
            assert load <= C + 1e-9


def test_sticky_naming_preserves_prev_consumer():
    prev = {0: 7, 1: 3}
    res = pack({0: 0.9, 1: 0.8}, C, strategy="first", prev=prev, sticky=True)
    # each item opens its own bin; sticky naming keeps both at home -> no moves
    assert res.pid_to_bin == prev
    assert rscore(prev, res.pid_to_bin, {0: 0.9, 1: 0.8}, C) == 0.0


def test_sticky_falls_back_to_lowest_unused_index():
    # both items previously on consumer 5; they land in one bin named 5, and a
    # third oversized item (prev consumer also 5) opens the lowest unused = 0.
    prev = {0: 5, 1: 5, 2: 5}
    res = pack({0: 0.4, 1: 0.4, 2: 0.9}, C, strategy="first", prev=prev)
    assert res.pid_to_bin[0] == 5
    assert res.pid_to_bin[2] == 0


def test_rscore_counts_only_moved_previously_assigned():
    prev = {0: 0, 1: 0, 2: 1}
    new = {0: 0, 1: 2, 2: 1, 3: 5}   # 1 moved; 3 is newly assigned
    s = {0: 0.1, 1: 0.25, 2: 0.3, 3: 0.9}
    assert rebalanced_partitions(prev, new) == {1}
    assert rscore(prev, new, s, capacity=0.5) == pytest.approx(0.5)


def test_modified_any_fit_hand_trace():
    """Manual trace of Algorithm 1 (MBF, cumulative sort).

    group: c0={p0:0.5, p1:0.3}(cum 0.8), c1={p2:0.6, p3:0.3}(cum 0.9), C=1.
    Sorted consumers: [c1(0.9), c0(0.8)].
    c1: no open bins -> phase-1 fails on p3(0.3); create bin c1; insert
        decreasing: p2(0.6) ok, p3(0.3) ok -> c1 = {p2,p3} load 0.9.
    c0: phase-1 small->big: p1(0.3) best-fit into c1? load 0.9+0.3>1 -> fail;
        create bin c0; insert decreasing p0(0.5), p1(0.3) -> c0 load 0.8.
    No unassigned left.  Nothing moved.
    """
    speeds = {0: 0.5, 1: 0.3, 2: 0.6, 3: 0.3}
    group = {0: [0, 1], 1: [2, 3]}
    res = modified_any_fit(speeds, C, group, fit="best", sort_key="cumulative")
    assert res.n_bins == 2
    assert res.pid_to_bin == {0: 0, 1: 0, 2: 1, 3: 1}
    prev = {0: 0, 1: 0, 2: 1, 3: 1}
    assert rscore(prev, res.pid_to_bin, speeds, C) == 0.0


def test_modified_any_fit_migrates_small_partitions_into_open_bins():
    """Phase-1 moves a later consumer's small partitions into earlier bins.

    c0={p0:0.9}, c1={p1:0.05, p2:0.6}: sorted [c0(0.9), c1(0.65)].
    c0 -> own bin (0.9).  c1 phase-1: p1(0.05) fits into c0's bin (best fit,
    0.95) -> migrated; p2(0.6) does not fit -> own bin c1.
    """
    speeds = {0: 0.9, 1: 0.05, 2: 0.6}
    group = {0: [0], 1: [1, 2]}
    res = modified_any_fit(speeds, C, group, fit="best", sort_key="cumulative")
    assert res.pid_to_bin == {0: 0, 1: 0, 2: 1}
    assert rscore({0: 0, 1: 1, 2: 1}, res.pid_to_bin, speeds, C) == pytest.approx(0.05)


def test_modified_break_semantics_defers_fitting_smaller_items():
    """Lines 18-25: after the own-bin insert breaks, remaining smaller items
    go to U even if they would have fit -- they are placed in the final stage.

    c0 = {p0:0.7, p1:0.6, p2:0.2}; no other consumers.
    phase-1: no bins -> fail on p2.  own bin c0: p0(0.7) ok; p1(0.6) fails ->
    break; p2(0.2) deferred to U although it fits (0.7+0.2<=1).
    Final stage: U sorted desc = [p1, p2]; best fit: p1 -> new bin (sticky
    name: prev consumer 0 taken -> lowest unused 1), p2 -> tightest = bin c0
    (0.9) vs bin1 (0.6): bin c0.
    """
    speeds = {0: 0.7, 1: 0.6, 2: 0.2}
    group = {0: [0, 1, 2]}
    res = modified_any_fit(speeds, C, group, fit="best", sort_key="cumulative")
    assert res.pid_to_bin == {0: 0, 1: 1, 2: 0}
    assert res.loads == {0: pytest.approx(0.9), 1: pytest.approx(0.6)}


def test_max_partition_sort_differs_from_cumulative():
    # c0: one big partition 0.8 (max 0.8, cum 0.8)
    # c1: three small 0.3 (max 0.3, cum 0.9)
    # cumulative order: [c1, c0]; max-partition order: [c0, c1].
    speeds = {0: 0.8, 1: 0.3, 2: 0.3, 3: 0.3}
    group = {0: [0], 1: [1, 2, 3]}
    cum = modified_any_fit(speeds, C, group, fit="best", sort_key="cumulative")
    mxp = modified_any_fit(speeds, C, group, fit="best", sort_key="max_partition")
    # same bin count but different first-created bin
    assert cum.creation_order[0] == 1
    assert mxp.creation_order[0] == 0


# ---------------------------------------------------------------------------
# property tests (paper Eqs. 6-7 + any-fit structure)
# ---------------------------------------------------------------------------
@settings(max_examples=150, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       name=st.sampled_from(sorted(PY_PACKERS)))
def test_all_algorithms_valid_packing(speeds, seed, name):
    sp = {j: w for j, w in enumerate(speeds)}
    prev = with_prev(speeds, seed)
    res = PY_PACKERS[name](sp, C, prev=prev)
    # Eq. 7: every item in exactly one bin
    assert set(res.pid_to_bin) == set(sp)
    # Eq. 6 (+ oversize rule): capacity respected unless a single oversized item
    bins = res.bins()
    for cid, members in bins.items():
        load = sum(sp[p] for p in members)
        assert load == pytest.approx(res.loads[cid], abs=1e-9)
        if load > C + 1e-9:
            assert len(members) == 1 and sp[members[0]] > C
    # bin names unique, count consistent
    assert len(set(res.creation_order)) == res.n_bins == len(bins)
    # lower bound
    if all(w <= C for w in speeds):
        assert res.n_bins >= capacity_lower_bound(speeds, C)


@settings(max_examples=150, deadline=None)
@given(speeds=speeds_st, strategy=st.sampled_from(["first", "best", "worst"]),
       decreasing=st.booleans())
def test_any_fit_at_most_one_half_empty_bin(speeds, strategy, decreasing):
    sp = {j: w for j, w in enumerate(speeds)}
    res = pack(sp, C, strategy=strategy, decreasing=decreasing)
    small = [l for l in res.loads.values() if l <= C / 2]
    assert len(small) <= 1


@settings(max_examples=100, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(["next", "first", "best", "worst"]),
       decreasing=st.booleans())
def test_sticky_only_renames(speeds, seed, strategy, decreasing):
    """Sec. IV-C: the adaptation never changes bin count or composition."""
    sp = {j: w for j, w in enumerate(speeds)}
    prev = with_prev(speeds, seed)
    a = pack(sp, C, strategy=strategy, decreasing=decreasing, prev=prev, sticky=True)
    b = pack(sp, C, strategy=strategy, decreasing=decreasing, prev=prev, sticky=False)
    assert a.n_bins == b.n_bins
    assert a.composition() == b.composition()


@settings(max_examples=100, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       fit=st.sampled_from(["best", "worst"]),
       key=st.sampled_from(["cumulative", "max_partition"]))
def test_modified_any_fit_valid(speeds, seed, fit, key):
    sp = {j: w for j, w in enumerate(speeds)}
    prev = with_prev(speeds, seed)
    res = modified_any_fit(sp, C, group_view(prev), fit=fit, sort_key=key)
    assert set(res.pid_to_bin) == set(sp)
    for cid, members in res.bins().items():
        load = sum(sp[p] for p in members)
        if load > C + 1e-9:
            assert len(members) == 1 and sp[members[0]] > C
    if all(w <= C for w in speeds):
        assert res.n_bins >= capacity_lower_bound(speeds, C)


@settings(max_examples=60, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1))
def test_modified_keeps_surviving_consumer_names(speeds, seed):
    """Every bin created as a consumer's own bin keeps the consumer id, so
    bin names of the new config that coincide with old consumers only hold
    either kept or migrated partitions -- and a partition that stays on a
    bin named like its previous consumer is never counted as rebalanced."""
    sp = {j: w for j, w in enumerate(speeds)}
    prev = with_prev(speeds, seed)
    res = modified_any_fit(sp, C, group_view(prev), fit="best", sort_key="cumulative")
    moved = rebalanced_partitions(prev, res.pid_to_bin)
    for p in set(prev) & set(res.pid_to_bin):
        if res.pid_to_bin[p] == prev[p]:
            assert p not in moved
