"""Fleet execution layer tests.

Load-bearing properties (ISSUE acceptance criteria):

* a uniform all-active fleet run equals the direct engines
  (``sweep_streams`` / ``sweep_lag``) exactly -- the fleet is a pure
  execution layer, not a different simulator;
* ragged scenarios padded into shape buckets equal their solo runs --
  padding-by-masking is exact (deterministic policies);
* the compile cache is bounded (LRU eviction) and observable;
* the ``repro.api`` verbs route through the fleet, masks included.

(The multi-device sharded-equality assertion lives in
``benchmarks/fleet_bench.py --smoke``, which CI runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` -- the device
count is fixed at process start, so it cannot be a same-process test.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core.jaxpack import sweep_streams
from repro.core.scenarios import generate_masked_scenario
from repro.fleet import FleetConfig, FleetRunner
from repro.lagsim import LagSimConfig, sweep_lag

CFG = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)


def _traces(b=3, t=12, n=6, seed=0):
    return jax.random.uniform(jax.random.key(seed), (b, t, n), maxval=0.9)


# ---------------------------------------------------------------------------
# uniform fleets == direct engines
# ---------------------------------------------------------------------------
def test_uniform_sweep_equals_direct():
    tr = _traces()
    res = FleetRunner().sweep(("BFD", "MBFP"), tr, 1.0)
    direct = sweep_streams(("BFD", "MBFP"), tr, 1.0)
    bins, rscores, migs = res.stacked()
    np.testing.assert_array_equal(bins, np.asarray(direct.bins))
    assert rscores.tobytes() == np.asarray(direct.rscores).tobytes()
    np.testing.assert_array_equal(migs, np.asarray(direct.migrations))


def test_uniform_simulate_equals_direct():
    tr = _traces(seed=1)
    res = FleetRunner().simulate(("BFD", "KEDA_LAG"), tr, CFG)
    direct = sweep_lag(("BFD", "KEDA_LAG"), tr, CFG)
    st = res.stacked()
    assert st["lag_total"].tobytes() == \
        np.asarray(direct.lag_total).tobytes()
    np.testing.assert_array_equal(st["consumers"],
                                  np.asarray(direct.consumers))
    np.testing.assert_array_equal(st["migrations"],
                                  np.asarray(direct.migrations))


def test_masked_sweep_equals_direct():
    sp, ac = generate_masked_scenario("topic_lifecycle", jax.random.key(2),
                                      2, 16, 5)
    res = FleetRunner().sweep(("BFD",), sp, 1.0, active=ac)
    direct = sweep_streams(("BFD",), sp, 1.0, ac)
    bins, rscores, _ = res.stacked()
    np.testing.assert_array_equal(bins, np.asarray(direct.bins))
    assert rscores.tobytes() == np.asarray(direct.rscores).tobytes()


# ---------------------------------------------------------------------------
# ragged fleets: bucket padding is exact
# ---------------------------------------------------------------------------
def test_ragged_sweep_equals_solo_runs():
    rng = np.random.default_rng(3)
    runner = FleetRunner(FleetConfig(t_buckets=(16,), n_buckets=(8,)))
    shapes = ((10, 5), (16, 8), (7, 3), (12, 8))
    scen = [jnp.asarray(rng.uniform(0, 1, s), jnp.float32) for s in shapes]
    res = runner.sweep(("BFD", "MWF"), scen, 1.0)
    for i, s in enumerate(scen):
        solo = sweep_streams(("BFD", "MWF"), s[None], 1.0)
        assert res.bins[i].shape == (2, s.shape[0])
        np.testing.assert_array_equal(res.bins[i],
                                      np.asarray(solo.bins)[:, 0, :])
        np.testing.assert_array_equal(res.rscores[i],
                                      np.asarray(solo.rscores)[:, 0, :])
    # every scenario landed in the single 16x8 bucket => one compile
    stats = runner.stats()
    assert stats["buckets"] == {"16x8": 4}
    assert stats["cache_misses"] == 1


def test_ragged_simulate_equals_solo_runs():
    """Padded partitions are dead (inactive) partitions, so the twin's
    trajectories are unchanged; the config resolves at each scenario's
    true N (reactive clamps must not widen to the bucket)."""
    rng = np.random.default_rng(4)
    runner = FleetRunner(FleetConfig(t_buckets=(20,), n_buckets=(8,)))
    shapes = ((14, 4), (20, 8), (9, 6))
    scen = [jnp.asarray(rng.uniform(0, 1.2, s), jnp.float32)
            for s in shapes]
    res = runner.simulate(("BFD", "KEDA_LAG"), scen, CFG)
    for i, s in enumerate(scen):
        solo = sweep_lag(("BFD", "KEDA_LAG"), s[None], CFG)
        np.testing.assert_allclose(res.lag_total[i],
                                   np.asarray(solo.lag_total)[:, 0, :],
                                   atol=1e-6)
        np.testing.assert_array_equal(res.consumers[i],
                                      np.asarray(solo.consumers)[:, 0, :])
        np.testing.assert_array_equal(res.migrations[i],
                                      np.asarray(solo.migrations)[:, 0, :])


def test_ragged_masked_scenarios_as_pairs():
    sp1, ac1 = generate_masked_scenario("churn", jax.random.key(5), 1, 12, 4)
    sp2, ac2 = generate_masked_scenario("topic_lifecycle",
                                        jax.random.key(6), 1, 18, 7)
    runner = FleetRunner(FleetConfig(t_buckets=(18,), n_buckets=(8,)))
    res = runner.sweep(("MBFP",), [(sp1[0], ac1[0]), (sp2[0], ac2[0])], 1.0)
    for i, (sp, ac) in enumerate(((sp1, ac1), (sp2, ac2))):
        solo = sweep_streams(("MBFP",), sp, 1.0, ac)
        np.testing.assert_array_equal(res.bins[i],
                                      np.asarray(solo.bins)[:, 0, :])


# ---------------------------------------------------------------------------
# bounded compile cache
# ---------------------------------------------------------------------------
def test_compile_cache_is_bounded_lru():
    runner = FleetRunner(FleetConfig(max_compile_cache=2))
    for t in (8, 9, 10):
        runner.sweep(("BFD",), _traces(1, t, 4), 1.0)
    s = runner.stats()
    assert s["cache_entries"] <= 2
    assert s["cache_misses"] == 3 and s["cache_evictions"] >= 1
    # the warm entry still answers correctly after evictions
    tr = _traces(1, 10, 4)
    res = runner.sweep(("BFD",), tr, 1.0)
    direct = sweep_streams(("BFD",), tr, 1.0)
    np.testing.assert_array_equal(res.stacked()[0], np.asarray(direct.bins))
    assert runner.stats()["cache_hits"] >= 1


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="max_compile_cache"):
        FleetConfig(max_compile_cache=0)
    with pytest.raises(ValueError, match="ascending"):
        FleetConfig(t_buckets=(32, 16))


def test_scenario_shape_validation():
    runner = FleetRunner()
    with pytest.raises(ValueError, match="f32\\[T, N\\]"):
        runner.sweep(("BFD",), [jnp.zeros((4,))], 1.0)
    with pytest.raises(ValueError, match="active mask has shape"):
        runner.sweep(("BFD",), _traces(2, 8, 4), 1.0,
                     active=jnp.ones((2, 8, 3), bool))


# ---------------------------------------------------------------------------
# repro.api routes through the fleet
# ---------------------------------------------------------------------------
def test_api_sweep_routes_through_fleet():
    tr = _traces(seed=7)
    runner = FleetRunner()
    out = api.sweep(tr, 1.0, algorithms=("BFD", "MBFP"), fleet=runner)
    direct = sweep_streams(("BFD", "MBFP"), tr, 1.0)
    np.testing.assert_array_equal(out.bins, np.asarray(direct.bins))
    assert runner.stats()["cache_misses"] == 1   # the call used THIS runner


def test_api_simulate_accepts_mask():
    sp, ac = generate_masked_scenario("topic_lifecycle", jax.random.key(8),
                                      2, 10, 4)
    out = api.simulate(sp, policies=("BFD",), active=ac)
    assert out.lag_total.shape == (1, 2, 10)
    direct = sweep_lag(("BFD",), sp, LagSimConfig(), active=ac)
    np.testing.assert_allclose(out.lag_total,
                               np.asarray(direct.lag_total), atol=1e-6)


def test_default_fleet_is_shared():
    assert api.default_fleet() is api.default_fleet()
    assert isinstance(api.default_fleet(), api.FleetRunner)
