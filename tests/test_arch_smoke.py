"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + two decode steps on CPU, asserting output shapes and
finite values.  (Full configs are exercised only by the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (forward, init_decode_state, init_params, serve_step)

ARCHS = configs.list_archs()


def make_batch(cfg, rng, batch=2, seq=16):
    ks = jax.random.split(rng, 4)
    b = {}
    if cfg.encoder_decoder:
        b["inputs"] = jax.random.normal(ks[0], (batch, cfg.encoder_seq_len,
                                                cfg.d_model), jnp.float32)
        b["decoder_tokens"] = jax.random.randint(ks[1], (batch, seq), 0,
                                                 cfg.vocab_size)
    elif cfg.input_mode == "embeddings":
        b["inputs"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                        jnp.float32)
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(jnp.arange(seq)[None, :], (batch, seq))
            b["positions"] = jnp.stack([pos, pos, pos])   # text: t==h==w
    else:
        b["inputs"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    b["labels"] = jax.random.randint(ks[2], (batch, seq), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get(arch, smoke=True)
    rng = jax.random.key(0)
    params = init_params(rng, cfg)
    batch = make_batch(cfg, jax.random.key(1))

    def loss_fn(p):
        loss, metrics = forward(p, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # loss should be near ln(vocab) at init (uniform predictions)
    assert 0.2 * np.log(cfg.vocab_size) < float(metrics["ce"]) \
        < 3.0 * np.log(cfg.vocab_size)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step_smoke(arch):
    cfg = configs.get(arch, smoke=True)
    params = init_params(jax.random.key(0), cfg)
    batch_size, max_len = 2, 32
    state = init_decode_state(cfg, batch_size, max_len)
    if cfg.encoder_decoder:
        from repro.models.whisper import encode, precompute_cross_kv
        frames = jax.random.normal(jax.random.key(1),
                                   (batch_size, cfg.encoder_seq_len,
                                    cfg.d_model), jnp.float32)
        enc = encode(params, cfg, frames)
        ck, cv = precompute_cross_kv(params, cfg, enc)
        state = dict(state, cross_k=ck, cross_v=cv)

    step = jax.jit(lambda p, s, b: serve_step(p, cfg, s, b))
    for i in range(2):
        if cfg.input_mode == "embeddings" and not cfg.encoder_decoder:
            inp = jax.random.normal(jax.random.key(10 + i),
                                    (batch_size, 1, cfg.d_model), jnp.float32)
        else:
            inp = jnp.full((batch_size,), 5 + i, jnp.int32)
        logits, state = step(params, state, {"inputs": inp})
        assert logits.shape == (batch_size, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert int(state["cache_len"]) == 2


def test_decode_matches_forward_dense():
    """Prefill-vs-decode consistency: feeding tokens one by one through the
    cache must reproduce the full-sequence logits (dense arch).  f32 compute
    so the comparison isolates cache logic from bf16 rounding."""
    import dataclasses
    cfg = dataclasses.replace(configs.get("qwen3-8b", smoke=True),
                              dtype="float32", param_dtype="float32")
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 6), 0, cfg.vocab_size)

    from repro.models.layers import logits_fn
    from repro.models.transformer import backbone
    from repro.models.layers import embed_inputs
    pos = jnp.arange(6)[None, :]
    x = embed_inputs(params["embedding"], cfg, toks)
    h, _ = backbone(params, cfg, x, pos)
    full_logits = logits_fn(params, cfg, h)      # (1, 6, V)

    state = init_decode_state(cfg, 1, 8)
    outs = []
    for t in range(6):
        lg, state = serve_step(params, cfg, state, {"inputs": toks[:, t]})
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_rwkv_decode_matches_forward():
    """RWKV recurrence: step-by-step state updates == full-sequence scan."""
    import dataclasses
    cfg = dataclasses.replace(configs.get("rwkv6-3b", smoke=True),
                              dtype="float32", param_dtype="float32")
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 5), 0, cfg.vocab_size)

    from repro.models.layers import embed_inputs, logits_fn
    from repro.models.transformer import backbone
    x = embed_inputs(params["embedding"], cfg, toks)
    h, _ = backbone(params, cfg, x, jnp.arange(5)[None, :])
    full_logits = logits_fn(params, cfg, h)

    state = init_decode_state(cfg, 1, 8)
    outs = []
    for t in range(5):
        lg, state = serve_step(params, cfg, state, {"inputs": toks[:, t]})
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_full_configs():
    """Sanity: full-config parameter counts are in the advertised ballpark."""
    import math
    expect = {
        "deepseek-67b": (60e9, 75e9),
        "qwen3-8b": (7e9, 9.5e9),
        "olmo-1b": (0.9e9, 1.5e9),
        "granite-3-8b": (7e9, 10e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "qwen2-vl-72b": (65e9, 80e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
