"""Streaming-observability tests: sketches, alerting, export.

Load-bearing properties (ISSUE acceptance criteria):

* sketches/alerts **on** never change the simulated trajectories, and
  **off** leaves the engine's program untouched (``.sketch`` /
  ``.incidents`` stay ``None``);
* sketch moments agree with full-frame numpy on the recorded channels,
  and histogram quantiles agree with ``np.quantile(...,
  method="inverted_cdf")`` within one bin width;
* the debiased EWMA matches a reference python loop;
* fleet bucket padding is exact: padded sketch and alert state equal the
  direct engine's bit-for-bit, and ``merge_summaries`` over scenario
  parts equals a summary of the whole;
* alert rules open/close incidents with the documented step semantics,
  the bounded incident table overflows by counting (not corrupting);
* a fixed-seed run decodes to the checked-in golden incident stream
  (``tests/data/golden_incidents.json``);
* Prometheus exposition round-trips the validator, the validator rejects
  malformed exposition, and OTLP JSON is deterministic;
* the bench gate classifies incident leaves as regressions even from a
  zero baseline, and ``api.simulate`` surfaces sketches + incidents.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scenarios import generate_masked_scenario
from repro.fleet import FleetConfig, FleetProgress, FleetRunner
from repro.lagsim import LagSimConfig, simulate_lag, sweep_lag
from repro.telemetry import (
    AlertConfig,
    AlertRule,
    SketchConfig,
    SketchSummary,
    TelemetryConfig,
    alert_init,
    alert_step,
    decode_incidents,
    default_rules,
    incident_counts,
    incident_summary,
    merge_summaries,
    otlp_metrics_json,
    prometheus_exposition,
    summaries_from_state,
    validate_exposition,
)

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "golden_incidents.json")

CFG = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
TRACE_FIELDS = ("lag_total", "lag_max", "consumers", "migrations",
                "unreadable")
POLICIES = ("MBFP", "KEDA_LAG")


def _obs(cfg, *, frames=True, sketch=True, alerts=True, **sk):
    return dataclasses.replace(cfg, telemetry=TelemetryConfig(
        record_frames=frames,
        sketch=SketchConfig(**sk) if sketch else None,
        alerts=AlertConfig(rules=default_rules()) if alerts else None))


def _scenario(seed=0, batch=2, t=24, n=6):
    return generate_masked_scenario(
        "topic_lifecycle", jax.random.key(seed), batch, t, n)


# ---------------------------------------------------------------------------
# on never changes trajectories; off carries nothing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_sketch_alerts_on_trajectories_unchanged(policy):
    speeds, active = _scenario()
    off = simulate_lag(speeds[0], policy=policy, cfg=CFG, active=active[0])
    on = simulate_lag(speeds[0], policy=policy, cfg=_obs(CFG),
                      active=active[0])
    for f in TRACE_FIELDS:
        assert np.asarray(getattr(off, f)).tobytes() == \
            np.asarray(getattr(on, f)).tobytes(), f
    assert off.sketch is None and off.incidents is None
    assert on.sketch is not None and on.incidents is not None


def test_frames_off_still_sketches():
    """``record_frames=False`` drops the O(T) frame but keeps the O(1)
    sketch + alert state -- the planet-scale configuration."""
    speeds, active = _scenario()
    res = simulate_lag(speeds[0], policy="MBFP",
                       cfg=_obs(CFG, frames=False), active=active[0])
    assert res.telemetry is None
    assert res.sketch is not None and res.incidents is not None
    assert float(res.sketch.count) == speeds.shape[1]


def test_config_validation():
    with pytest.raises(ValueError, match="ring"):
        TelemetryConfig(record_frames=False, ring=8)
    with pytest.raises(TypeError, match="SketchConfig"):
        TelemetryConfig(sketch="yes")
    with pytest.raises(TypeError, match="AlertConfig"):
        TelemetryConfig(alerts="yes")
    with pytest.raises(ValueError, match="hist_bins"):
        SketchConfig(hist_bins=1)
    with pytest.raises(ValueError, match="ewma_halflives"):
        SketchConfig(ewma_halflives=(0.0,))
    with pytest.raises(ValueError, match="at least one AlertRule"):
        AlertConfig()
    with pytest.raises(ValueError, match="unknown alert kind"):
        AlertRule(name="x", kind="nope")
    with pytest.raises(ValueError, match="unique"):
        AlertConfig(rules=(AlertRule.slo_burn(), AlertRule.slo_burn()))
    with pytest.raises(ValueError, match="unknown channel"):
        simulate_lag(_scenario()[0][0], policy="MBFP",
                     cfg=_obs(CFG, hist_channels=("nope",)))


# ---------------------------------------------------------------------------
# sketch numerics vs full-frame numpy
# ---------------------------------------------------------------------------

def _summary_and_frame(policy="MBFP", seed=0, t=48, n=6):
    speeds, active = _scenario(seed=seed, batch=1, t=t, n=n)
    cfg = _obs(CFG, alerts=False)
    res = simulate_lag(speeds[0], policy=policy, cfg=cfg, active=active[0])
    rcfg = cfg.resolve(n)
    summary = SketchSummary.from_state(res.sketch, rcfg.telemetry.sketch)
    return summary, np.asarray(res.telemetry.channels), rcfg.telemetry.sketch


def test_sketch_moments_match_numpy():
    summary, frame, _ = _summary_and_frame()
    assert summary.count == frame.shape[0]
    assert np.allclose(summary.mean, frame.mean(axis=0), atol=1e-4)
    assert np.allclose(summary.variance(), frame.var(axis=0), atol=1e-3)
    assert np.allclose(summary.vmin, frame.min(axis=0), atol=1e-6)
    assert np.allclose(summary.vmax, frame.max(axis=0), atol=1e-6)


@pytest.mark.parametrize("q", (0.5, 0.9, 0.99))
def test_sketch_quantile_within_bin_width(q):
    summary, frame, scfg = _summary_and_frame()
    lag = frame[:, summary.channel_index("lag_total")]
    exact = float(np.quantile(lag, q, method="inverted_cdf"))
    got = summary.quantile(q, "lag_total")
    assert abs(got - exact) <= scfg.bin_width + 1e-6, (got, exact)


def test_ewma_matches_reference_loop():
    summary, frame, scfg = _summary_and_frame()
    for h, got in summary.ewma.items():
        alpha = 1.0 - 2.0 ** (-1.0 / h)
        acc = np.zeros(frame.shape[1])
        w = 0.0
        for row in frame:
            acc = (1 - alpha) * acc + alpha * row
            w = (1 - alpha) * w + alpha
        assert np.allclose(got, acc / w, atol=1e-4), h


def test_sweep_stacks_sketch_and_for_policy_slices():
    speeds, active = _scenario()
    res = sweep_lag(POLICIES, speeds, cfg=_obs(CFG), active=active)
    p, b = len(POLICIES), speeds.shape[0]
    assert res.sketch.count.shape == (p, b)
    assert res.incidents.count.shape[:2] == (p, b)
    one = res.for_policy("KEDA_LAG")
    assert np.array_equal(np.asarray(one.sketch.mean),
                          np.asarray(res.sketch.mean[1]))
    cfg = _obs(CFG).resolve(speeds.shape[2])
    pairs = summaries_from_state(res.sketch, cfg.telemetry.sketch)
    assert [idx for idx, _ in pairs] == \
        [(i, j) for i in range(p) for j in range(b)]


# ---------------------------------------------------------------------------
# fleet padding exactness + merging + progress
# ---------------------------------------------------------------------------

def test_fleet_padded_sketch_and_alerts_match_direct():
    speeds, active = _scenario(t=20, n=5)
    cfg = _obs(CFG)
    fleet = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    res = fleet.simulate(POLICIES, speeds, cfg, active=active)
    rcfg = cfg.resolve(speeds.shape[2])
    for i in range(speeds.shape[0]):
        for pi, pol in enumerate(POLICIES):
            direct = simulate_lag(speeds[i], policy=pol, cfg=cfg,
                                  active=active[i])
            got = jax.tree_util.tree_map(lambda a: a[pi], res.sketch[i])
            for fld in ("count", "mean", "m2", "vmin", "vmax", "ewma",
                        "ewma_w", "hist"):
                assert np.asarray(getattr(got, fld)).tobytes() == \
                    np.asarray(getattr(direct.sketch, fld)).tobytes(), \
                    (i, pol, fld)
            inc = jax.tree_util.tree_map(lambda a: a[pi], res.incidents[i])
            for fld in ("tick", "active", "open_step", "close_step",
                        "peak", "count"):
                assert np.asarray(getattr(inc, fld)).tobytes() == \
                    np.asarray(getattr(direct.incidents, fld)).tobytes(), \
                    (i, pol, fld)
            # and the finalized views agree
            want = SketchSummary.from_state(direct.sketch,
                                            rcfg.telemetry.sketch)
            have = dict(res.sketch_summaries(i))[(pi,)]
            assert np.array_equal(have.mean, want.mean)
    # decoded incidents carry the policy index
    incs = res.scenario_incidents(0)
    assert incs and all(inc.index[0] in (0, 1) for inc in incs)


def test_fleet_raises_named_errors_when_off():
    speeds, active = _scenario(t=10, n=4)
    fleet = FleetRunner(FleetConfig())
    res = fleet.simulate(("MBFP",), speeds, CFG, active=active)
    with pytest.raises(ValueError, match="no sketches"):
        res.sketch_summaries(0)
    with pytest.raises(ValueError, match="no alerting"):
        res.scenario_incidents(0)


def test_merge_summaries_equals_whole():
    """Chan's merge over per-scenario summaries == one summary whose
    counts/hist are the element-wise union."""
    speeds, active = _scenario(batch=3, t=32, n=6)
    cfg = _obs(CFG, alerts=False)
    res = sweep_lag(("MBFP",), speeds, cfg=cfg, active=active)
    scfg = cfg.resolve(speeds.shape[2]).telemetry.sketch
    parts = [s for _, s in summaries_from_state(res.sketch, scfg)]
    merged = merge_summaries(parts)
    frames = np.asarray(res.telemetry.channels)[0]     # [B, T, K]
    allsteps = frames.reshape(-1, frames.shape[-1])
    assert merged.count == allsteps.shape[0]
    assert np.allclose(merged.mean, allsteps.mean(axis=0), atol=1e-4)
    assert np.allclose(merged.variance(), allsteps.var(axis=0), atol=1e-3)
    assert np.allclose(merged.vmin, allsteps.min(axis=0))
    assert np.allclose(merged.vmax, allsteps.max(axis=0))
    assert np.allclose(merged.hist.sum(axis=1),
                       [allsteps.shape[0]] * len(merged.hist_names))
    with pytest.raises(ValueError, match="at least one summary"):
        merge_summaries([])


def test_fleet_progress_callback_streams_snapshots():
    speeds_a, active_a = _scenario(seed=0, batch=2, t=20, n=5)
    speeds_b, active_b = _scenario(seed=1, batch=1, t=40, n=5)
    scen = [(speeds_a[i], active_a[i]) for i in range(2)]
    scen.append((speeds_b[0], active_b[0]))
    fleet = FleetRunner(FleetConfig(t_buckets=(32, 64), n_buckets=(8,)))
    snaps = []
    fleet.simulate(POLICIES, scen, _obs(CFG), progress=snaps.append)
    assert len(snaps) >= 2                       # two bucket groups
    assert [s.done for s in snaps] == sorted(s.done for s in snaps)
    last = snaps[-1]
    assert isinstance(last, FleetProgress)
    assert last.done == last.total == len(scen)
    assert last.sketch is not None and last.sketch.count > 0
    assert set(last.incidents) == set(r.name for r in default_rules())


# ---------------------------------------------------------------------------
# alert semantics: open/close steps, durations, overflow
# ---------------------------------------------------------------------------

def _drive(cfg, signals):
    """Run ``alert_step`` over ``signals`` dicts; -> final state."""
    state = alert_init(cfg)
    for sig in signals:
        state = alert_step(cfg, state, slo_lag=1.0, **sig)
    return state


def _quiet(**kw):
    sig = dict(lag_total=0.0, consumers=1.0, unreadable=0.0,
               storm_parts=0.0)
    sig.update(kw)
    return sig


def test_storm_incident_open_close_steps():
    """rebalance_storm fires on the storm_steps-th consecutive blocked
    step and closes on the first unblocked one (close_step inclusive)."""
    cfg = AlertConfig(rules=(AlertRule.rebalance_storm(storm_steps=3),))
    sigs = [_quiet()] * 2 + [_quiet(unreadable=2.0)] * 5 + [_quiet()] * 2
    state = _drive(cfg, sigs)
    (inc,) = decode_incidents(state, cfg, dt=2.0)
    assert inc.kind == "rebalance_storm" and not inc.still_open
    # blocked on steps 2..6 -> consec hits 3 at step 4, unblocked at 7
    assert (inc.open_step, inc.close_step) == (4, 6)
    assert inc.duration_s == (6 - 4 + 1) * 2.0
    assert inc.peak == 5.0                       # longest consec run


def test_still_open_incident_closes_at_last_step():
    cfg = AlertConfig(rules=(AlertRule.rebalance_storm(storm_steps=2),))
    state = _drive(cfg, [_quiet(unreadable=1.0)] * 4)
    (inc,) = decode_incidents(state, cfg)
    assert inc.still_open
    assert (inc.open_step, inc.close_step) == (1, 3)
    assert inc.duration_s == 3.0


def test_incident_table_overflow_counts_without_rows():
    cfg = AlertConfig(rules=(AlertRule.rebalance_storm(storm_steps=1),),
                      max_incidents=1)
    burst = [_quiet(unreadable=1.0), _quiet()]
    state = _drive(cfg, burst * 3)
    assert incident_counts(state) == {"rebalance_storm": 3}
    decoded = decode_incidents(state, cfg)
    assert len(decoded) == 1                     # only the tabled row
    assert decoded[0].open_step == 0
    summ = incident_summary(state, cfg)["rebalance_storm"]
    assert summ["count"] == 3.0 and summ["open"] == 0.0


def test_slo_burn_needs_both_windows():
    """Once the slow window is anchored by healthy history, a short lag
    spike burns only the fast window -- multi-window burn rate
    suppresses the page; a sustained violation burns both and fires."""
    rule = AlertRule.slo_burn(slo_target=0.9, burn_threshold=3.0,
                              fast_halflife=2.0, slow_halflife=64.0)
    cfg = AlertConfig(rules=(rule,))
    healthy = [_quiet()] * 40
    spike = healthy + [_quiet(lag_total=5.0)] * 3 + [_quiet()] * 10
    assert incident_counts(_drive(cfg, spike)) == {"slo_burn": 0}
    sustained = healthy + [_quiet(lag_total=5.0)] * 30
    assert incident_counts(_drive(cfg, sustained)) == {"slo_burn": 1}


def test_valid_false_freezes_alert_state():
    cfg = AlertConfig(rules=default_rules())
    state = alert_init(cfg)
    st1 = alert_step(cfg, state, slo_lag=1.0, **_quiet(lag_total=9.0))
    frozen = alert_step(cfg, st1, slo_lag=1.0, valid=jnp.asarray(False),
                        **_quiet(lag_total=99.0))
    for fld in ("tick", "fast", "prev_lag", "count"):
        assert np.array_equal(np.asarray(getattr(frozen, fld)),
                              np.asarray(getattr(st1, fld))), fld


# ---------------------------------------------------------------------------
# golden incident stream (fixed seed, pinned)
# ---------------------------------------------------------------------------

def _golden_incidents():
    """The exact fixed-seed run the golden file pins (see the generator
    note inside the golden)."""
    speeds, active = _scenario(seed=0, batch=2, t=32, n=8)
    cfg = _obs(CFG, frames=False)
    res = simulate_lag(speeds[0], policy="KEDA_LAG", cfg=cfg,
                       active=active[0])
    return decode_incidents(res.incidents, cfg.telemetry.alerts, dt=CFG.dt)


def test_golden_incident_stream():
    with open(GOLDEN) as f:
        want = json.load(f)
    got = [inc.as_dict() for inc in _golden_incidents()]
    assert len(got) == len(want["incidents"])
    for g, w in zip(got, want["incidents"]):
        for key in ("rule", "kind", "severity", "open_step", "close_step",
                    "still_open", "index"):
            assert g[key] == w[key], (g, w, key)
        assert g["duration_s"] == pytest.approx(w["duration_s"])
        assert g["peak"] == pytest.approx(w["peak"], abs=1e-4)


# ---------------------------------------------------------------------------
# export: Prometheus + OTLP
# ---------------------------------------------------------------------------

def _export_inputs():
    speeds, active = _scenario(batch=1, t=32, n=6)
    cfg = _obs(CFG, frames=False)
    res = simulate_lag(speeds[0], policy="KEDA_LAG", cfg=cfg,
                       active=active[0])
    rcfg = cfg.resolve(6)
    summary = SketchSummary.from_state(res.sketch, rcfg.telemetry.sketch)
    incidents = decode_incidents(res.incidents, cfg.telemetry.alerts)
    return summary, incidents


def test_prometheus_exposition_lints_clean():
    summary, incidents = _export_inputs()
    text = prometheus_exposition(sketch=summary, incidents=incidents,
                                 spans={"api.simulate": {
                                     "count": 2, "total_us": 10.0,
                                     "steady_us": 4.0}},
                                 labels={"run": "test"})
    validate_exposition(text)
    assert 'repro_sketch_mean{channel="lag_total",run="test"}' in text
    assert "# TYPE repro_sketch_lag_total histogram" in text
    assert 'le="+Inf"' in text
    assert "repro_incidents_total{" in text
    assert "repro_span_calls_total{" in text
    with pytest.raises(ValueError, match="label"):
        prometheus_exposition(sketch=summary, labels={"bad-name": "x"})


def test_validator_rejects_malformed_exposition():
    with pytest.raises(ValueError, match="no preceding # TYPE"):
        validate_exposition("untyped_metric 1\n")
    with pytest.raises(ValueError, match="invalid metric name"):
        validate_exposition("# TYPE 9bad counter\n")
    with pytest.raises(ValueError, match="non-numeric"):
        validate_exposition("# TYPE m gauge\nm abc\n")
    with pytest.raises(ValueError, match="not cumulative"):
        validate_exposition(
            "# TYPE h histogram\n"
            'h_bucket{le="1"} 5\nh_bucket{le="2"} 3\n'
            'h_bucket{le="+Inf"} 5\nh_count 5\n')
    with pytest.raises(ValueError, match="no '\\+Inf'"):
        validate_exposition(
            "# TYPE h histogram\n"
            'h_bucket{le="1"} 5\nh_count 5\n')
    with pytest.raises(ValueError, match="!= _count"):
        validate_exposition(
            "# TYPE h histogram\n"
            'h_bucket{le="+Inf"} 5\nh_count 7\n')


def test_otlp_metrics_json_deterministic_and_coherent():
    summary, incidents = _export_inputs()
    a = otlp_metrics_json(sketch=summary, incidents=incidents)
    b = otlp_metrics_json(sketch=summary, incidents=incidents)
    assert a == b                                # no wall clock leaked
    metrics = a["resourceMetrics"][0]["scopeMetrics"][0]["metrics"]
    by_name = {m["name"]: m for m in metrics}
    hist = by_name["repro.sketch.hist.lag_total"]["histogram"]["dataPoints"][0]
    assert sum(int(c) for c in hist["bucketCounts"]) == int(hist["count"])
    assert len(hist["explicitBounds"]) == len(hist["bucketCounts"]) - 1
    counts = by_name["repro.incidents.count"]["sum"]["dataPoints"]
    assert sum(p["asDouble"] for p in counts) == len(incidents)
    assert json.dumps(a)                         # JSON-serializable


# ---------------------------------------------------------------------------
# bench gate + api surface
# ---------------------------------------------------------------------------

def test_bench_diff_gates_incident_leaves():
    from benchmarks.bench_diff import (DEFAULT_THRESHOLD, _direction, diff,
                                       _inject_incident_regression)

    # incident classification wins over the informational fragments
    assert _direction(("telemetry", "incidents", "count")) == "incident"
    assert _direction(("observability", "per_policy", "MBFP", "incidents",
                       "slo_burn", "count")) == "incident"
    assert _direction(("observability", "per_policy", "MBFP", "sketch",
                       "channels", "lag_total", "mean")) == "info"
    report = {"kind": "x", "observability": {"per_policy": {"MBFP": {
        "incidents": {"slo_burn": {"count": 0.0, "total_duration_s": 0.0},
                      "lag_growth": {"count": 2.0}}}}}}
    # zero baseline still gates: 0 -> 1 incident is a regression
    hurt = _inject_incident_regression(report)
    res = diff(report, hurt, DEFAULT_THRESHOLD)
    regressed = {name for name, *_ in res["regressions"]}
    assert any(name.endswith("slo_burn/count") for name in regressed)
    assert any(name.endswith("lag_growth/count") for name in regressed)
    # identity diff is clean; fewer incidents is an improvement
    assert diff(report, report, DEFAULT_THRESHOLD)["regressions"] == []
    better = json.loads(json.dumps(report))
    better["observability"]["per_policy"]["MBFP"]["incidents"][
        "lag_growth"]["count"] = 0.0
    res = diff(report, better, DEFAULT_THRESHOLD)
    assert res["regressions"] == [] and len(res["improvements"]) == 1


def test_api_simulate_surfaces_sketches_and_incidents():
    from repro import api

    speeds, active = _scenario()
    out = api.simulate(
        speeds, policies=POLICIES, config=CFG, active=active,
        telemetry=TelemetryConfig(record_frames=False,
                                  sketch=SketchConfig(),
                                  alerts=AlertConfig(rules=default_rules())))
    assert out.telemetry is None
    assert len(out.sketches) == speeds.shape[0]
    assert len(out.sketches[0]) == len(POLICIES)
    merged = merge_summaries([s for per in out.sketches for s in per])
    assert merged.count == len(POLICIES) * speeds.shape[0] * speeds.shape[1]
    incs = [i for per in out.incidents for i in per]
    assert incs and all(i.index[0] < len(POLICIES) for i in incs)
    validate_exposition(prometheus_exposition(sketch=merged, incidents=incs))
    # without the override nothing observability-shaped is carried
    plain = api.simulate(speeds[:1], policies=("MBFP",), config=CFG,
                         active=active[:1])
    assert plain.sketches is None and plain.incidents is None
