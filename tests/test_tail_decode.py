"""Block-buffered (tail) decode correctness: stepping with a small tail
window + periodic flush must reproduce the full-sequence forward logits and
match the direct-DUS decode path exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import forward, init_decode_state, init_params, serve_step
from repro.models.attention import flush_kv_tail
from repro.models.layers import embed_inputs, logits_fn
from repro.models.transformer import backbone

W = 4
N_TOK = 11   # crosses two flush boundaries (at 4 and 8)


def _cfgs():
    base = dataclasses.replace(configs.get("qwen3-8b", smoke=True),
                               dtype="float32", param_dtype="float32")
    return base, dataclasses.replace(base, decode_tail_window=W)


def test_tailed_decode_matches_forward_and_plain_decode():
    cfg, cfg_tail = _cfgs()
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, N_TOK), 0, cfg.vocab_size)

    # reference: full-sequence forward
    pos = jnp.broadcast_to(jnp.arange(N_TOK)[None], (2, N_TOK))
    h, _ = backbone(params, cfg, embed_inputs(params["embedding"], cfg, toks),
                    pos)
    full_logits = np.asarray(logits_fn(params, cfg, h), np.float32)

    # plain decode
    state_p = init_decode_state(cfg, 2, 16)
    # tailed decode with flush every W steps
    state_t = init_decode_state(cfg_tail, 2, 16)
    assert "tail" in state_t

    for t in range(N_TOK):
        lg_p, state_p = serve_step(params, cfg, state_p,
                                   {"inputs": toks[:, t]})
        lg_t, state_t = serve_step(params, cfg_tail, state_t,
                                   {"inputs": toks[:, t]})
        if int(state_t["cache_len"]) % W == 0:
            state_t = flush_kv_tail(cfg_tail, state_t)
        np.testing.assert_allclose(np.asarray(lg_t, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   atol=2e-3, rtol=2e-3,
                                   err_msg=f"tail vs plain at step {t}")
        np.testing.assert_allclose(np.asarray(lg_t, np.float32),
                                   full_logits[:, t], atol=2e-2, rtol=2e-2,
                                   err_msg=f"tail vs forward at step {t}")

    # after the run, main holds the flushed prefix and tail the remainder
    main_len = (N_TOK // W) * W
    k_main = np.asarray(state_t["kv"]["k"][0, 0, 0, :, 0], np.float32)
    assert np.any(k_main[:main_len] != 0.0)
    assert np.all(k_main[main_len + 1:] == 0.0)  # beyond flushed region empty
