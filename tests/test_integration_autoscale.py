"""End-to-end integration tests of the autoscaling pipeline (paper Sec. VI-D:
'Our approach guarantees adequate consumption rates ... at lower operational
costs')."""
import numpy as np
import pytest

from repro.broker import TopicPartition
from repro.core.controller import Controller, ControllerConfig, ControllerState
from repro.serving import AutoscaleSimulation

CAP = 1.0e6  # 1 MB/s replica capacity for readable numbers


def make_sim(rates, **kw):
    return AutoscaleSimulation(
        n_partitions=len(rates),
        rate_fn=AutoscaleSimulation.constant_rates(rates),
        capacity=CAP,
        monitor_interval=5.0,
        **kw,
    )


def test_scales_to_load_and_keeps_lag_bounded():
    # total load 2.2 MB/s -> at least 3 consumers; autoscaler must keep up.
    rates = [0.55e6, 0.55e6, 0.55e6, 0.55e6]
    sim = make_sim(rates)
    m = sim.run(seconds=400, dt=1.0)
    n = np.asarray(m.n_replicas)
    lag = np.asarray(m.lag_bytes)
    assert n[-1] >= 3
    # lag stops growing once scaled: compare last two quarters
    q = len(lag) // 4
    assert lag[-1] <= lag[-q] + 2 * CAP  # bounded (allowing batching slack)
    # consumption keeps pace with production overall
    assert m.consumed and sum(m.consumed) >= 0.9 * sim.produced_bytes - 10 * CAP


def test_scales_down_when_load_drops():
    rates = [0.8e6] * 6  # 4.8 MB/s -> ~5-6 consumers
    sim = make_sim(rates)
    sim.run(seconds=200)
    high = sim.manager.n_alive()
    assert high >= 5
    # drop load to 0.4 MB/s total -> 1 consumer suffices
    sim.rate_fn = AutoscaleSimulation.constant_rates([0.4e6 / 6] * 6)
    sim.run(seconds=400)
    low = sim.manager.n_alive()
    assert low <= 2, f"did not scale down: {high} -> {low}"


def test_single_reader_invariant_under_migrations():
    """The broker raises if two group members ever read one partition; a
    churny workload with many reassignments must never trigger it."""
    sim = AutoscaleSimulation(
        n_partitions=10,
        rate_fn=AutoscaleSimulation.random_walk_rates(10, CAP, delta=25, seed=3),
        capacity=CAP,
        monitor_interval=5.0,
    )
    sim.run(seconds=600)  # raises on violation
    assert len(sim.controller.migrations) >= 2
    # every finished migration recorded an Rscore consistent with its moves
    for rec in sim.controller.migrations:
        assert rec.rscore >= 0.0
        if rec.moved:
            assert rec.rscore > 0.0


def test_replica_crash_recovery():
    rates = [0.5e6] * 4
    sim = make_sim(rates, heartbeat_timeout=20.0)
    sim.run(seconds=120)
    assert sim.manager.n_alive() >= 2
    # hard-kill the busiest replica: no shutdown, no partition release
    victim_cid = next(iter(sim.manager.list()))
    victim = sim.manager.replicas[victim_cid]
    victim.crash()
    sim.run(seconds=200)
    # controller expelled the dead member; the id may be reused by a fresh
    # incarnation, but the crashed object must be out of the fleet
    assert all(not r.crashed for r in sim.manager.replicas.values())
    assert sim.manager.replicas.get(victim_cid) is not victim
    assigned = set(sim.controller.assignment.keys())
    expected = {TopicPartition("sensors", i) for i in range(4)}
    assert assigned == expected
    # and consumption continues (lag bounded after recovery)
    lag = np.asarray(sim.metrics.lag_bytes)
    assert lag[-1] <= lag[len(lag) // 2] + 30 * CAP


def test_straggler_is_drained():
    rates = [0.45e6] * 4
    sim = make_sim(rates)
    sim.run(seconds=150)
    victim = next(iter(sim.manager.list()))
    sim.manager.replicas[victim].rate_factor = 0.2  # degrade to 20% capacity
    for _ in range(200):
        sim.tick(1.0)
        sim.controller.check_stragglers(rate_threshold=0.35)
    assert victim not in sim.manager.list(), "straggler was not drained"
    # its partitions were repacked onto healthy replicas
    assert set(sim.controller.assignment) == {
        TopicPartition("sensors", i) for i in range(4)}


def test_controller_crash_synchronize_recovery():
    rates = [0.5e6] * 4
    # 5% overload headroom so measurement jitter around exactly-C loads does
    # not trigger a legitimate (but test-confusing) repack after recovery
    sim = make_sim(rates, overload_factor=1.05)
    sim.run(seconds=150)
    old_assignment = dict(sim.controller.assignment)
    assert old_assignment
    # controller dies; a fresh one must rebuild its perceived state from the
    # consumers' reports (SYNCHRONIZE), not from scratch.
    sim.controller = Controller.recover(
        sim.broker, sim.manager,
        ControllerConfig(capacity=CAP, algorithm="MBFP", overload_factor=1.05))
    assert sim.controller.state is ControllerState.SYNCHRONIZE
    sim.run(seconds=60)
    assert sim.controller.state is not ControllerState.SYNCHRONIZE
    assert sim.controller.assignment == old_assignment
    # no spurious migration was triggered by recovery
    assert all(not rec.moved for rec in sim.controller.migrations)
