"""Substrate tests: optimizer, checkpoint store, data pipeline, gradient
compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import LoaderPool, ShardSpec, TokenPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, ef_int8_compress_state,
                         ef_int8_psum, warmup_cosine)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(5.0)}
    target = {"w": jnp.array([1.0, 1.0]), "b": jnp.array(-1.0)}
    state = adamw_init(params)

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_warmup_cosine_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(warmup_cosine(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == pytest.approx(0.0)
    assert lrs[10] == pytest.approx(1.0, abs=1e-6)
    assert lrs[100] == pytest.approx(0.1, abs=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # monotone decay


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                      "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, tree, extra={"note": "hi"})
    assert latest_step(d) == 3
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = restore_checkpoint(d, 3, target)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_rotation(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, {"x": jnp.full((2,), s, jnp.float32)})
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    step, tree = mgr.restore_latest({"x": jax.ShapeDtypeStruct((2,), jnp.float32)})
    assert step == 4 and float(tree["x"][0]) == 4.0


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"x": jnp.ones((8,))})
    blob = [f for f in os.listdir(os.path.join(d, "step_00000001"))
            if f.endswith((".zst", ".zz"))][0]
    path = os.path.join(d, "step_00000001", blob)
    with open(path, "r+b") as f:
        f.seek(4)
        f.write(b"\x00\x01")
    with pytest.raises(Exception):
        restore_checkpoint(d, 1, {"x": jax.ShapeDtypeStruct((8,), jnp.float32)})


def test_checkpoint_zlib_fallback_roundtrip(tmp_path, monkeypatch):
    """Without zstandard, blobs are zlib-compressed .zz files and restore
    exactly; the codec is recorded per leaf in the manifest."""
    from repro.checkpoint import store
    monkeypatch.setattr(store, "zstd", None)
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(6.0), "b": jnp.ones((2, 2))}
    store.save_checkpoint(d, 1, tree)
    files = os.listdir(os.path.join(d, "step_00000001"))
    assert all(f.endswith(".zz") for f in files if f != "MANIFEST.msgpack")
    out = store.restore_checkpoint(
        d, 1, {"w": jax.ShapeDtypeStruct((6,), jnp.float32),
               "b": jax.ShapeDtypeStruct((2, 2), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(6.0))
    np.testing.assert_array_equal(np.asarray(out["b"]), np.ones((2, 2)))


def test_checkpoint_zstd_without_zstandard_raises(tmp_path, monkeypatch):
    """A zstd-coded checkpoint on a host without zstandard fails loudly."""
    import msgpack

    from repro.checkpoint import store
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"x": jnp.ones((4,))})
    mpath = os.path.join(d, "step_00000001", "MANIFEST.msgpack")
    with open(mpath, "rb") as f:
        manifest = msgpack.unpackb(f.read())
    for meta in manifest["leaves"].values():
        meta["codec"] = "zstd"
    with open(mpath, "wb") as f:
        f.write(msgpack.packb(manifest))
    monkeypatch.setattr(store, "zstd", None)
    with pytest.raises(ImportError, match="zstandard is not"):
        store.restore_checkpoint(
            d, 1, {"x": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_pipeline_determinism_and_resume():
    p1 = TokenPipeline(batch_size=4, seq_len=32, vocab_size=1000, seed=1)
    batches = [p1.next_batch() for _ in range(3)]
    state = p1.state()
    b4 = p1.next_batch()
    # fresh pipeline, restore state -> identical continuation
    p2 = TokenPipeline(batch_size=4, seq_len=32, vocab_size=1000, seed=1)
    p2.load_state(state)
    b4b = p2.next_batch()
    np.testing.assert_array_equal(b4["inputs"], b4b["inputs"])
    # labels are inputs shifted by one
    np.testing.assert_array_equal(batches[0]["inputs"][:, 1:],
                                  batches[0]["labels"][:, :-1])


def test_loader_pool_packs_and_sticks():
    specs = [ShardSpec(i, i, rate=1.0) for i in range(8)]
    pool = LoaderPool(specs, capacity=3.0)
    n0 = pool.n_loaders()
    assert n0 >= 3  # 8 units of rate / capacity 3
    before = dict(pool.assignment)
    # small drift: most shards must stay on their loader (sticky packing)
    pool.repack(rates={i: 1.05 for i in range(8)})
    moved = sum(1 for k in before if pool.assignment[k] != before[k])
    assert moved <= 2


def test_ef_int8_psum_error_feedback():
    """Compressed psum with error feedback: per-step error is bounded and the
    residual carries what was lost, so the *running sum* tracks the true
    gradient sum (vmap axis_name provides the collective semantics on one
    device; shard_map over the pod axis uses identical code in train.py)."""
    axis_size, steps = 4, 6

    @jax.jit
    def one_step(g, r):
        f = jax.vmap(lambda gg, rr: ef_int8_psum({"g": gg}, {"g": rr}, "pod"),
                     axis_name="pod")
        out, new_r = f(g, r)
        return out["g"], new_r["g"]

    rng = np.random.default_rng(0)
    tot_true = np.zeros(16, np.float32)
    tot_hat = np.zeros(16, np.float32)
    r = jnp.zeros((axis_size, 16), jnp.float32)
    for s in range(steps):
        g = rng.normal(size=(axis_size, 16)).astype(np.float32)
        out, r = one_step(jnp.asarray(g), r)
        tot_true += g.mean(0)
        tot_hat += np.asarray(out)[0]
        # every pod sees the same reduced gradient
        np.testing.assert_allclose(np.asarray(out)[0], np.asarray(out)[-1])
    # error feedback keeps the cumulative estimate close to the true sum
    np.testing.assert_allclose(tot_hat, tot_true, atol=0.05)
    assert float(jnp.max(jnp.abs(r))) > 0.0  # residual is actually carried
