"""Cross-backend parity, driven by the registry: for every policy name
registered with BOTH a ``py`` and a ``jax`` backend, the two packers must
agree bit-for-bit -- same bin names per item, same loads, same bin count
-- across random instances and random previous assignments.

No hand-enumerated algorithm lists: the parametrization is
``repro.registry.list_policies``, so a policy added on both backends is
automatically under test (and a jax-only or py-only packer would simply
not enter the parity set).

Speeds are quantized to k/1024 so all load sums are exact in float32: any
disagreement is a logic bug, never rounding.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import run_stream
from repro.core.streams import generate_stream
from repro.registry import PACKER_FAMILIES, get_spec, list_policies, packer_for

C = 1.0

#: every name registered on both backends -- the parity set
BOTH_BACKENDS = tuple(
    name
    for name in list_policies(backend="jax")
    if name in list_policies(backend="py")
)

speeds_st = st.lists(
    st.integers(min_value=0, max_value=2048).map(lambda k: k / 1024.0),
    min_size=1,
    max_size=24,
)


def test_parity_set_covers_all_packers():
    """Every packer family member is registered on both backends, so the
    property tests below cover all 12 paper algorithms."""
    assert BOTH_BACKENDS == list_policies(family=PACKER_FAMILIES,
                                          backend="jax")
    assert len(BOTH_BACKENDS) == 12


def _prev_arrays(n, seed):
    rng = np.random.default_rng(seed)
    prev = rng.integers(-1, max(1, n // 2), size=n).astype(np.int32)
    prev_map = {j: int(c) for j, c in enumerate(prev) if c >= 0}
    return prev, prev_map


def _check_match(name, res_ref, res_jax):
    bin_of = np.asarray(res_jax.bin_of)
    loads = np.asarray(res_jax.loads)
    names = np.asarray(res_jax.names)
    k = int(res_jax.n_bins)
    assert k == res_ref.n_bins, f"{name}: bin count {k} != {res_ref.n_bins}"
    for j, cid in res_ref.pid_to_bin.items():
        assert int(bin_of[j]) == cid, (
            f"{name}: item {j} -> {int(bin_of[j])} (jax) vs {cid} (ref)")
    jl = {int(names[s]): float(loads[s]) for s in range(k)}
    for cid, load in res_ref.loads.items():
        assert jl[cid] == pytest.approx(load, abs=1e-6), f"{name}: load of bin {cid}"


@settings(max_examples=200, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       name=st.sampled_from(sorted(BOTH_BACKENDS)))
def test_registered_backends_agree_bitwise(speeds, seed, name):
    """The registry-driven parity property: py and jax one-shot packers of
    the same registered name produce identical packs."""
    n = len(speeds)
    prev, prev_map = _prev_arrays(n, seed)
    sp = {j: w for j, w in enumerate(speeds)}
    ref = packer_for(name, backend="py")(sp, C, prev=prev_map)
    out = packer_for(name, backend="jax")(
        jnp.asarray(speeds, jnp.float32), jnp.asarray(prev), C)
    _check_match(name, ref, out)


@settings(max_examples=60, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       name=st.sampled_from(sorted(
           list_policies(family="heuristic", backend="jax"))),
       sticky=st.booleans())
def test_classical_sticky_override_parity(speeds, seed, name, sticky):
    """The ``sticky`` hyperparameter (Sec. IV-C naming on/off) agrees
    across backends through the spec's declared knobs."""
    from repro.core.binpack import pack
    from repro.core.jaxpack import pack_jax

    spec = get_spec(name, backend="jax")
    strategy = spec.hyperparams["strategy"]
    dec = spec.hyperparams["decreasing"]
    n = len(speeds)
    prev, prev_map = _prev_arrays(n, seed)
    sp = {j: w for j, w in enumerate(speeds)}
    ref = pack(sp, C, strategy=strategy, decreasing=dec, prev=prev_map,
               sticky=sticky)
    out = pack_jax(jnp.asarray(speeds, jnp.float32), jnp.asarray(prev), C,
                   strategy=strategy, decreasing=dec, sticky=sticky)
    _check_match(name, ref, out)


@pytest.mark.parametrize("name", sorted(BOTH_BACKENDS))
def test_stream_evaluation_matches_reference(name):
    """Whole-stream scan (bins + Rscore per iteration) agrees with the python
    controller loop on a quantized Eq. 11 stream."""
    from repro.core.jaxpack import evaluate_stream_jax

    stream = generate_stream(n_partitions=10, n_measurements=40, delta=15,
                             capacity=C, seed=7)
    stream = np.round(stream * 1024) / 1024.0
    runs = run_stream({name: packer_for(name, backend="py")}, stream, C)
    bins_jax, rs_jax = evaluate_stream_jax(jnp.asarray(stream, jnp.float32), C,
                                           algorithm=name)
    np.testing.assert_array_equal(np.asarray(bins_jax), np.array(runs[name].bins))
    np.testing.assert_allclose(np.asarray(rs_jax), np.array(runs[name].rscores),
                               atol=1e-6)
