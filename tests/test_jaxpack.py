"""Exact-equivalence property tests: the JAX (lax.scan) packer must agree
bit-for-bit with the reference implementation -- same bin names per item,
same loads, same bin count -- across all 12 algorithms, random instances and
random previous assignments.

Speeds are quantized to k/1024 so all load sums are exact in float32: any
disagreement is a logic bug, never rounding.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import ALL_ALGORITHMS, group_view, run_stream
from repro.core.jaxpack import (
    evaluate_stream_jax,
    modified_any_fit_jax,
    pack_jax,
)
from repro.core.streams import generate_stream

C = 1.0

speeds_st = st.lists(
    st.integers(min_value=0, max_value=2048).map(lambda k: k / 1024.0),
    min_size=1,
    max_size=24,
)

CLASSICAL_SPEC = {
    "NF": ("next", False), "NFD": ("next", True),
    "FF": ("first", False), "FFD": ("first", True),
    "BF": ("best", False), "BFD": ("best", True),
    "WF": ("worst", False), "WFD": ("worst", True),
}
MODIFIED_SPEC = {
    "MWF": ("worst", "cumulative"), "MBF": ("best", "cumulative"),
    "MWFP": ("worst", "max_partition"), "MBFP": ("best", "max_partition"),
}


def _prev_arrays(n, seed):
    rng = np.random.default_rng(seed)
    prev = rng.integers(-1, max(1, n // 2), size=n).astype(np.int32)
    prev_map = {j: int(c) for j, c in enumerate(prev) if c >= 0}
    return prev, prev_map


def _check_match(name, res_ref, bin_of, loads, names, n_bins):
    bin_of = np.asarray(bin_of)
    loads = np.asarray(loads)
    names = np.asarray(names)
    k = int(n_bins)
    assert k == res_ref.n_bins, f"{name}: bin count {k} != {res_ref.n_bins}"
    for j, cid in res_ref.pid_to_bin.items():
        assert int(bin_of[j]) == cid, (
            f"{name}: item {j} -> {int(bin_of[j])} (jax) vs {cid} (ref)")
    jl = {int(names[s]): float(loads[s]) for s in range(k)}
    for cid, load in res_ref.loads.items():
        assert jl[cid] == pytest.approx(load, abs=1e-6), f"{name}: load of bin {cid}"


@settings(max_examples=120, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       name=st.sampled_from(sorted(CLASSICAL_SPEC)), sticky=st.booleans())
def test_classical_jax_matches_reference(speeds, seed, name, sticky):
    strategy, dec = CLASSICAL_SPEC[name]
    n = len(speeds)
    prev, prev_map = _prev_arrays(n, seed)
    sp = {j: w for j, w in enumerate(speeds)}
    from repro.core.binpack import pack
    ref = pack(sp, C, strategy=strategy, decreasing=dec, prev=prev_map, sticky=sticky)
    out = pack_jax(jnp.asarray(speeds, jnp.float32), jnp.asarray(prev), C,
                   strategy=strategy, decreasing=dec, sticky=sticky)
    _check_match(name, ref, out.bin_of, out.loads, out.names, out.n_bins)


@settings(max_examples=120, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       name=st.sampled_from(sorted(MODIFIED_SPEC)))
def test_modified_jax_matches_reference(speeds, seed, name):
    fit, key = MODIFIED_SPEC[name]
    n = len(speeds)
    prev, prev_map = _prev_arrays(n, seed)
    sp = {j: w for j, w in enumerate(speeds)}
    from repro.core.modified import modified_any_fit
    ref = modified_any_fit(sp, C, group_view(prev_map), fit=fit, sort_key=key)
    out = modified_any_fit_jax(jnp.asarray(speeds, jnp.float32), jnp.asarray(prev),
                               C, fit=fit, sort_key=key)
    _check_match(name, ref, out.bin_of, out.loads, out.names, out.n_bins)


@pytest.mark.parametrize("name", sorted(ALL_ALGORITHMS))
def test_stream_evaluation_matches_reference(name):
    """Whole-stream scan (bins + Rscore per iteration) agrees with the python
    controller loop on a quantized Eq. 11 stream."""
    stream = generate_stream(n_partitions=10, n_measurements=40, delta=15,
                             capacity=C, seed=7)
    stream = np.round(stream * 1024) / 1024.0
    runs = run_stream({name: ALL_ALGORITHMS[name]}, stream, C)
    bins_jax, rs_jax = evaluate_stream_jax(jnp.asarray(stream, jnp.float32), C,
                                           algorithm=name)
    np.testing.assert_array_equal(np.asarray(bins_jax), np.array(runs[name].bins))
    np.testing.assert_allclose(np.asarray(rs_jax), np.array(runs[name].rscores),
                               atol=1e-6)
