"""The variable-N mask contract, pinned end to end.

Load-bearing properties (ISSUE acceptance criteria):

* **All-active is the identity** -- packing with an all-``True`` mask is
  bit-identical to the unmasked packer, on every registered algorithm
  (hypothesis property, both backends).
* **A masked-out item does not exist** -- it never names a bin (its
  ``bin_of`` is ``NEG``), contributes no load, and the masked jax pack
  equals the reference pack of the speed map with the item removed --
  the py backend's native notion of absence (hypothesis property).
* The same holds one level up (sweep driver, run_stream, policies,
  annealer) and one level down (the Pallas kernels' masked variants
  against their oracles).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.core.jaxpack import evaluate_stream_jax, sweep_streams
from repro.core.metrics import run_stream
from repro.registry import (PACKER_FAMILIES, list_policies, make_policy,
                            packer_for)

C = 1.0
NEG = -1

ALGORITHMS = list_policies(family=PACKER_FAMILIES, backend="jax")

if HAVE_HYPOTHESIS:
    speeds_st = st.lists(
        st.integers(min_value=0, max_value=2048).map(lambda k: k / 1024.0),
        min_size=1,
        max_size=20,
    )


def _instance(speeds, seed):
    """Quantized instance + random prev + random mask from one seed."""
    n = len(speeds)
    rng = np.random.default_rng(seed)
    prev = rng.integers(-1, max(1, n // 2), size=n).astype(np.int32)
    active = rng.integers(0, 2, size=n).astype(bool)
    return (jnp.asarray(speeds, jnp.float32), jnp.asarray(prev),
            jnp.asarray(active), prev, active)


# ---------------------------------------------------------------------------
# one-shot packers (the satellite property, both backends)
# ---------------------------------------------------------------------------
def _check_all_active_identity(speeds, seed, name):
    sj, pj, _, _, _ = _instance(speeds, seed)
    n = len(speeds)
    fn = packer_for(name, backend="jax")
    plain = fn(sj, pj, C)
    masked = fn(sj, pj, C, active=jnp.ones(n, bool))
    assert np.asarray(plain.bin_of).tobytes() == \
        np.asarray(masked.bin_of).tobytes(), name
    assert np.asarray(plain.loads).tobytes() == \
        np.asarray(masked.loads).tobytes(), name
    assert np.asarray(plain.names).tobytes() == \
        np.asarray(masked.names).tobytes(), name
    assert int(plain.n_bins) == int(masked.n_bins), name


def _check_masked_absent(speeds, seed, name):
    """A masked-out item packs to NEG, adds no load, opens no bin; the
    surviving pack is exactly the py reference pack of the speed map with
    the masked items *removed* (both backends see one semantics)."""
    sj, pj, aj, prev, active = _instance(speeds, seed)
    res = packer_for(name, backend="jax")(sj, pj, C, active=aj)
    bin_of = np.asarray(res.bin_of)
    k = int(res.n_bins)
    # absent: no bin name, no load
    assert (bin_of[~active] == NEG).all(), name
    live_load = sum(w for j, w in enumerate(speeds) if active[j])
    assert float(np.asarray(res.loads)[:k].sum()) == \
        pytest.approx(live_load, abs=1e-5), name
    # cross-backend: reference pack of the filtered dict
    sp = {j: w for j, w in enumerate(speeds) if active[j]}
    prev_map = {j: int(c) for j, c in enumerate(prev)
                if active[j] and c >= 0}
    ref = packer_for(name, backend="py")(sp, C, prev=prev_map)
    assert k == ref.n_bins, name
    for j, cid in ref.pid_to_bin.items():
        assert int(bin_of[j]) == cid, (name, j)
    jl = {int(nm): float(ld)
          for nm, ld in zip(np.asarray(res.names)[:k],
                            np.asarray(res.loads)[:k])}
    for cid, load in ref.loads.items():
        assert jl[cid] == pytest.approx(load, abs=1e-6), (name, cid)


if HAVE_HYPOTHESIS:
    @settings(max_examples=150, deadline=None)
    @given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
           name=st.sampled_from(sorted(ALGORITHMS)))
    def test_all_active_mask_is_bit_identical(speeds, seed, name):
        _check_all_active_identity(speeds, seed, name)

    @settings(max_examples=150, deadline=None)
    @given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
           name=st.sampled_from(sorted(ALGORITHMS)))
    def test_masked_item_absent_and_backends_agree(speeds, seed, name):
        _check_masked_absent(speeds, seed, name)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
@pytest.mark.parametrize("seed", (0, 7))
def test_mask_contract_fixed_instances(name, seed):
    """Deterministic fallback of the hypothesis properties above (always
    runs, with or without hypothesis installed)."""
    rng = np.random.default_rng(100 + seed)
    speeds = list(np.round(rng.uniform(0, 2, 14) * 1024) / 1024.0)
    _check_all_active_identity(speeds, seed, name)
    _check_masked_absent(speeds, seed, name)


# ---------------------------------------------------------------------------
# sweep driver + reference stream runner
# ---------------------------------------------------------------------------
def test_sweep_all_active_bit_identical():
    traces = jax.random.uniform(jax.random.key(0), (2, 14, 6), maxval=0.9)
    ones = jnp.ones(traces.shape, bool)
    plain = sweep_streams(("BFD", "MBFP", "WF"), traces, C)
    masked = sweep_streams(("BFD", "MBFP", "WF"), traces, C, ones)
    for a, b in ((plain.bins, masked.bins), (plain.rscores, masked.rscores),
                 (plain.migrations, masked.migrations)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_masked_sweep_matches_reference_run_stream():
    """Whole-stream masked scan == the py controller loop that drops dead
    partitions from each iteration's speed map."""
    rng = np.random.default_rng(5)
    t, n = 20, 7
    stream = np.round(rng.uniform(0, 1, (t, n)) * 1024) / 1024.0
    active = rng.integers(0, 2, (t, n)).astype(bool)
    for name in ("BFD", "MWFP"):
        runs = run_stream({name: packer_for(name, backend="py")},
                          stream, C, active=active)
        bins_jax, rs_jax = evaluate_stream_jax(
            jnp.asarray(stream, jnp.float32), C, algorithm=name,
            active=jnp.asarray(active))
        np.testing.assert_array_equal(np.asarray(bins_jax),
                                      np.array(runs[name].bins))
        np.testing.assert_allclose(np.asarray(rs_jax),
                                   np.array(runs[name].rscores), atol=1e-6)


def test_dead_partition_costs_no_migration():
    """A partition dying mid-stream (active -> inactive) must not itself
    count as a migration or price an R-score move.  Speeds are 0.8 per
    partition (capacity 1.0), so every partition sits alone in its own
    sticky-named bin and a death cannot make the *others* repack."""
    stream = jnp.full((4, 3), 0.8, jnp.float32)
    active = jnp.asarray([[True, True, True],
                          [True, True, True],
                          [True, False, True],   # partition 1 dies
                          [True, False, True]])
    res = sweep_streams(("BFD",), stream[None], C, active[None])
    bins = np.asarray(res.bins[0, 0])
    migs = np.asarray(res.migrations[0, 0])
    rs = np.asarray(res.rscores[0, 0])
    np.testing.assert_array_equal(bins, [3, 3, 2, 2])  # the bin disappears
    assert (migs[1:] == 0).all() and (rs[1:] == 0.0).all()


# ---------------------------------------------------------------------------
# Policy protocol (registry builders honor the mask)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ("BFD", "MBFP", "KEDA_LAG",
                                  "RATE_THRESHOLD", "ANNEAL_STICKY"))
def test_policy_step_masks_partitions(name):
    n = 6
    pol = make_policy(name, n, C, backend="jax", strict=False)
    speeds = jnp.asarray([0.4, 0.5, 0.3, 0.6, 0.2, 0.4], jnp.float32)
    lag = 2.0 * speeds
    prev = jnp.full(n, NEG, jnp.int32)
    active = jnp.asarray([True, False, True, True, False, True])
    assign, k, _ = pol.step(speeds, lag, prev, pol.init(n), active)
    assign = np.asarray(assign)
    assert (assign[~np.asarray(active)] == NEG).all(), name
    assert (assign[np.asarray(active)] >= 0).all(), name
    assert int(k) >= 1


@pytest.mark.parametrize("name", ("BFD", "KEDA_LAG", "RATE_THRESHOLD"))
def test_policy_step_all_active_equals_unmasked(name):
    n = 5
    pol = make_policy(name, n, C, backend="jax", strict=False)
    speeds = jnp.asarray([0.7, 0.2, 0.9, 0.4, 0.5], jnp.float32)
    lag = 3.0 * speeds
    prev = jnp.asarray([1, 0, NEG, 2, 1], jnp.int32)
    a0, k0, _ = pol.step(speeds, lag, prev, pol.init(n))
    a1, k1, _ = pol.step(speeds, lag, prev, pol.init(n), jnp.ones(n, bool))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    assert int(k0) == int(k1)


# ---------------------------------------------------------------------------
# annealer
# ---------------------------------------------------------------------------
def test_anneal_mask_semantics():
    from repro.opt.anneal import anneal_assign, assignment_cost, name_universe

    rng = np.random.default_rng(2)
    n = 10
    speeds = jnp.asarray(rng.uniform(0.05, 0.6, n), jnp.float32)
    prev = jnp.asarray(rng.integers(-1, 5, n), jnp.int32)
    active = jnp.asarray(rng.integers(0, 2, n).astype(bool))
    key = jax.random.key(7)
    # all-active == unmasked bit-for-bit (same PRNG shapes, same logits)
    a0 = anneal_assign(speeds, prev, C, key, lam=2.0, chains=4, steps=40)
    a1 = anneal_assign(speeds, prev, C, key, lam=2.0, chains=4, steps=40,
                       active=jnp.ones(n, bool))
    np.testing.assert_array_equal(np.asarray(a0[0]), np.asarray(a1[0]))
    assert int(a0[1]) == int(a1[1])
    # masked: inactive items come back NEG; bins count only live items
    assign, bins = anneal_assign(speeds, prev, C, key, lam=2.0, chains=4,
                                 steps=40, active=active)
    assign = np.asarray(assign)
    act = np.asarray(active)
    assert (assign[~act] == NEG).all()
    assert (assign[act] >= 0).all()
    _, bins2, _ = assignment_cost(jnp.asarray(assign), speeds, prev, C,
                                  jnp.float32(2.0), m=name_universe(n),
                                  active=active)
    assert int(bins) == int(bins2) == len(set(assign[act]))


def test_assignment_cost_ignores_masked_items():
    from repro.opt.anneal import assignment_cost

    speeds = jnp.asarray([0.5, 0.5, 0.5], jnp.float32)
    prev = jnp.asarray([0, 1, 2], jnp.int32)
    assign = jnp.asarray([0, 5, 2], jnp.int32)    # item 1 moved
    active = jnp.asarray([True, False, True])
    cost, bins, r = assignment_cost(assign, speeds, prev, C,
                                    jnp.float32(1.0), m=8, active=active)
    assert int(bins) == 2          # item 1's bin does not exist
    assert float(r) == 0.0         # its move is not priced


# ---------------------------------------------------------------------------
# kernels: masked variants vs oracles
# ---------------------------------------------------------------------------
def test_select_slot_masked_rows_return_neg():
    from repro.kernels.binpack_select import select_slot_grid

    rng = np.random.default_rng(0)
    b, n, m = 2, 40, 16
    loads = jnp.asarray(rng.uniform(0, 1, (b, n, m)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 0.6, (b, n)), jnp.float32)
    k = jnp.asarray(rng.integers(0, m + 1, (b, n)), jnp.int32)
    cap = jnp.ones((b, n), jnp.float32)
    active = jnp.asarray(rng.integers(0, 2, (b, n)), jnp.int32)
    got = np.asarray(select_slot_grid(loads, w, k, cap, active=active))
    plain = np.asarray(select_slot_grid(loads, w, k, cap))
    act = np.asarray(active).astype(bool)
    assert (got[~act] == NEG).all()
    np.testing.assert_array_equal(got[act], plain[act])


def test_lag_update_masked_matches_reference_and_zeroes_dead():
    from repro.kernels.lag_update import lag_update_batch, lag_update_reference

    rng = np.random.default_rng(1)
    b, n, m = 3, 12, 26
    lag = jnp.asarray(rng.uniform(0, 5, (b, n)), jnp.float32)
    prod = jnp.asarray(rng.uniform(0, 1, (b, n)), jnp.float32)
    assign = jnp.asarray(rng.integers(-1, m, (b, n)), jnp.int32)
    readable = jnp.asarray(rng.integers(0, 2, (b, n)), jnp.int32)
    cap = jnp.full((b, m), 1.1, jnp.float32)
    active = jnp.asarray(rng.integers(0, 2, (b, n)), jnp.int32)
    out_k = lag_update_batch(lag, prod, assign, readable, cap, active=active)
    out_r = lag_update_reference(lag, prod, assign, readable, cap, m=m,
                                 active=active)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(out_k)[~np.asarray(active).astype(bool)] == 0.0).all()


def test_move_delta_masked_blocks_inactive_rows():
    from repro.kernels.move_eval import (MOVE_BLOCKED, move_delta_batch,
                                         move_delta_reference)

    rng = np.random.default_rng(3)
    k, n, m = 3, 8, 18
    assign = jnp.asarray(rng.integers(0, m, (k, n)), jnp.int32)
    counts = jnp.zeros((k, m), jnp.int32)
    counts = counts.at[jnp.arange(k)[:, None], assign].add(1)
    speeds = jnp.asarray(rng.uniform(0.05, 0.5, (k, n)), jnp.float32)
    loads = jnp.zeros((k, m), jnp.float32)
    loads = loads.at[jnp.arange(k)[:, None], assign].add(speeds)
    prev = jnp.asarray(rng.integers(-1, m, (k, n)), jnp.int32)
    lam = jnp.asarray(rng.uniform(0, 4, k), jnp.float32)
    cap = jnp.ones(k, jnp.float32)
    active = jnp.asarray(rng.integers(0, 2, (k, n)), jnp.int32)
    ref = move_delta_reference(loads, counts, assign, speeds, prev, lam, cap,
                               active=active)
    got = move_delta_batch(loads, counts, assign, speeds, prev, lam, cap,
                           active=active)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    dead = ~np.asarray(active).astype(bool)
    assert (np.asarray(got)[dead, :] == MOVE_BLOCKED).all()


# ---------------------------------------------------------------------------
# lag twin: masked partitions are unreadable and empty
# ---------------------------------------------------------------------------
def test_lagsim_dead_columns_equal_removed_columns():
    """Simulating [T, N + D] with D always-dead partitions equals
    simulating the live [T, N] columns alone -- the padding-exactness
    property the fleet layer is built on (deterministic policies)."""
    import dataclasses

    from repro.lagsim import LagSimConfig, simulate_lag

    rng = np.random.default_rng(4)
    live = jnp.asarray(rng.uniform(0, 0.8, (18, 5)), jnp.float32)
    dead = jnp.asarray(rng.uniform(0, 0.9, (18, 3)), jnp.float32)
    padded = jnp.concatenate([live, dead], axis=1)
    mask = jnp.concatenate([jnp.ones((18, 5), bool),
                            jnp.zeros((18, 3), bool)], axis=1)
    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2).resolve(5)
    for pol in ("BFD", "MBFP", "KEDA_LAG"):
        a = simulate_lag(live, policy=pol, cfg=cfg)
        b = simulate_lag(padded, policy=pol, cfg=cfg, active=mask)
        np.testing.assert_allclose(np.asarray(a.lag_total),
                                   np.asarray(b.lag_total), atol=1e-6,
                                   err_msg=pol)
        np.testing.assert_array_equal(np.asarray(a.consumers),
                                      np.asarray(b.consumers), err_msg=pol)
        np.testing.assert_array_equal(np.asarray(a.migrations),
                                      np.asarray(b.migrations), err_msg=pol)
