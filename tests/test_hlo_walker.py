"""The HLO cost walker must reproduce ground-truth FLOPs for scanned
programs (where XLA's own cost_analysis under-counts by the trip count) and
agree with the unrolled equivalent."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_walker import walk
from repro.launch import hlo_stats


def _compile(f, *args, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*args).compile()


def test_scan_flops_match_unrolled():
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = lax.scan(body, x, None, length=8)
        return c

    def unrolled(x, w):
        for _ in range(8):
            x = x @ w
        return x

    s = walk(_compile(scanned, x, w).as_text())
    u = walk(_compile(unrolled, x, w).as_text())
    truth = 8 * 2 * 16 * 64 * 64
    assert s.flops == pytest.approx(truth, rel=0.01), "scan trip count lost"
    assert u.flops == pytest.approx(truth, rel=0.01)
    # scan body bytes are also multiplied
    assert s.hbm_bytes >= 8 * (16 * 64 * 4)


def test_nested_scan_multipliers():
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None
        c, _ = lax.scan(outer, x, None, length=5)
        return c

    s = walk(_compile(nested, x, w).as_text())
    truth = 5 * 3 * 2 * 8 * 32 * 32
    assert s.flops == pytest.approx(truth, rel=0.01)


def test_remat_shows_recompute():
    """jax.checkpoint recomputes the forward in backward: walker flops must
    exceed the no-remat version."""
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loss(x, w, remat):
        def blk(c, _):
            def f(c):
                return jnp.tanh(c @ w) @ w, None
            if remat:
                f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
            return f(c)
        c, _ = lax.scan(blk, x, None, length=4)
        return jnp.sum(c)

    g_plain = _compile(lambda x, w: jax.grad(loss, argnums=1)(x, w, False), x, w)
    g_remat = _compile(lambda x, w: jax.grad(loss, argnums=1)(x, w, True), x, w)
    f_plain = walk(g_plain.as_text()).flops
    f_remat = walk(g_remat.as_text()).flops
    # theory: 8/6 dots; XLA CSE recovers some recompute -> measured ~7/6
    assert f_remat > f_plain * 1.1


def test_collective_parse_fixture():
    hlo = """
HloModule m, num_partitions=8

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ag = f32[128,64]{1,0} all-gather(%p0), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  ROOT %ar = f32[128,64]{1,0} all-reduce(%ag), channel_id=2, replica_groups=[4,2]<=[8], to_apply=%add
}
"""
    st = hlo_stats.parse_collectives(hlo)
    full = 128 * 64 * 4
    assert st.bytes_by_kind["all-gather"] == pytest.approx(full * 3 / 4)
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(2 * full * 1 / 2)
    w = walk(hlo)
    assert w.collective_bytes == pytest.approx(full * 3 / 4 + 2 * full * 1 / 2)
