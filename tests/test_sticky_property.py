"""Hypothesis property tests for the Sec. IV-C sticky naming rule.

The sticky adaptation only changes which *names* newly created bins get,
so for every fit strategy it can never change the number of bins or which
partitions share a bin -- that is a theorem and the properties pin it
exactly.  Its R-score effect needs a more careful statement than "never
worse than sticky=False": non-sticky sequential naming (0, 1, 2, ...) can
*accidentally* coincide with a partition's previous consumer and luckily
count it as not-moved, and an adversarial ``prev`` can hand that luck
more speed than sticky's deliberate reuse
(``test_sticky_rscore.test_sticky_not_always_below_nonsticky_sequential_naming``
pins a concrete counterexample).  What sticky does guarantee is the
fresh-naming bound: it never does worse than giving every new bin a
brand-new name, under which *every* previously-assigned partition counts
as moved.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.binpack import FIT_STRATEGIES, pack
from repro.core.rscore import rscore, rscore_of_set

C = 1.0

speeds_st = st.lists(
    st.integers(min_value=0, max_value=2048).map(lambda k: k / 1024.0),
    min_size=1,
    max_size=20,
)


def _instance(speeds, seed):
    rng = np.random.default_rng(seed)
    n = len(speeds)
    sp = {j: w for j, w in enumerate(speeds)}
    prev_vals = rng.integers(-1, max(1, n), size=n)
    prev = {j: int(c) for j, c in enumerate(prev_vals) if c >= 0}
    return sp, prev


@settings(max_examples=150, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(FIT_STRATEGIES), decreasing=st.booleans())
def test_sticky_never_changes_bin_count_or_grouping(speeds, seed, strategy,
                                                    decreasing):
    """For every fit strategy (and Decreasing variant): sticky vs
    non-sticky produce the same number of bins and the same partition
    grouping -- the adaptation is a pure renaming."""
    sp, prev = _instance(speeds, seed)
    res_s = pack(sp, C, strategy=strategy, decreasing=decreasing, prev=prev,
                 sticky=True)
    res_n = pack(sp, C, strategy=strategy, decreasing=decreasing, prev=prev,
                 sticky=False)
    assert res_s.n_bins == res_n.n_bins
    assert res_s.composition() == res_n.composition()


@settings(max_examples=150, deadline=None)
@given(speeds=speeds_st, seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(FIT_STRATEGIES), decreasing=st.booleans())
def test_sticky_rscore_never_exceeds_fresh_naming(speeds, seed, strategy,
                                                  decreasing):
    """Sticky naming never produces a higher R-score than the no-reuse
    baseline, where every new bin gets a name outside ``prev`` and hence
    every previously-assigned partition counts as rebalanced."""
    sp, prev = _instance(speeds, seed)
    res = pack(sp, C, strategy=strategy, decreasing=decreasing, prev=prev,
               sticky=True)
    r_sticky = rscore(prev, res.pid_to_bin, sp, C)
    r_fresh = rscore_of_set(set(prev), sp, C)
    assert r_sticky <= r_fresh + 1e-9
