"""The fused multi-step lag engine, pinned bit-for-bit to the scan.

Load-bearing properties (ISSUE acceptance criteria):

* **Fused == unfused, bit for bit** -- with ``fused_steps > 0`` every
  heuristic policy's trajectory (all five ``LagTrace`` fields) is
  byte-identical to the per-step ``lax.scan``, across every scenario
  family, under partition masking (``topic_lifecycle`` / ``churn``),
  with ``T % K != 0`` remainders, and with a seeded ``initial_lag``
  (hypothesis property + deterministic fallback).
* **Observability carries over** -- sketch summaries and alert/incident
  states from the fused path equal the unfused ones leaf-for-leaf.
* **The Pallas megakernel agrees** -- ``fused_kernel=True`` routes
  through ``kernels/loop_fused.py`` and still matches the scan exactly
  (interpreter mode off-TPU, like every kernel in the repo).
* **Fleet padding is preserved** -- a padded bucket run with the fused
  config equals the padded run of the unfused config byte-for-byte.
* **Refusals are named** -- optimizer policies, control-plane configs
  and control-plane-wrapped REAL scalers raise ``FusedPathError``;
  everything else the fused loop cannot express falls back to the scan
  per policy (``fused_mode`` is the documented routing table).
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.core.scenarios import generate_masked_scenario, scenario_suite
from repro.fleet import FleetConfig, FleetRunner
from repro.lagsim import (
    FUSED_MAX_PARTITIONS,
    ControlPlaneConfig,
    FusedPathError,
    LagSimConfig,
    fused_mode,
    simulate_lag,
    sweep_lag,
)
from repro.telemetry import (AlertConfig, SketchConfig, TelemetryConfig,
                             default_rules)

HEURISTICS = ("NF", "NFD", "FF", "FFD", "BF", "BFD", "WF", "WFD")
FIELDS = ("lag_total", "lag_max", "consumers", "migrations", "unreadable")

BASE = LagSimConfig(capacity=1.0, dt=0.7, migration_steps=3)
FUSED = dataclasses.replace(BASE, fused_steps=8)


def _fused_pair(cfg, **over):
    """(unfused, fused) configs differing only in ``fused_steps``."""
    a = dataclasses.replace(cfg, **over)
    return a, dataclasses.replace(a, fused_steps=8)


def _assert_traces_equal(a, b, msg=""):
    for f in FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.tobytes() == y.tobytes(), (msg, f)


# ---------------------------------------------------------------------------
# fused == unfused, bit for bit
# ---------------------------------------------------------------------------
def test_fused_equals_scan_every_scenario_family():
    suite = scenario_suite(jax.random.key(0), 2, 37, 10)
    for fam, traces in suite.items():
        a = sweep_lag(HEURISTICS, traces, BASE)
        b = sweep_lag(HEURISTICS, traces, FUSED)
        _assert_traces_equal(a, b, fam)


@pytest.mark.parametrize("family", ("churn", "topic_lifecycle"))
def test_fused_equals_scan_masked(family):
    """Partition masking (birth/death mid-stream) flows through the fused
    carry exactly: dead partitions stay unreadable-and-empty."""
    sp, act = generate_masked_scenario(family, jax.random.key(1), 2, 41, 9)
    a = sweep_lag(HEURISTICS, sp, BASE, active=act)
    b = sweep_lag(HEURISTICS, sp, FUSED, active=act)
    _assert_traces_equal(a, b, family)


@pytest.mark.parametrize("k", (1, 5, 8, 64))
def test_fused_remainder_blocks(k):
    """T % K != 0: the internal pad to a K multiple never leaks into the
    real steps (incl. K == 1 and K > T degenerate blockings)."""
    tr = jax.random.uniform(jax.random.key(2), (2, 23, 7), maxval=1.1)
    a = sweep_lag(("BFD", "WFD"), tr, BASE)
    b = sweep_lag(("BFD", "WFD"),
                  tr, dataclasses.replace(BASE, fused_steps=k))
    _assert_traces_equal(a, b, f"K={k}")


def test_fused_single_stream_initial_lag_and_assigns():
    tr = jax.random.uniform(jax.random.key(3), (29, 8), maxval=0.9)
    il = jnp.linspace(0.0, 3.0, 8)
    ra, aa = simulate_lag(tr, policy="BFD", cfg=BASE, initial_lag=il,
                          record_assign=True)
    rb, ab = simulate_lag(tr, policy="BFD", cfg=FUSED, initial_lag=il,
                          record_assign=True)
    _assert_traces_equal(ra, rb)
    np.testing.assert_array_equal(np.asarray(aa), np.asarray(ab))


def _check_fused_equals_scan(seed, policy, k):
    rng = np.random.default_rng(seed)
    tr = jnp.asarray(rng.uniform(0, 1.3, (19, 6)), jnp.float32)
    a = simulate_lag(tr, policy=policy, cfg=BASE)
    b = simulate_lag(tr, policy=policy,
                     cfg=dataclasses.replace(BASE, fused_steps=k))
    _assert_traces_equal(a, b, (policy, k))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           policy=st.sampled_from(HEURISTICS),
           k=st.sampled_from((1, 3, 8, 32)))
    def test_fused_equals_scan_property(seed, policy, k):
        _check_fused_equals_scan(seed, policy, k)


@pytest.mark.parametrize("policy", HEURISTICS)
@pytest.mark.parametrize("seed,k", ((0, 3), (7, 8)))
def test_fused_equals_scan_fixed_instances(policy, seed, k):
    """Deterministic fallback of the hypothesis property above (always
    runs, with or without hypothesis installed)."""
    _check_fused_equals_scan(seed, policy, k)


# ---------------------------------------------------------------------------
# observability: same aggregates off the fused path
# ---------------------------------------------------------------------------
def test_fused_sketch_and_incident_states_equal():
    tele = TelemetryConfig(record_frames=False, sketch=SketchConfig(),
                           alerts=AlertConfig(rules=default_rules()))
    cfg_a, cfg_b = _fused_pair(BASE, telemetry=tele)
    sp, act = generate_masked_scenario("topic_lifecycle", jax.random.key(4),
                                       2, 33, 8)
    a = sweep_lag(("BFD", "WFD"), sp, cfg_a, active=act)
    b = sweep_lag(("BFD", "WFD"), sp, cfg_b, active=act)
    _assert_traces_equal(a, b)
    assert a.sketch is not None and a.incidents is not None
    for x, y in ((a.sketch, b.sketch), (a.incidents, b.incidents)):
        la, lb = jax.tree_util.tree_leaves(x), jax.tree_util.tree_leaves(y)
        assert len(la) == len(lb) and len(la) > 0
        for u, v in zip(la, lb):
            assert np.asarray(u).tobytes() == np.asarray(v).tobytes()
    assert a.sketch.names == b.sketch.names


def test_fused_frame_recording_falls_back():
    """O(T) per-step frame recording is an unfused-only surface."""
    tele = TelemetryConfig(record_frames=True)
    cfg = dataclasses.replace(FUSED, telemetry=tele)
    assert fused_mode("BFD", cfg, 6) == "unfused"


# ---------------------------------------------------------------------------
# the Pallas megakernel path
# ---------------------------------------------------------------------------
def test_megakernel_equals_scan():
    cfg_k = dataclasses.replace(BASE, fused_steps=7, fused_kernel=True)
    tr = jax.random.uniform(jax.random.key(5), (2, 23, 6), maxval=1.0)
    a = sweep_lag(("BFD", "NF"), tr, BASE)
    b = sweep_lag(("BFD", "NF"), tr, cfg_k)
    _assert_traces_equal(a, b)


def test_megakernel_masked_equals_scan():
    cfg_k = dataclasses.replace(BASE, fused_steps=8, fused_kernel=True)
    sp, act = generate_masked_scenario("topic_lifecycle", jax.random.key(6),
                                       1, 19, 6)
    a = sweep_lag(("FFD",), sp, BASE, active=act)
    b = sweep_lag(("FFD",), sp, cfg_k, active=act)
    _assert_traces_equal(a, b)


def test_loop_fused_batch_direct_call():
    """The kernel entry point itself: carry (lag/assign/downtime) across
    K-blocks with a seeded initial lag, vs the single-stream engine."""
    from repro.kernels.loop_fused import loop_fused_batch

    rng = np.random.default_rng(7)
    tr = jnp.asarray(rng.uniform(0, 1.2, (17, 5)), jnp.float32)
    il = jnp.asarray(rng.uniform(0, 2.0, 5), jnp.float32)
    ref, assigns = simulate_lag(tr, policy="BFD", cfg=BASE, initial_lag=il,
                                record_assign=True)
    tot, mx, cons, migs, unread, asg = loop_fused_batch(
        tr[None], strategy="best", decreasing=True, capacity=1.0, dt=0.7,
        migration_steps=3, fused_steps=4, initial_lag=il[None])
    for got, want in ((tot, ref.lag_total), (mx, ref.lag_max),
                      (cons, ref.consumers), (migs, ref.migrations),
                      (unread, ref.unreadable)):
        assert np.asarray(got[0]).tobytes() == np.asarray(want).tobytes()
    np.testing.assert_array_equal(np.asarray(asg[0]), np.asarray(assigns))


def test_loop_fused_batch_rejects_wide_instances():
    from repro.kernels.loop_fused import loop_fused_batch

    with pytest.raises(ValueError, match="n <= 14"):
        loop_fused_batch(jnp.zeros((1, 4, 15)), strategy="best",
                         decreasing=True)


# ---------------------------------------------------------------------------
# fleet: fused config in the bucket/compile key, padding preserved
# ---------------------------------------------------------------------------
def test_fleet_padded_fused_equals_padded_scan():
    rng = np.random.default_rng(8)
    shapes = ((14, 4), (20, 8), (9, 6))
    scen = [jnp.asarray(rng.uniform(0, 1.2, s), jnp.float32)
            for s in shapes]

    def run(cfg):
        runner = FleetRunner(FleetConfig(t_buckets=(20,), n_buckets=(8,)))
        return runner.simulate(("BFD", "WFD"), scen, cfg)

    a, b = run(BASE), run(FUSED)
    for i in range(len(scen)):
        assert a.lag_total[i].tobytes() == b.lag_total[i].tobytes()
        np.testing.assert_array_equal(a.consumers[i], b.consumers[i])
        np.testing.assert_array_equal(a.migrations[i], b.migrations[i])


def test_fleet_n_bucket_above_limit_falls_back_inside_program():
    """A scenario padded into an N bucket wider than the bitmask limit
    runs unfused inside the same program -- and still matches."""
    runner = FleetRunner(FleetConfig(t_buckets=(16,),
                                     n_buckets=(FUSED_MAX_PARTITIONS + 2,)))
    tr = jax.random.uniform(jax.random.key(9), (12, 5), maxval=1.0)
    res = runner.simulate(("BFD",), [tr], FUSED)
    solo = sweep_lag(("BFD",), tr[None], BASE)
    np.testing.assert_array_equal(res.consumers[0],
                                  np.asarray(solo.consumers)[:, 0, :])
    np.testing.assert_array_equal(res.migrations[0],
                                  np.asarray(solo.migrations)[:, 0, :])
    np.testing.assert_allclose(res.lag_total[0],
                               np.asarray(solo.lag_total)[:, 0, :],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# routing: named refusals and documented fallbacks
# ---------------------------------------------------------------------------
def test_fused_mode_routing_table():
    assert fused_mode("BFD", FUSED, 10) == "fused"
    assert fused_mode("BFD", FUSED, FUSED_MAX_PARTITIONS + 1) == "unfused"
    assert fused_mode("MBFP", FUSED, 10) == "unfused"      # sweep family
    assert fused_mode("KEDA_LAG", FUSED, 10) == "unfused"  # reactive (ideal)
    kern = dataclasses.replace(FUSED, use_kernel=True)
    assert fused_mode("BFD", kern, 10) == "unfused"


@pytest.mark.parametrize("policy", ("ANNEAL", "ANNEAL_STICKY"))
def test_fused_optimizer_policy_raises(policy):
    tr = jnp.ones((1, 6, 4), jnp.float32) * 0.4
    with pytest.raises(FusedPathError, match="optimizer"):
        sweep_lag((policy,), tr, FUSED)


@pytest.mark.parametrize("policy", ("KEDA_LAG_REAL", "CLOUD_RUN_CPU_LAG"))
def test_fused_real_scaler_raises(policy):
    tr = jnp.ones((6, 4), jnp.float32) * 0.4
    with pytest.raises(FusedPathError, match="control-plane-wrapped"):
        simulate_lag(tr, policy=policy, cfg=FUSED)


def test_fused_control_plane_raises():
    cfg = dataclasses.replace(FUSED, control_plane=ControlPlaneConfig())
    tr = jnp.ones((6, 4), jnp.float32) * 0.4
    with pytest.raises(FusedPathError, match="control_plane"):
        simulate_lag(tr, policy="BFD", cfg=cfg)


def test_fused_kernel_requires_fused_steps():
    with pytest.raises(ValueError, match="fused_kernel=True requires"):
        LagSimConfig(fused_kernel=True).resolve(4)
    with pytest.raises(ValueError, match="fused_steps must be >= 0"):
        LagSimConfig(fused_steps=-1).resolve(4)


def test_mixed_sweep_falls_back_per_policy():
    """One sweep mixing fused-capable and fallback policies: the fused
    group runs fused, the rest keep the scan, stacking order holds."""
    tr = jax.random.uniform(jax.random.key(10), (2, 21, 7), maxval=1.0)
    pols = ("BFD", "MBFP", "KEDA_LAG")
    a = sweep_lag(pols, tr, BASE)
    b = sweep_lag(pols, tr, FUSED)
    assert a.policies == b.policies == pols
    _assert_traces_equal(a, b)


# ---------------------------------------------------------------------------
# satellite: the rank-1 drain entry point
# ---------------------------------------------------------------------------
def test_lag_update_single_equals_batch_row():
    from repro.kernels.lag_update import (lag_update_batch,
                                          lag_update_reference,
                                          lag_update_single)

    rng = np.random.default_rng(11)
    n, m = 9, 19
    lag = jnp.asarray(rng.uniform(0, 5, n), jnp.float32)
    prod = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    assign = jnp.asarray(rng.integers(-1, m, n), jnp.int32)
    readable = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    cap = jnp.asarray(rng.uniform(0.5, 1.5, m), jnp.float32)
    active = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    for act in (None, active):
        one = lag_update_single(lag, prod, assign, readable, cap, active=act)
        batch = lag_update_batch(
            lag[None], prod[None], assign[None], readable[None], cap[None],
            active=None if act is None else act[None])
        ref = lag_update_reference(lag, prod, assign, readable, cap, m=m,
                                   active=act)
        assert np.asarray(one).tobytes() == np.asarray(batch[0]).tobytes()
        np.testing.assert_allclose(np.asarray(one), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
