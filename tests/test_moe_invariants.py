"""Property tests for the MoE dispatch invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models.moe import _capacity, apply_moe, init_moe


def _cfg(e, k, cf):
    base = configs.get("qwen2-moe-a2.7b", smoke=True)
    return dataclasses.replace(base, n_experts=e, experts_per_token=k,
                               capacity_factor=cf, n_shared_experts=0,
                               dtype="float32", param_dtype="float32")


@settings(max_examples=12, deadline=None)
@given(e=st.sampled_from([4, 6, 8]), k=st.sampled_from([1, 2]),
       b=st.sampled_from([1, 2]), s=st.sampled_from([4, 16]),
       seed=st.integers(0, 2 ** 16))
def test_moe_output_finite_and_gate_weighted(e, k, b, s, seed):
    cfg = _cfg(e, k, cf=8.0)  # no drops
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(seed), (b, s, cfg.d_model),
                          jnp.float32)
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0
    # with cf high enough for zero drops, output must be a convex (gate)
    # combination of expert outputs: scaling x scales y consistently for
    # the linear part -- cheap sanity: y is not identically zero
    assert float(jnp.max(jnp.abs(y))) > 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_moe_dropped_tokens_contribute_zero(seed):
    """cf so small that capacity=1 per expert: any token beyond the first
    routed to an expert is dropped and must receive exactly zero from the
    routed path (it would get only shared-expert output in a full config)."""
    cfg = _cfg(e=2, k=1, cf=0.01)
    p = init_moe(jax.random.key(1), cfg)
    s = 8
    x = jax.random.normal(jax.random.key(seed), (1, s, cfg.d_model),
                          jnp.float32)
    cap = _capacity(cfg, s)
    assert cap == 1
    y, _ = apply_moe(p, cfg, x)
    # at most e*cap = 2 tokens can be served; the rest are exactly zero rows
    nonzero_rows = int(jnp.sum(jnp.any(jnp.abs(y[0]) > 0, axis=-1)))
    assert nonzero_rows <= 2


def test_moe_permutation_equivariance_within_row():
    """Shuffling tokens within a row and unshuffling the output must give
    the same result when nothing is dropped (dispatch is content-based)."""
    cfg = _cfg(e=4, k=2, cf=8.0)
    p = init_moe(jax.random.key(2), cfg)
    s = 12
    x = jax.random.normal(jax.random.key(3), (1, s, cfg.d_model), jnp.float32)
    y, _ = apply_moe(p, cfg, x)
    perm = np.random.default_rng(0).permutation(s)
    y_p, _ = apply_moe(p, cfg, x[:, perm])
    np.testing.assert_allclose(np.asarray(y_p[0]), np.asarray(y[0][perm]),
                               atol=1e-4, rtol=1e-4)
