"""Roofline -> autoscaler bridge: the replica capacity C the controller
packs against comes from the dry-run's compiled serve_step."""
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.jsonl")


@pytest.mark.skipif(not os.path.exists(RESULTS),
                    reason="dry-run results not generated yet")
def test_derived_capacity_feeds_controller():
    from repro.serving.capacity import derived_replica_capacity
    from repro.core.controller import ControllerConfig

    base = derived_replica_capacity("deepseek-67b", "decode_32k",
                                    results_path=RESULTS)
    assert base["tokens_per_s"] > 0
    opt = derived_replica_capacity("deepseek-67b", "decode_32k",
                                   rules="tail256", results_path=RESULTS)
    # the optimized variant must serve strictly more tokens/s
    assert opt["tokens_per_s"] > base["tokens_per_s"] * 1.2

    cfg = ControllerConfig(capacity=opt["tokens_per_s"], algorithm="MBFP")
    assert cfg.capacity == opt["tokens_per_s"]


@pytest.mark.skipif(not os.path.exists(RESULTS),
                    reason="dry-run results not generated yet")
def test_all_baseline_cells_have_capacity():
    from repro.launch.shapes import SHAPES, applicable
    from repro import configs
    from repro.serving.capacity import derived_replica_capacity

    for arch in configs.list_archs():
        cfg = configs.get(arch)
        ok, _ = applicable(cfg, "decode_32k")
        if not ok:
            continue
        cap = derived_replica_capacity(arch, "decode_32k",
                                       results_path=RESULTS)
        assert cap["tokens_per_s"] > 0, arch
