"""Trace engine tests (``repro.scenarios.traces`` / ``.seeds``).

Load-bearing properties (ISSUE acceptance criteria):

* save -> load round-trips bit for bit in both formats (``.json`` stores
  float32 values exactly via the float32->double->float32 identity,
  ``.npz`` stores the raw arrays);
* a loaded trace resampled to its own length is the *same* arrays, and
  replayed through ``FleetRunner``'s padded ragged path it reproduces
  the direct engine run bit for bit -- recording is not a different
  simulator;
* validation rejects malformed traces (wrong version, rank, shape
  mismatch, non-finite / negative rates, rates under an inactive mask);
* the seed library (arXiv 2003.06452 shapes) is deterministic across
  calls and sessions (name-keyed, not ``hash``-keyed).

The property-based variant runs only when ``hypothesis`` is installed
(it is optional in this environment); a fixed-seed sweep covers the same
property otherwise.
"""
import numpy as np
import pytest

import jax

from repro.fleet import FleetConfig, FleetRunner
from repro.lagsim import LagSimConfig, sweep_lag
from repro.scenarios import (SEED_SHAPES, TRACE_VERSION, Trace, list_seeds,
                             load_trace, resample_trace, save_trace,
                             seed_trace, trace_from_scenario, validate_trace)

CFG = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)


def _trace(seed=0, batch=2, iters=16, n=5, family="adversarial", **knobs):
    return trace_from_scenario(family, jax.random.PRNGKey(seed), batch,
                               iters, n, capacity=1.0, name=f"t{seed}",
                               **knobs)


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ext", ["json", "npz"])
def test_save_load_bitexact(tmp_path, ext):
    tr = _trace(seed=3, family="bursty")
    path = str(tmp_path / f"t.{ext}")
    save_trace(tr, path)
    back = load_trace(path)
    assert back.version == TRACE_VERSION
    assert back.rates.dtype == np.float32 and back.active.dtype == np.bool_
    np.testing.assert_array_equal(back.rates, np.asarray(tr.rates))
    np.testing.assert_array_equal(back.active, np.asarray(tr.active))
    assert back.name == tr.name and back.capacity == tr.capacity
    assert back.meta["family"] == "bursty"


def test_json_and_npz_agree(tmp_path):
    tr = _trace(seed=4, family="churn")
    pj, pn = str(tmp_path / "t.json"), str(tmp_path / "t.npz")
    save_trace(tr, pj)
    save_trace(tr, pn)
    a, b = load_trace(pj), load_trace(pn)
    np.testing.assert_array_equal(a.rates, b.rates)
    np.testing.assert_array_equal(a.active, b.active)


def test_resample_identity_is_same_arrays():
    tr = _trace(seed=5)
    again = resample_trace(tr, tr.iters)
    assert again is tr


@pytest.mark.parametrize("method", ["hold", "linear"])
def test_resample_respects_mask_contract(method):
    tr = _trace(seed=6, iters=12, family="adversarial",
                lifecycle_frac=0.8, churn_p=0.05, death_frac=0.7)
    for iters in (6, 24, 37):
        rs = resample_trace(tr, iters, method=method)
        validate_trace(rs)          # includes silence-where-inactive
        assert rs.iters == iters and rs.batch == tr.batch and rs.n == tr.n
        assert rs.meta["resampled"]["from_iters"] == tr.iters


def test_resample_hold_repeats_steps():
    tr = _trace(seed=7, iters=8)
    rs = resample_trace(tr, 16, method="hold")
    np.testing.assert_array_equal(np.asarray(rs.rates)[:, 0::2],
                                  np.asarray(tr.rates))
    np.testing.assert_array_equal(np.asarray(rs.rates)[:, 1::2],
                                  np.asarray(tr.rates))


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_validate_rejects_malformed():
    tr = _trace(seed=8)
    rates, active = np.asarray(tr.rates), np.asarray(tr.active)
    with pytest.raises(ValueError, match="version"):
        validate_trace(Trace(rates=rates, active=active, capacity=1.0,
                             name="v", source="test", meta={}, version=99))
    with pytest.raises(ValueError, match=r"f32\[B, T, N\]"):
        validate_trace(Trace(rates=rates[0], active=active[0], capacity=1.0,
                             name="r", source="test", meta={}))
    with pytest.raises(ValueError, match="shape"):
        validate_trace(Trace(rates=rates, active=active[:, :-1],
                             capacity=1.0, name="s", source="test", meta={}))
    bad = rates.copy()
    bad[0, 0, 0] = -0.5
    with pytest.raises(ValueError, match="negative"):
        validate_trace(Trace(rates=bad, active=np.ones_like(active),
                             capacity=1.0, name="n", source="test", meta={}))
    loud = rates.copy()
    loud[~active] = 0.0
    loud[0, 0, 0] = 0.7
    silent = active.copy()
    silent[0, 0, 0] = False
    with pytest.raises(ValueError, match="mask contract"):
        validate_trace(Trace(rates=loud, active=silent, capacity=1.0,
                             name="m", source="test", meta={}))


def test_load_rejects_truncated_json(tmp_path):
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        f.write('{"kind": "repro.trace", "version": 1}')
    with pytest.raises((ValueError, KeyError)):
        load_trace(path)


# ---------------------------------------------------------------------------
# seed library (arXiv 2003.06452 shapes)
# ---------------------------------------------------------------------------
def test_seed_library_deterministic():
    assert sorted(list_seeds()) == sorted(SEED_SHAPES)
    for name in list_seeds():
        a = seed_trace(name, batch=2, iters=32, n=6)
        b = seed_trace(name, batch=2, iters=32, n=6)
        np.testing.assert_array_equal(np.asarray(a.rates),
                                      np.asarray(b.rates))
        assert a.meta["paper"] == "arXiv:2003.06452"
        assert a.source == f"seed:{name}"
        validate_trace(a)


def test_seed_shapes_differ():
    rates = [np.asarray(seed_trace(n, batch=1, iters=64, n=8).rates)
             for n in list_seeds()]
    for i in range(len(rates)):
        for j in range(i + 1, len(rates)):
            assert not np.array_equal(rates[i], rates[j])


# ---------------------------------------------------------------------------
# the acceptance property: replay == direct run, bit for bit,
# through the padded fleet path
# ---------------------------------------------------------------------------
def _roundtrip_equals_direct(tmp_path, seed, ext, family, iters, n):
    tr = _trace(seed=seed, batch=1, iters=iters, n=n, family=family)
    path = str(tmp_path / f"rt{seed}.{ext}")
    save_trace(tr, path)
    back = resample_trace(load_trace(path), iters)   # identity resample
    runner = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    res = runner.simulate(("BFD", "KEDA_LAG"),
                          [(back.rates[0], back.active[0])], CFG)
    direct = sweep_lag(("BFD", "KEDA_LAG"), tr.rates, CFG,
                       active=tr.active)
    assert res.lag_total[0].tobytes() == \
        np.asarray(direct.lag_total)[:, 0, :].tobytes()
    np.testing.assert_array_equal(res.consumers[0],
                                  np.asarray(direct.consumers)[:, 0, :])


@pytest.mark.parametrize("seed,ext,family,iters,n", [
    (11, "json", "adversarial", 20, 5),
    (12, "npz", "bursty", 32, 8),
    (13, "npz", "topic_lifecycle", 17, 6),
])
def test_roundtrip_replay_bitexact(tmp_path, seed, ext, family, iters, n):
    _roundtrip_equals_direct(tmp_path, seed, ext, family, iters, n)


def test_roundtrip_replay_bitexact_property(tmp_path):
    """Property-based variant when hypothesis is available: arbitrary
    shapes and formats, same bit-for-bit guarantee."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=10, deadline=None)
    @hyp.given(seed=st.integers(0, 2**16), ext=st.sampled_from(["json",
                                                                "npz"]),
               family=st.sampled_from(["adversarial", "churn", "bursty"]),
               iters=st.integers(4, 32), n=st.integers(2, 8))
    def prop(seed, ext, family, iters, n):
        _roundtrip_equals_direct(tmp_path, seed, ext, family, iters, n)

    prop()
