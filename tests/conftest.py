import os
import sys

# Allow `pytest tests/` without PYTHONPATH=src (the canonical invocation still
# sets it).  NOTE: never set XLA_FLAGS device-count overrides here -- smoke
# tests and benchmarks must see the single real CPU device; only
# launch/dryrun.py (run as its own process) forces 512 placeholder devices.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in [os.path.abspath(p) for p in sys.path]:
    sys.path.insert(0, os.path.abspath(_SRC))
